"""Fleet control plane: vmapped fleet_controller_step == per-camera host
``LatencyController.update`` for every camera, with ONE compiled variant
across subset table hot-swaps -- the issue's 64-camera acceptance bar."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import synthetic_controller_table as synthetic_table
from repro.analysis.trace_guard import assert_compiled_once, trace_guard
from repro.core.channel import calibrated_channel
from repro.core.characterization import (LatencyRegression,
                                         fit_latency_regression)
from repro.core.controller import (ControllerConfig, JaxControllerTables,
                                   LatencyController, FleetController,
                                   fleet_controller_init,
                                   fleet_controller_step, fleet_swap_tables,
                                   stack_params, stack_tables,
                                   ControllerParams)
from repro.core.scenario import (CameraSpec, InterferenceSpike, ScenarioSpec,
                                 TableRefresh, run_scenario)


@dataclasses.dataclass
class _Cam:
    """Minimal broker stand-in carrying what FleetController reads."""
    camera_id: str
    controller: LatencyController
    table_version: int = 0
    qos_version: int = 0


def build_fleet(n: int, *, seed: int = 0, capacity: int = 128):
    """n cameras with varied tables and varied (feasible) targets, plus
    shadow host controllers stepped in lockstep for parity checks."""
    rng = np.random.default_rng(seed)
    reg = LatencyRegression(slope=1.2e-6, intercept=0.008)
    cams, hosts = [], []
    for i in range(n):
        tbl = synthetic_table(12 + i % 29, smin=2e3 + 37.0 * i,
                              smax=9e4 - 101.0 * i)
        cfg = ControllerConfig(
            latency_target=0.040 + 0.001 * (i % 17),
            accuracy_target=0.90 + 0.002 * (i % 4))
        cams.append(_Cam(f"cam{i:03d}", LatencyController(cfg, tbl, reg)))
        hosts.append(LatencyController(cfg, tbl, reg))
    fleet = FleetController(cams, capacity=capacity)
    return cams, hosts, fleet, rng


class TestFleetParity:
    def test_64_camera_parity_single_compile_and_subset_swap(self):
        """The acceptance bar: 64 cameras, one compiled fleet step
        (cache size 1), host/jit decision parity on EVERY camera at EVERY
        step -- including across a mid-run hot-swap of a camera SUBSET's
        tables and a mid-run retarget of another subset."""
        n = 64
        cams, hosts, fleet, rng = build_fleet(n)
        swap_at, retarget_at = 20, 32
        with trace_guard(fleet):
            for step in range(48):
                if step == swap_at:
                    # re-characterization lands on 5 cameras at once
                    for i in (3, 17, 31, 44, 63):
                        fresh = synthetic_table(20 + i % 7,
                                                smin=3e3 + 11.0 * i,
                                                smax=7e4)
                        cams[i].controller.swap_table(fresh)
                        cams[i].table_version += 1
                        hosts[i].swap_table(fresh)
                if step == retarget_at:
                    # live QoS renegotiation on another subset
                    for i in (0, 8, 50):
                        cams[i].controller.set_target(0.075, 0.91)
                        cams[i].qos_version += 1
                        hosts[i].set_target(0.075, 0.91)
                fb = {c.camera_id: float(rng.uniform(0.005, 0.5))
                      for c in cams}
                decisions = fleet.decide(fb)
                for i, cam in enumerate(cams):
                    dh = hosts[i].update(fb[cam.camera_id])
                    df = decisions[cam.camera_id]
                    assert df.setting_index == dh.setting_index, (step, i)
                    assert df.acted == dh.acted, (step, i)
                    assert df.feasible == dh.feasible, (step, i)

    def test_lanes_without_feedback_hold(self):
        cams, hosts, fleet, rng = build_fleet(8)
        before = [c.controller._current for c in cams]
        decisions = fleet.decide({})           # nobody has samples yet
        for i, cam in enumerate(cams):
            d = decisions[cam.camera_id]
            assert not d.acted
            assert d.setting_index == before[i]
        # a later real tick still acts
        decisions = fleet.decide(
            {c.camera_id: 0.5 for c in cams})
        assert all(d.acted for d in decisions.values())

    def test_integral_carries_across_table_swap_but_resets_on_retarget(self):
        cams, hosts, fleet, rng = build_fleet(4)
        for _ in range(6):
            fb = {c.camera_id: float(rng.uniform(0.1, 0.4)) for c in cams}
            fleet.decide(fb)
        integ = np.asarray(fleet.state.integral)
        assert (integ != 0).any()
        cams[1].controller.swap_table(synthetic_table(16))
        cams[1].table_version += 1
        fleet.sync()
        assert float(fleet.state.integral[1]) == pytest.approx(
            float(integ[1]))                     # swap: integral carries
        cams[2].controller.set_target(0.08, 0.9)
        cams[2].qos_version += 1
        fleet.sync()
        assert float(fleet.state.integral[2]) == 0.0   # retarget: reset


class TestFleetPrimitives:
    def test_stack_tables_requires_shared_capacity(self):
        a = JaxControllerTables.from_table(synthetic_table(8), capacity=32)
        b = JaxControllerTables.from_table(synthetic_table(8), capacity=64)
        with pytest.raises(ValueError, match="capacity"):
            stack_tables([a, b])

    def test_fleet_swap_capacity_mismatch_rejected(self):
        rows = [JaxControllerTables.from_table(synthetic_table(8),
                                               capacity=32)
                for _ in range(3)]
        stack = stack_tables(rows)
        fresh = JaxControllerTables.from_table(synthetic_table(8),
                                               capacity=64)
        with pytest.raises(ValueError, match="capacity"):
            fleet_swap_tables(stack, 1, fresh)

    def test_fleet_swap_subset_only_touches_named_lanes(self):
        rows = [JaxControllerTables.from_table(synthetic_table(8 + i),
                                               capacity=32)
                for i in range(4)]
        stack = stack_tables(rows)
        fresh = JaxControllerTables.from_table(synthetic_table(20),
                                               capacity=32)
        out = fleet_swap_tables(stack, 2, fresh)
        np.testing.assert_array_equal(np.asarray(out.sizes_sorted[2]),
                                      np.asarray(fresh.sizes_sorted))
        for lane in (0, 1, 3):
            np.testing.assert_array_equal(
                np.asarray(out.sizes_sorted[lane]),
                np.asarray(stack.sizes_sorted[lane]))
        assert int(out.n_valid[2]) == 20

    def test_capacity_growth_rebuilds_deliberately(self):
        """TWO cameras outgrow the shared capacity in the same sync (the
        rebuild must size to the fleet-wide max, not the first offender),
        after the lanes have accumulated LIVE PI state -- which must carry
        across the rebuild (the host fields are stale in fleet mode)."""
        cams, hosts, fleet, rng = build_fleet(4, capacity=48)
        # accumulate live integral / operating-point state first
        for _ in range(6):
            fb = {c.camera_id: float(rng.uniform(0.1, 0.4)) for c in cams}
            decisions = fleet.decide(fb)
            for i, cam in enumerate(cams):
                dh = hosts[i].update(fb[cam.camera_id])
                assert decisions[cam.camera_id].setting_index == \
                    dh.setting_index
        for i, n_rows in ((0, 200), (2, 300)):
            big = synthetic_table(n_rows)
            cams[i].controller.swap_table(big)
            cams[i].table_version += 1
            hosts[i].swap_table(big)
        for step in range(4):
            fb = {c.camera_id: float(rng.uniform(0.1, 0.4)) for c in cams}
            decisions = fleet.decide(fb)
            assert fleet.capacity >= 300
            for i, cam in enumerate(cams):
                dh = hosts[i].update(fb[cam.camera_id])
                assert decisions[cam.camera_id].setting_index == \
                    dh.setting_index, (step, i)

    def test_vmapped_step_matches_manual_loop(self):
        """fleet_controller_step == N independent single-camera cores."""
        rows = [JaxControllerTables.from_table(synthetic_table(10 + i),
                                               capacity=64)
                for i in range(6)]
        stack = stack_tables(rows)
        reg = LatencyRegression(slope=1e-6, intercept=0.005)
        params = stack_params([
            ControllerParams.from_scalars(
                latency_target=0.05 + 0.01 * i, accuracy_target=0.9,
                slope=reg.slope, intercept=reg.intercept)
            for i in range(6)])
        states = fleet_controller_init(stack)
        lats = jnp.asarray(np.linspace(0.02, 0.4, 6), jnp.float32)
        new_states, aux = fleet_controller_step(states, lats, stack, params)
        assert aux.idx.shape == (6,)
        # every lane's chosen index is a LIVE row of its own table
        for i in range(6):
            assert 0 <= int(aux.idx[i]) < int(stack.n_valid[i])


class TestMeshParity:
    """The sharded dispatch: a mesh-partitioned fleet (``shard_map`` over
    the camera axis) is bit-identical to the unmeshed fleet and the host
    controllers, with ONE compiled (and placement-stable) dispatch across
    subset swaps and retargets.  The 8-device variant lives in
    tests/test_fleet_sharded.py (forced host platform device count)."""

    def test_one_device_mesh_matches_unmeshed(self):
        n = 13
        cams, hosts, fleet, rng = build_fleet(n)
        meshed = FleetController(cams, capacity=128, mesh=1)
        assert meshed.mesh is not None
        with trace_guard(fleet), trace_guard(meshed):
            for step in range(40):
                if step == 12:
                    for i in (2, 7, 11):
                        fresh = synthetic_table(20 + i,
                                                smin=3e3 + 11.0 * i,
                                                smax=7e4)
                        cams[i].controller.swap_table(fresh)
                        cams[i].table_version += 1
                        hosts[i].swap_table(fresh)
                if step == 24:
                    for i in (0, 5):
                        cams[i].controller.set_target(0.075, 0.91)
                        cams[i].qos_version += 1
                        hosts[i].set_target(0.075, 0.91)
                fb = {c.camera_id: float(rng.uniform(0.005, 0.5))
                      for c in cams}
                dm = meshed.decide(fb)
                du = fleet.decide(fb)
                for i, cam in enumerate(cams):
                    dh = hosts[i].update(fb[cam.camera_id])
                    a, b = dm[cam.camera_id], du[cam.camera_id]
                    assert a == b, (step, i)
                    assert a.setting_index == dh.setting_index, (step, i)
                    assert a.acted == dh.acted, (step, i)
                    assert a.feasible == dh.feasible, (step, i)
        assert meshed.cache_size() == 1

    def test_mesh_pads_lanes_to_device_multiple(self):
        from repro.sharding.partition import fleet_mesh, padded_lane_count
        mesh = fleet_mesh(1)
        assert padded_lane_count(13, mesh) == 13
        cams, _, _, _ = build_fleet(3)
        meshed = FleetController(cams, capacity=64, mesh=1)
        assert meshed._n_padded >= meshed.n_lanes == 3


class TestFleetScenarioParity:
    """The satellite: fleet decisions equal the per-camera host controller
    across a WHOLE scenario, and the compiled step survives a mid-scenario
    per-camera table swap with cache size 1."""

    def _spec(self, **kw):
        base = dict(
            name="fleet-parity",
            cameras=tuple(CameraSpec(f"cam{i}", dynamics="medium")
                          for i in range(3)),
            frames=30, seed=9, workload="jaad",
            latency=0.100, accuracy=0.92, fleet=True,
            record_decisions=True,
            events=(InterferenceSpike(start=2.0, end=4.0, factor=7.0),),
        )
        base.update(kw)
        return ScenarioSpec(**base)

    def test_fleet_trace_identical_to_host_trace(self):
        tables = {"medium": synthetic_table()}
        flt = run_scenario(self._spec(), tables=tables)
        host = run_scenario(self._spec(fleet=False, record_decisions=False),
                            tables=tables)
        assert flt.to_json() == host.to_json()
        assert_compiled_once(flt.fleet_cache_size, "fleet step")

    def test_mesh_scenario_trace_identical_to_host_trace(self):
        """Satellite 3: the fused + sharded replay (1-device mesh) is
        byte-identical to the host-path trace -- so the committed golden
        traces pin the meshed path too."""
        tables = {"medium": synthetic_table()}
        meshed = run_scenario(self._spec(mesh=1), tables=tables)
        host = run_scenario(self._spec(fleet=False, record_decisions=False),
                            tables=tables)
        assert meshed.to_json() == host.to_json()
        assert_compiled_once(meshed.fleet_cache_size, "meshed fleet step")

    def test_history_replays_against_host_controllers(self):
        """Replay the recorded fleet decision history through fresh host
        ``LatencyController``s: every lane's index matches at every step."""
        spec = self._spec()
        tbl = synthetic_table()
        res = run_scenario(spec, tables={"medium": tbl})
        assert res.fleet_history
        # reconstruct the scenario's controllers exactly (same channel
        # regression fit, same config defaults as CamBroker.set_target)
        ch = calibrated_channel(seed=spec.seed, workload=spec.workload)
        sizes = np.linspace(tbl.sizes_sorted[0], tbl.sizes_sorted[-1], 16)
        reg = fit_latency_regression(
            sizes, ch.regression_points(sizes, n=len(spec.cameras)))
        hosts = [LatencyController(
            ControllerConfig(spec.latency, spec.accuracy), tbl, reg)
            for _ in spec.cameras]
        for step, row in enumerate(res.fleet_history):
            for i, host in enumerate(hosts):
                if row["fed"][i]:
                    dh = host.update(row["lat"][i])
                    assert row["idx"][i] == dh.setting_index, (step, i)
                else:
                    assert row["idx"][i] == host._current, (step, i)

    def test_mid_scenario_table_refresh_keeps_single_compile(self):
        """Online re-characterization of ONE camera mid-scenario hot-swaps
        its lane; the fleet step never recompiles."""
        spec = self._spec(events=(TableRefresh(at=3.0, camera_id="cam1"),),
                          frames=40)
        res = run_scenario(spec, tables={"medium": synthetic_table()})
        refreshed = [e for e in res.events_log
                     if e.get("kind") == "TableRefresh"]
        assert refreshed and refreshed[0]["refreshed"] is True
        assert_compiled_once(res.fleet_cache_size, "fleet step")
        assert len(res.rows) == 3 * 40


class TestFleetDriftParity:
    """The PR 5 satellite: drift-aware AUTO-recharacterization across a
    mid-run ``SceneShift`` produces bit-identical traces on the fleet and
    host control paths, and the compiled fleet step survives the
    drift-triggered per-lane hot-swaps with cache size 1."""

    @pytest.fixture(scope="class")
    def drift_tables(self):
        from repro.core.characterization import characterize
        from repro.data.camera import CameraConfig, SyntheticCamera

        def table(cid):
            return characterize(
                lambda: SyntheticCamera(CameraConfig(
                    camera_id=cid, dynamics="simple", seed=7)),
                clip_len=10, min_accuracy=0.90)
        return {cid: table(cid) for cid in ("cam0", "cam1", "cam2")}

    def _spec(self, **kw):
        from repro.core.scenario import SceneShift
        base = dict(
            name="fleet-drift-parity",
            cameras=tuple(CameraSpec(f"cam{i}", dynamics="simple")
                          for i in range(3)),
            frames=40, seed=9, workload="jaad",
            latency=0.100, accuracy=0.95, min_accuracy=0.90,
            fleet=True, auto_recharacterize=True,
            events=(SceneShift(at=3.0, camera_id="cam1",
                               dynamics="complex"),),
        )
        base.update(kw)
        return ScenarioSpec(**base)

    def test_auto_recharacterization_fleet_matches_host_bit_for_bit(
            self, drift_tables):
        flt = run_scenario(self._spec(), tables=drift_tables)
        host = run_scenario(self._spec(fleet=False), tables=drift_tables)
        # the drift loop actually ran: the shifted camera re-swept, the
        # stationary cameras did not, on BOTH control paths identically
        for res in (flt, host):
            refreshed = [e for e in res.events_log
                         if e.get("kind") == "table_refresh"]
            assert refreshed, res.events_log
            assert {e["camera_id"] for e in refreshed} == {"cam1"}
            assert res.drift_fire_counts["cam1"] >= 1
            assert res.drift_fire_counts["cam0"] == 0
            assert res.drift_fire_counts["cam2"] == 0
            assert_compiled_once(res.drift_cache_size, "drift step")
        assert flt.to_json() == host.to_json()
        # drift-triggered per-lane table swaps never recompile the fleet
        assert_compiled_once(flt.fleet_cache_size, "fleet step")
        assert host.fleet_cache_size is None      # host path has no fleet

    def test_mesh_drift_scene_shift_matches_host_bit_for_bit(
            self, drift_tables):
        """Satellite 3: SceneShift + drift-fired mid-run table swaps on a
        1-device mesh -- fused sharded decisions bit-identical to the host
        path, one compiled dispatch throughout."""
        meshed = run_scenario(self._spec(mesh=1), tables=drift_tables)
        host = run_scenario(self._spec(fleet=False), tables=drift_tables)
        assert meshed.to_json() == host.to_json()
        assert meshed.drift_fire_counts["cam1"] >= 1
        assert_compiled_once(meshed.fleet_cache_size, "meshed fleet step")
        assert_compiled_once(meshed.drift_cache_size, "drift step")

    def test_sync_reports_exactly_the_refreshed_lanes(self):
        """``FleetController.sync`` returns the lane sets it rewrote --
        the drift loop's contract that a refresh touches exactly the fired
        cameras."""
        cams, hosts, fleet, rng = build_fleet(6)
        assert fleet.sync() == ([], [])
        fresh = synthetic_table(18)
        for i in (1, 4):
            cams[i].controller.swap_table(fresh)
            cams[i].table_version += 1
        cams[2].controller.set_target(0.08, 0.91)
        cams[2].qos_version += 1
        swapped, retargeted = fleet.sync()
        assert swapped == [1, 4]
        assert retargeted == [2]
        assert fleet.sync() == ([], [])
