"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
output shapes + no NaNs, plus cross-path consistency invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, cells_for, get_config, skipped_cells_for
from repro.models.registry import (DECODE_SLACK, build_model, cache_spec,
                                   input_specs, make_batch)

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        m = build_model(cfg)
        out[arch] = (cfg, m, m.init_params(KEY))
    return out


@pytest.mark.parametrize("arch", list(ARCHS))
class TestSmoke:
    def test_train_step_shapes_and_finite(self, models, arch):
        cfg, m, params = models[arch]
        batch = make_batch(cfg, B, S, train=True)
        logits, aux = jax.jit(m.forward)(params, batch)
        assert logits.shape[0] == B
        assert logits.shape[-1] == cfg.padded_vocab
        assert bool(jnp.isfinite(logits).all()), arch
        loss = jax.jit(m.loss_fn)(params, batch)
        assert bool(jnp.isfinite(loss))
        assert 0.0 < float(loss) < 20.0

    def test_grads_finite_nonzero(self, models, arch):
        cfg, m, params = models[arch]
        batch = make_batch(cfg, B, S, train=True)
        grads = jax.grad(m.loss_fn)(params, batch)
        flat = jax.tree_util.tree_leaves(grads)
        assert all(bool(jnp.isfinite(g).all()) for g in flat), arch
        total = sum(float(jnp.abs(g).sum()) for g in flat)
        assert total > 0

    def test_prefill_decode(self, models, arch):
        cfg, m, params = models[arch]
        pb = make_batch(cfg, B, S, train=False)
        kw = {"enc_len": S} if cfg.family == "audio" else {}
        cache = m.init_cache(B, S + 8, **kw)
        logits, cache = jax.jit(m.prefill)(params, pb, cache)
        assert logits.shape[:2] == (B, 1)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        logits2, cache2 = jax.jit(m.decode_step)(params, tok, cache)
        assert bool(jnp.isfinite(logits2).all())
        assert int(cache2.length) == int(cache.length) + 1

    def test_padded_vocab_never_wins(self, models, arch):
        cfg, m, params = models[arch]
        if cfg.padded_vocab == cfg.vocab_size:
            pytest.skip("no padding at this vocab")
        batch = make_batch(cfg, B, S, train=True)
        logits, _ = m.forward(params, batch)
        assert int(jnp.argmax(logits, -1).max()) < cfg.vocab_size

    def test_input_specs_cover_cells(self, models, arch):
        cfg, _, _ = models[arch]
        full = get_config(arch)
        from repro.configs.base import SHAPE_CELLS
        for cell_name in cells_for(arch):
            specs = input_specs(full, SHAPE_CELLS[cell_name])
            leaves = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)

    def test_shape_cell_skips_documented(self, models, arch):
        cfg, _, _ = models[arch]
        skips = skipped_cells_for(arch)
        if cfg.supports_long_context:
            assert "long_500k" in cells_for(arch) and not skips
        else:
            assert "long_500k" in skips


class TestConsistency:
    """Cross-path invariants: training forward vs serving prefill+decode."""

    @pytest.mark.parametrize("arch", ["llama3-8b", "qwen3-1.7b",
                                      "rwkv6-1.6b", "zamba2-7b"])
    def test_prefill_matches_forward_tail(self, models, arch):
        """prefill's last-position logits == forward's last-position logits
        (identical math, different cache plumbing)."""
        cfg, m, params = models[arch]
        batch = make_batch(cfg, B, S, train=False)
        full_logits, _ = m.forward(params, batch)
        cache = m.init_cache(B, S + 8)
        pre_logits, _ = m.prefill(params, batch, cache)
        np.testing.assert_allclose(
            np.asarray(pre_logits[:, 0], np.float32),
            np.asarray(full_logits[:, -1], np.float32), rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("arch", ["llama3-8b", "rwkv6-1.6b"])
    def test_decode_matches_forward(self, models, arch):
        """Teacher-forced decode over S tokens == forward over the full
        sequence (step-by-step cache path is exact)."""
        cfg, m, params = models[arch]
        toks = make_batch(cfg, B, 12, train=False)["tokens"]
        full_logits, _ = m.forward(params, {"tokens": toks})
        cache = m.init_cache(B, 12 + 8)
        logits, cache = m.prefill(params, {"tokens": toks[:, :4]}, cache)
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   np.asarray(full_logits[:, 3], np.float32),
                                   rtol=2e-4, atol=2e-4)
        for t in range(4, 12):
            logits, cache = m.decode_step(params, toks[:, t : t + 1], cache)
            np.testing.assert_allclose(
                np.asarray(logits[:, 0], np.float32),
                np.asarray(full_logits[:, t], np.float32),
                rtol=3e-4, atol=3e-4)

    def test_moe_dispatch_conservation(self, models):
        """Every kept token's gates sum to ~1 after renormalization; capacity
        drops only ever REMOVE contribution (output norm <= dense bound)."""
        cfg, m, params = models["phi3.5-moe-42b-a6.6b"]
        from repro.models import moe as moe_mod
        lp = jax.tree_util.tree_map(lambda x: x[0], params["layers"])
        x = jax.random.normal(jax.random.fold_in(KEY, 9),
                              (2, 16, cfg.d_model)) * 0.5
        hi = dataclasses.replace(cfg, capacity_factor=8.0)
        lo = dataclasses.replace(cfg, capacity_factor=0.10)
        y_hi, _ = moe_mod.moe_ffn(lp["moe"], x, hi)
        y_lo, _ = moe_mod.moe_ffn(lp["moe"], x, lo)
        assert bool(jnp.isfinite(y_hi).all()) and bool(jnp.isfinite(y_lo).all())
        # generous capacity must route more mass than a starved one
        assert float(jnp.abs(y_hi).mean()) >= float(jnp.abs(y_lo).mean())

    def test_mamba2_chunk_invariance(self):
        """SSD output is independent of chunk size (exact algorithm)."""
        from repro.models.mamba2 import ssd_chunked
        b, s, h, p, n = 2, 64, 3, 8, 4
        x = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h, p)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(
            jax.random.fold_in(KEY, 2), (b, s, h)))
        Bm = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, n)) * 0.5
        Cm = jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, n)) * 0.5
        A = -jnp.exp(jnp.linspace(-1, 1, h))
        D = jnp.ones((h,))
        y16, h16 = ssd_chunked(x, dt, Bm, Cm, A, D, chunk=16)
        y64, h64 = ssd_chunked(x, dt, Bm, Cm, A, D, chunk=64)
        np.testing.assert_allclose(np.asarray(y16), np.asarray(y64),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h16), np.asarray(h64),
                                   rtol=2e-4, atol=2e-4)

    def test_param_count_sane(self):
        """Analytic param counts should be within 20% of actual leaves."""
        for arch in ("llama3-8b", "qwen3-1.7b"):
            cfg = get_config(arch)
            reduced = cfg.reduced()
            m = build_model(reduced)
            params = m.init_params(KEY)
            actual = sum(np.prod(p.shape) for p in
                         jax.tree_util.tree_leaves(params))
            est = reduced.param_count()
            # reduced configs pad vocab to 512 which the formula tracks via
            # vocab_size; allow tolerance for norms/small tensors
            assert 0.7 < est / actual < 1.3, (arch, est, actual)
