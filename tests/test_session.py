"""v2 session API: multi-camera fan-in, FrameBatch invariants, live QoS
renegotiation, events, lifecycle, compat-shim equivalence, and multi-tenant
admission control (SLO classes, wire-budget feasibility, shared cache)."""

import threading

import numpy as np
import pytest

from repro.core.api import (AdmissionRejected, EventKind, FrameBatch,
                            QosBounds, RPCTimeout, SessionEvent, Status,
                            SubscribeSpec, SubscriptionOptions,
                            SubscriptionState)
from repro.core.broker import MezSystem
from repro.core import knobs as K
from repro.core.channel import calibrated_channel
from repro.core.characterization import characterize, fit_latency_regression
from repro.core import detector as det
from repro.core.session import MezClient
from repro.data.camera import CameraConfig, SyntheticCamera
from repro.data.pipeline import CameraBatcher


@pytest.fixture(scope="module")
def table():
    return characterize(
        lambda: SyntheticCamera(CameraConfig(dynamics="medium", seed=7)),
        clip_len=10)


def build_system(table, *, n_cams=2, frames=10, workload=None, seed=3,
                 wire_budget=None):
    ch = calibrated_channel(seed=seed, workload=workload)
    sys = MezSystem(ch, wire_budget=wire_budget)
    sizes = np.linspace(table.sizes_sorted[0], table.sizes_sorted[-1], 12)
    reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=n_cams))
    for i in range(n_cams):
        cam = sys.add_camera(f"cam{i}")
        src = SyntheticCamera(CameraConfig(camera_id=f"cam{i}",
                                           dynamics="medium", seed=7))
        cam.background = src.background
        cam.set_target(0.100, 0.90, table, reg)
        for ts, f, gt in src.stream(frames):
            cam.publish(ts, f)
    return sys


def open_sub(sys, cameras, *, latency=0.1, accuracy=0.9, t_stop=100.0):
    sess = MezClient(sys).open_session("app")
    return sess, sess.subscribe(cameras, 0.0, t_stop,
                                qos=QosBounds(latency, accuracy))


class TestFanIn:
    def test_multi_camera_chronological_merge(self, table):
        sys = build_system(table, n_cams=3, frames=10)
        sess, sub = open_sub(sys, ["cam0", "cam1", "cam2"])
        total, seen = 0, {f"cam{i}": [] for i in range(3)}
        while (batch := sub.poll(max_frames=9)):
            ts = batch.timestamps
            # merged batch is sorted, ties broken by camera id
            assert all((a.timestamp, a.camera_id) <= (b.timestamp, b.camera_id)
                       for a, b in zip(batch.frames, batch.frames[1:]))
            for d in batch.frames:
                seen[d.camera_id].append(d.timestamp)
            total += len(batch)
        assert total == 30
        # per-camera order is preserved end to end (at-most-once, no dupes)
        for cid, stamps in seen.items():
            assert stamps == sorted(stamps)
            assert len(stamps) == len(set(stamps)) == 10
        assert sub.state is SubscriptionState.DRAINED
        sess.close()

    def test_max_frames_respected(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        while (batch := sub.poll(max_frames=3)):
            assert len(batch) <= 3
        sess.close()

    def test_credit_backpressure_bounds_per_camera(self, table):
        """One poll never pulls more than credit_limit frames per camera."""
        sys = build_system(table, n_cams=2, frames=10)
        sess = MezClient(sys).open_session("app")
        sub = sess.subscribe(["cam0", "cam1"], 0.0, 100.0,
                             qos=QosBounds(0.1, 0.9),
                             options=SubscriptionOptions(credit_limit=2))
        while (batch := sub.poll(max_frames=16)):
            per_cam = {}
            for d in batch.frames:
                per_cam[d.camera_id] = per_cam.get(d.camera_id, 0) + 1
            assert all(v <= 2 for v in per_cam.values())
        sess.close()


class TestFrameBatch:
    def test_stack_shape_and_valid_mask(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        batch = sub.poll(max_frames=4)
        payload, valid = batch.stack()
        assert payload.dtype == np.float32
        assert payload.ndim == 4
        assert payload.shape[0] == len(batch.delivered) == int(valid.sum())
        sess.close()

    def test_stack_fixed_batch_size_pads_with_zeros(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        batch = sub.poll(max_frames=4)
        n = len(batch.delivered)
        payload, valid = batch.stack(batch_size=8)
        assert payload.shape[0] == 8
        assert valid.tolist() == [True] * n + [False] * (8 - n)
        assert not payload[n:].any()
        if n >= 1:
            with pytest.raises(ValueError):
                batch.stack(batch_size=n - 1)
        sess.close()

    def test_stack_empty(self):
        payload, valid = FrameBatch(()).stack(batch_size=4)
        assert payload.shape[0] == 4 and not valid.any()
        assert not FrameBatch(())


class TestQosRenegotiation:
    def test_update_qos_retargets_in_place(self, table):
        sys = build_system(table, n_cams=5, frames=20, workload="dukemtmc")
        sess, sub = open_sub(sys, "cam0")
        for _ in range(3):
            sub.poll(max_frames=2)
        ctl = sys.cams["cam0"].controller
        ctl_id = id(ctl)
        q = sub.update_qos(latency=0.030)
        assert q.status is Status.OK and q.applied_cameras == ("cam0",)
        # same controller object: retarget happened IN PLACE, no teardown
        assert id(sys.cams["cam0"].controller) == ctl_id
        assert ctl.config.latency_target == 0.030
        assert ctl.config.accuracy_target == 0.90   # unchanged axis preserved
        assert sub.state is SubscriptionState.ACTIVE
        sess.close()

    def test_update_qos_effective_within_one_interval(self, table):
        """Retarget re-seeds the operating point: the setting moves toward
        the new target's nominal size immediately, not after N samples."""
        sys = build_system(table, n_cams=5, frames=20, workload="dukemtmc")
        sess, sub = open_sub(sys, "cam0", latency=0.030)
        for _ in range(4):
            sub.poll(max_frames=2)
        ctl = sys.cams["cam0"].controller
        size_tight = table.size_by_setting[ctl._current]
        sub.update_qos(latency=1.0)          # relax drastically
        size_relaxed = table.size_by_setting[ctl._current]
        assert size_relaxed >= size_tight    # reseeded before any feedback
        batch = sub.poll(max_frames=2)       # next interval ships bigger frames
        assert batch
        assert all(d.wire_bytes >= size_tight * 0.5 for d in batch.delivered)
        sess.close()

    def test_update_qos_recharacterize_hot_swaps_tables(self, table):
        """``update_qos(recharacterize=True)`` re-sweeps the camera's knob
        tables from its own recent frames and hot-swaps them into the live
        controller (host + padded jit twin) before applying the bounds."""
        sys = build_system(table, n_cams=1, frames=20)
        sess, sub = open_sub(sys, "cam0")
        for _ in range(3):
            sub.poll(max_frames=4)
        cam = sys.cams["cam0"]
        v0 = cam.table_version
        q = sub.update_qos(latency=0.080, recharacterize=True)
        assert q.status is Status.OK
        assert q.recharacterized == ("cam0",)
        assert cam.table_version == v0 + 1
        assert cam.controller.table is not table   # fresh live-clip table
        assert cam.controller.table.proxy is not None
        assert cam.controller.config.latency_target == 0.080
        assert int(cam.jax_tables.n_valid) == len(cam.controller.table.settings)
        assert sub.poll(max_frames=4)              # stream survives the swap
        sess.close()

    def test_session_update_qos_fans_out(self, table):
        """Session.update_qos returns ONE merged QosUpdate whose
        subscription_ids / per_camera fields carry the fan-out detail (it
        used to return a list)."""
        sys = build_system(table, n_cams=2, frames=10)
        sess = MezClient(sys).open_session("app")
        sub0 = sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        sub1 = sess.subscribe("cam1", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        merged = sess.update_qos(latency=0.050)
        assert set(merged.subscription_ids) == {
            sub0.subscription_id, sub1.subscription_id}
        assert merged.status is Status.OK
        assert set(merged.applied_cameras) == {"cam0", "cam1"}
        assert {r.camera_id for r in merged.per_camera} == {"cam0", "cam1"}
        assert all(r.status is Status.OK for r in merged.per_camera)
        assert sys.cams["cam0"].controller.config.latency_target == 0.050
        sess.close()

    def test_subscription_update_qos_same_shape(self, table):
        """Subscription.update_qos fills the same unified fields."""
        sys = build_system(table, n_cams=1, frames=10)
        sess = MezClient(sys).open_session("app", tenant="acme", slo="gold")
        sub = sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        q = sub.update_qos(latency=0.080)
        assert q.subscription_ids == (sub.subscription_id,)
        assert q.tenant == "acme" and q.slo_class == "gold"
        assert [r.camera_id for r in q.per_camera] == ["cam0"]
        sess.close()

    def test_update_qos_on_closed_subscription_fails(self, table):
        sys = build_system(table)
        sess, sub = open_sub(sys, "cam0")
        sub.close()
        assert sub.update_qos(latency=0.2).status is Status.FAIL
        sess.close()


class TestEventsAndFailures:
    def test_infeasible_surfaces_as_event(self, table):
        sys = build_system(table, n_cams=5, frames=12, workload="dukemtmc")
        sess, sub = open_sub(sys, "cam0", latency=0.001, accuracy=0.999)
        while sub.poll(max_frames=2):
            pass
        kinds = {e.kind for e in sub.events()}
        assert EventKind.INFEASIBLE in kinds
        sess.close()

    def test_partial_camera_failure_keeps_streaming(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sys.cams["cam0"].crash()
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        got = []
        while (batch := sub.poll(max_frames=4)):
            got.extend(batch.frames)
        assert len(got) == 10                      # cam1's stream survives
        assert {d.camera_id for d in got} == {"cam1"}
        evs = sub.events()
        assert any(e.kind is EventKind.RPC_TIMEOUT and e.camera_id == "cam0"
                   for e in evs)
        assert sub.state is SubscriptionState.FAILED
        sess.close()

    def test_all_cameras_failed_raises(self, table):
        sys = build_system(table)
        sys.cams["cam0"].crash()
        sess, sub = open_sub(sys, "cam0")
        with pytest.raises(RPCTimeout):
            sub.poll()
        sess.close()

    def test_session_events_aggregates_subscriptions(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sys.cams["cam0"].crash()
        sess = MezClient(sys).open_session("app")
        sub0 = sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        sub1 = sess.subscribe("cam1", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        with pytest.raises(RPCTimeout):
            sub0.poll()
        while sub1.poll(max_frames=4):
            pass
        evs = sess.events()
        assert any(e.subscription_id == sub0.subscription_id for e in evs)
        assert sess.close() is Status.OK


class TestRoundRobinFairness:
    """Regression for the cached round-robin active order: the cache must
    be invalidated on crash/detach/drain/reattach so rotation stays fair
    across membership changes (satellite of the fused-tick PR)."""

    def test_rotation_fair_across_crash_and_reattach(self, table):
        cams = [f"cam{i}" for i in range(4)]
        sys = build_system(table, n_cams=4, frames=40)
        sess, sub = open_sub(sys, cams)

        def window(n):
            """n consecutive max_frames=1 polls -> the head camera of each
            rotation (every camera always has frames pending)."""
            ids = []
            for _ in range(n):
                batch = sub.poll(max_frames=1)
                assert len(batch) == 1
                ids.append(batch.frames[0].camera_id)
            return ids

        # 4 live cameras: every window of 4 polls visits each exactly once
        for _ in range(2):
            assert sorted(window(4)) == cams

        # crash one mid-stream; rotation discovers it (no cam1 frames) and
        # the cached order is rebuilt over the 3 survivors
        sys.cams["cam1"].crash()
        assert "cam1" not in window(4)
        survivors = ["cam0", "cam2", "cam3"]
        for _ in range(2):
            assert sorted(window(3)) == survivors
        assert any(e.kind is EventKind.RPC_TIMEOUT and e.camera_id == "cam1"
                   for e in sub.events())

        # recover + reattach: cache invalidates again, rotation is fair
        # over all 4 and the late camera resumes from its old cursor
        sys.cams["cam1"].recover()
        assert sys.edge.reattach_camera(sub.subscription_id,
                                        "cam1") is Status.OK
        for _ in range(2):
            assert sorted(window(4)) == cams
        sess.close()


class TestLifecycle:
    def test_close_is_idempotent(self, table):
        sys = build_system(table)
        sess, sub = open_sub(sys, "cam0")
        assert sub.close() is Status.OK
        assert sub.close() is Status.OK            # second close: still OK
        assert sub.state is SubscriptionState.CLOSED
        assert not sub.poll()                      # closed => empty batch
        assert sess.close() is Status.OK
        assert sess.close() is Status.OK

    def test_context_managers_close(self, table):
        sys = build_system(table)
        with MezClient(sys).open_session("app") as sess:
            with sess.subscribe("cam0", 0.0, 100.0,
                                qos=QosBounds(0.1, 0.9)) as sub:
                assert sub.poll(max_frames=2)
            assert sub.state is SubscriptionState.CLOSED
        assert sess.closed

    def test_unknown_camera_rejected_at_create(self, table):
        sys = build_system(table)
        sess = MezClient(sys).open_session("app")
        with pytest.raises(RPCTimeout):
            sess.subscribe("ghost", 0.0, 1.0, qos=QosBounds(0.1, 0.9))
        sess.close()


class TestCompatShim:
    def test_v1_iterator_matches_v2_poll(self, table):
        """The old blocking iterator and the session API produce identical
        frame sequences (timestamps, wire bytes, knobs, latencies)."""
        key = lambda d: (d.timestamp, d.wire_bytes, d.knob_index,
                         round(d.latency.total, 12))
        sys_old = build_system(table, n_cams=5, frames=12, workload="jaad")
        old = [key(d) for d in sys_old.edge.subscribe(
            SubscribeSpec("app", "cam0", 0.0, 100.0, 0.1, 0.9))]
        sys_new = build_system(table, n_cams=5, frames=12, workload="jaad")
        sess, sub = open_sub(sys_new, "cam0")
        new = []
        while (batch := sub.poll(max_frames=2)):   # = shim's fetch_window
            new.extend(key(d) for d in batch.frames)
        assert old == new
        sess.close()

    def test_v1_unsubscribe_stops_v2_backed_stream(self, table):
        sys = build_system(table)
        it = sys.edge.subscribe(SubscribeSpec("app", "cam0", 0.0, 100.0,
                                              0.1, 0.9))
        next(it)
        assert sys.edge.unsubscribe("app", "cam0") is Status.OK
        assert len(list(it)) <= 1                  # current fetch drains only


class TestBatchConsumers:
    def test_camera_batcher_consumes_frame_batches(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        batcher = CameraBatcher(batch=4)
        model_batches, delivered = [], 0
        while (batch := sub.poll(max_frames=8)):
            delivered += len(batch.delivered)
            model_batches.extend(batcher.push_batch(batch))
        assert len(model_batches) == delivered // 4
        assert all(b.shape[0] == 4 for b in model_batches)
        sess.close()

    def test_detect_batch_runs_per_camera_background(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        bgs = {f"cam{i}": SyntheticCamera(
            CameraConfig(camera_id=f"cam{i}", dynamics="medium",
                         seed=7)).background for i in range(2)}
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        batch = sub.poll(max_frames=6)
        pairs = det.detect_batch(batch, lambda d: bgs[d.camera_id])
        assert len(pairs) == len(batch.delivered)
        for d, boxes in pairs:
            assert boxes.ndim == 2 and boxes.shape[1] == 4
        sess.close()


# -- multi-tenant serving ------------------------------------------------------


def slo_loads(table, *, n_cams=1, latency=0.1, accuracy=0.9):
    """(demand_bps, floor_bps) of one SLO-classed single-camera subscription,
    measured on a throwaway system.  Deterministic: the admission controller
    costs lanes from the characterization tables + channel config only, so a
    rebuilt identical system reports identical loads."""
    sys = build_system(table, n_cams=n_cams)
    sess = MezClient(sys).open_session("probe", tenant="probe", slo="gold")
    sub = sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(latency, accuracy))
    rep = sys.edge.wire_report()["subscriptions"][sub.subscription_id]
    sess.close()
    return rep["demand_bps"], rep["floor_bps"]


def sub_scale(sys, sub):
    return sys.edge.wire_report()["subscriptions"][
        sub.subscription_id]["scale"]


class TestAdmissionControl:
    def test_untenanted_flows_never_enter_admission(self, table):
        """No SLO class anywhere => no budget math, scale pinned at 1."""
        sys = build_system(table, n_cams=1, wire_budget=1.0)  # absurdly tight
        sess, sub = open_sub(sys, "cam0")
        assert sub_scale(sys, sub) == 1.0
        assert sub.poll(max_frames=2)
        assert not any(e.kind is EventKind.TENANT_DEGRADED
                       for e in sub.events())
        sess.close()

    def test_exactly_feasible_budget_admits_full_rate(self, table):
        d, f = slo_loads(table)
        sys = build_system(table, n_cams=1, wire_budget=d)
        sess = MezClient(sys).open_session("t", tenant="t", slo="gold")
        sub = sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        assert sub_scale(sys, sub) == 1.0
        assert not any(e.kind is EventKind.TENANT_DEGRADED
                       for e in sub.events())
        assert sub.poll(max_frames=2)
        sess.close()

    def test_gold_preempts_best_effort(self, table):
        d, f = slo_loads(table)
        assert f < d                       # a lane must have degradation room
        sys = build_system(table, n_cams=1, wire_budget=1.5 * d)
        be_sess = MezClient(sys).open_session("be", tenant="be",
                                              slo="best_effort")
        be = be_sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        assert sub_scale(sys, be) == 1.0   # alone: full rate
        g_sess = MezClient(sys).open_session("g", tenant="g", slo="gold")
        gold = g_sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        assert sub_scale(sys, gold) == 1.0          # gold untouched
        s = sub_scale(sys, be)
        assert s < 1.0                              # best_effort took the cut
        assert s * d >= f - 1e-6                    # but never below its floor
        evs = sys.edge.subscription_events(be.subscription_id)
        assert any(e.kind is EventKind.TENANT_DEGRADED for e in evs)
        g_sess.close()
        be_sess.close()

    def test_leave_restores_degraded_lanes(self, table):
        d, f = slo_loads(table)
        sys = build_system(table, n_cams=1, wire_budget=1.5 * d)
        be_sess = MezClient(sys).open_session("be", tenant="be",
                                              slo="best_effort")
        be = be_sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        g_sess = MezClient(sys).open_session("g", tenant="g", slo="gold")
        g_sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        assert sub_scale(sys, be) < 1.0
        g_sess.close()                     # tenant leaves, budget frees
        assert sub_scale(sys, be) == 1.0
        # restores are silent: no second TENANT_DEGRADED
        evs = sys.edge.subscription_events(be.subscription_id)
        assert sum(1 for e in evs
                   if e.kind is EventKind.TENANT_DEGRADED) == 1
        be_sess.close()

    def test_reject_vs_degrade_policy(self, table):
        d, f = slo_loads(table)
        budget = 1.5 * f                   # one floored lane fits, two don't
        sys = build_system(table, n_cams=1, wire_budget=budget)
        s1 = MezClient(sys).open_session("a", tenant="a", slo="gold")
        s1.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        s2 = MezClient(sys).open_session("b", tenant="b", slo="gold")
        with pytest.raises(AdmissionRejected) as ei:
            s2.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9),
                         options=SubscriptionOptions(admission="reject"))
        assert ei.value.budget_bps == budget
        assert any(e.kind is EventKind.ADMISSION_REJECTED
                   for e in s2.events())
        # same join under "degrade": admitted, flagged oversubscribed
        sub2 = s2.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9),
                            options=SubscriptionOptions(admission="degrade"))
        evs = sys.edge.subscription_events(sub2.subscription_id)
        assert any(e.kind is EventKind.TENANT_DEGRADED for e in evs)
        s2.close()
        s1.close()

    def test_simultaneous_joins_race_one_budget(self, table):
        """Two joins racing a budget that fits only one: the admission lock
        serializes them, so exactly one is admitted and one rejected --
        never both admitted against the same budget."""
        d, f = slo_loads(table)
        sys = build_system(table, n_cams=1, wire_budget=1.5 * f)
        results = []

        def join(name):
            sess = MezClient(sys).open_session(name, tenant=name,
                                               slo="silver")
            try:
                sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9),
                               options=SubscriptionOptions(
                                   admission="reject"))
                results.append("ok")
            except AdmissionRejected:
                results.append("rejected")

        threads = [threading.Thread(target=join, args=(f"t{i}",))
                   for i in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert sorted(results) == ["ok", "rejected"]

    def test_unknown_slo_and_policy_rejected(self, table):
        sys = build_system(table, n_cams=1)
        with pytest.raises(ValueError):
            MezClient(sys).open_session("x", slo="platinum")
        sess = MezClient(sys).open_session("x", tenant="x", slo="gold")
        with pytest.raises(ValueError):
            sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9),
                           options=SubscriptionOptions(admission="maybe"))
        sess.close()

    def test_multi_round_join_leave_restores_gold_first(self, table):
        """Scripted multi-round join/leave: every leave must land the
        fleet on exactly the allocation the remaining join-set produced on
        the way in -- gold lanes return to full rate first while the
        best_effort lane holds its earlier cut (reverse-degradation
        restore order)."""
        d, f = slo_loads(table)
        sys = build_system(table, n_cams=2, wire_budget=2.4 * d)
        client = MezClient(sys)

        def snap():
            return {sid: info["scale"] for sid, info in
                    sys.edge.wire_report()["subscriptions"].items()}

        be_sess = client.open_session("be", tenant="be", slo="best_effort")
        be = be_sess.subscribe("cam1", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        g1_sess = client.open_session("g1", tenant="g1", slo="gold")
        g1 = g1_sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        round1 = snap()
        assert set(round1.values()) == {1.0}   # both fit whole
        g2_sess = client.open_session("g2", tenant="g2", slo="gold")
        g2 = g2_sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        round2 = snap()
        assert round2[g1.subscription_id] == 1.0
        assert round2[g2.subscription_id] == 1.0
        assert round2[be.subscription_id] < 1.0    # BE absorbed the join
        g3_sess = client.open_session("g3", tenant="g3", slo="gold")
        g3 = g3_sess.subscribe("cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        round3 = snap()
        # deeper round: BE cut further, the NEWEST golds absorb the rest,
        # the oldest gold is the last one standing whole
        assert round3[be.subscription_id] < round2[be.subscription_id]
        assert round3[g3.subscription_id] < 1.0
        assert round3[g1.subscription_id] == 1.0
        g3_sess.close()
        # gold back whole FIRST; BE still holds its round-2 cut
        assert snap() == round2
        g2_sess.close()
        assert snap() == round1
        g1_sess.close()
        be_sess.close()

    def test_leave_with_crashed_lane_keeps_restore_order(self, table):
        """A best_effort lane whose camera is down at leave-time offers
        zero demand, but restoring it to full rate then would leapfrog the
        reverse-degradation order -- it must hold its degraded scale, and
        the reattach-triggered reallocation must keep it at or below every
        still-degraded gold lane."""
        d, f = slo_loads(table)
        sys = build_system(table, n_cams=2, wire_budget=1.6 * d)
        client = MezClient(sys)
        be_sess = client.open_session("be", tenant="be", slo="best_effort")
        be = be_sess.subscribe("cam1", 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        golds = []
        for name in ("g1", "g2", "g3"):
            sess = client.open_session(name, tenant=name, slo="gold")
            golds.append((sess, sess.subscribe(
                "cam0", 0.0, 100.0, qos=QosBounds(0.1, 0.9))))
        be_degraded = sub_scale(sys, be)
        assert be_degraded < 1.0
        sys.cams["cam1"].crash()
        golds[2][0].close()                    # newest gold leaves
        # the dark BE lane holds instead of jumping to 1.0
        assert sub_scale(sys, be) == be_degraded
        assert sub_scale(sys, golds[0][1]) == 1.0   # oldest gold whole again
        sys.cams["cam1"].recover()
        sys.edge.reattach_camera(be.subscription_id, "cam1")
        be_scale = sub_scale(sys, be)
        g2_scale = sub_scale(sys, golds[1][1])
        assert be_scale < 1.0                  # reallocated, still cut
        assert be_scale <= g2_scale            # never outruns a cut gold
        for sess, _ in golds[:2]:
            sess.close()
        be_sess.close()


class TestSharedFrameCache:
    def test_n_tenants_one_transform(self, table):
        """N tenants at the same operating point pay ~1 transform+deflate
        per (frame, setting): the edge-shared cache serves repeats."""
        n_tenants, frames = 4, 8
        sys = build_system(table, n_cams=1, frames=frames)
        sessions, subs = [], []
        for i in range(n_tenants):
            sess = MezClient(sys).open_session(f"t{i}", tenant=f"t{i}",
                                               slo="silver")
            subs.append(sess.subscribe("cam0", 0.0, 100.0,
                                       qos=QosBounds(0.1, 0.9)))
            sessions.append(sess)
        total = 0
        live = True
        while live:                        # lockstep round-robin drain
            live = False
            for sub in subs:
                batch = sub.poll(max_frames=2)
                total += len(batch)
                live = live or bool(batch)
        cache = sys.edge.frame_cache
        assert total == n_tenants * frames
        assert cache.hits > 0
        # strictly fewer transforms than delivered frames: sharing happened
        assert cache.misses < total
        assert cache.hit_rate() > 0.5
        for sess in sessions:
            sess.close()

    def test_recharacterize_invalidates_only_that_camera(self, table):
        sys = build_system(table, n_cams=2, frames=4)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        while sub.poll(max_frames=4):
            pass
        cache = sys.edge.frame_cache
        n = len(cache)
        assert n > 0
        keys0 = sum(1 for k in cache._entries if k[0] == "cam0")
        sys.cams["cam0"].recharacterize()
        assert len(cache) == n - keys0
        assert all(k[0] != "cam0" for k in cache._entries)
        sess.close()

    def test_table_swap_drops_stale_cached_payloads(self, table):
        """A hot table swap (staleness injection / set_target both route
        through ``_install_jax_tables``) must invalidate the camera's
        shared-cache entries: a post-swap hit has to be byte-identical to
        a freshly computed transform, never a pre-swap payload."""
        sys = build_system(table, n_cams=2, frames=4)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        while sub.poll(max_frames=4):
            pass
        cache = sys.edge.frame_cache
        cam = sys.cams["cam0"]
        ts, frame = cam.log.tail(1)[0]
        tbl = cam.controller.table
        setting = next(tbl.setting_for(i) for i in range(len(tbl.settings))
                       if tbl.setting_for(i).artifact == 0)
        entry = cam._transform_cached(ts, frame, setting)
        np.testing.assert_array_equal(entry[0],
                                      K.transform_frame(frame, setting))
        # poison the cached payload in place: it now stands for a
        # transform calibrated under the superseded table
        entry[0] = np.zeros_like(entry[0])
        assert cam._transform_cached(ts, frame, setting)[0] is entry[0]
        assert cam.inject_table_staleness()
        post = cam._transform_cached(ts, frame, setting)[0]
        assert post is not entry[0]
        np.testing.assert_array_equal(post,
                                      K.transform_frame(frame, setting))
        # the swap only touched cam0: the neighbour's entries survived
        assert any(k[0] == "cam1" for k in cache._entries)
        sess.close()


class TestDeprecatedSurfaces:
    def test_subscribe_legacy_kwargs_warn_and_fold(self, table):
        sys = build_system(table, n_cams=1, frames=6)
        sess = MezClient(sys).open_session("app")
        with pytest.warns(DeprecationWarning, match="SubscriptionOptions"):
            sub = sess.subscribe("cam0", 0.0, 100.0,
                                 qos=QosBounds(0.1, 0.9),
                                 controlled=True, credit_limit=1)
        while (batch := sub.poll(max_frames=4)):
            per_cam = {}
            for d in batch.frames:
                per_cam[d.camera_id] = per_cam.get(d.camera_id, 0) + 1
            assert all(v <= 1 for v in per_cam.values())  # folded credit
        sess.close()

    def test_subscribe_legacy_latency_accuracy_warn(self, table):
        sys = build_system(table, n_cams=1, frames=4)
        sess = MezClient(sys).open_session("app")
        with pytest.warns(DeprecationWarning, match="QosBounds"):
            sub = sess.subscribe("cam0", 0.0, 100.0, latency=0.1,
                                 accuracy=0.9)
        assert sub.poll(max_frames=2)
        sess.close()

    def test_slo_session_defaults_qos_bounds(self, table):
        """No qos given: the session's SLO class supplies the bounds."""
        sys = build_system(table, n_cams=1, frames=4)
        sess = MezClient(sys).open_session("app", tenant="t", slo="gold")
        sub = sess.subscribe("cam0", 0.0, 100.0)
        assert sub.poll(max_frames=2)
        ctl = sys.cams["cam0"].controller
        assert ctl.config.latency_target == pytest.approx(0.050)
        sess.close()

    def test_subscribe_without_qos_or_slo_raises(self, table):
        sys = build_system(table, n_cams=1)
        sess = MezClient(sys).open_session("app")
        with pytest.raises(ValueError):
            sess.subscribe("cam0", 0.0, 100.0)
        sess.close()

    def test_v1_iterator_warns_and_compat_module_does_not(self, table):
        from repro import compat
        sys = build_system(table, n_cams=1, frames=4)
        spec = SubscribeSpec("app", "cam0", 0.0, 100.0, 0.1, 0.9)
        with pytest.warns(DeprecationWarning, match="v1 iterator"):
            old = [d.timestamp for d in sys.edge.subscribe(spec)]
        sys2 = build_system(table, n_cams=1, frames=4)
        import warnings as _w
        with _w.catch_warnings():
            _w.simplefilter("error", DeprecationWarning)
            new = [d.timestamp for d in compat.subscribe_v1(sys2, spec)]
        assert old == new


class TestTenantFleetParity:
    def test_fleet_host_parity_across_joins_and_leaves(self, table):
        """A degradation cycle (tenant joins, victim's budget_scale drops,
        tenant leaves, scale restores) produces identical frame streams on
        the host PI path and the fused fleet path, and the fleet never
        retraces (cache_size stays 1)."""
        d, f = slo_loads(table, n_cams=2)

        def run(fleet):
            sys = build_system(table, n_cams=2, frames=12,
                               wire_budget=3.0 * d)
            sess = MezClient(sys).open_session("be", tenant="be",
                                               slo="best_effort")
            sub = sess.subscribe(["cam0", "cam1"], 0.0, 100.0,
                                 qos=QosBounds(0.1, 0.9),
                                 options=SubscriptionOptions(fleet=fleet))
            keys = []

            def drain(n):
                for _ in range(n):
                    for dfr in sub.poll(max_frames=2).frames:
                        keys.append((dfr.camera_id, dfr.timestamp,
                                     dfr.wire_bytes, dfr.knob_index))

            drain(2)                       # settle at full rate
            g = MezClient(sys).open_session("g", tenant="g", slo="gold")
            g.subscribe(["cam0", "cam1"], 0.0, 100.0,
                        qos=QosBounds(0.1, 0.9))
            scale = sub_scale(sys, sub)
            drain(2)                       # degraded stretch
            g.close()                      # tenant leaves, scale restores
            drain(2)
            fc = sys.edge.subscription_fleet(sub.subscription_id)
            cache = fc.cache_size() if fc is not None else None
            sess.close()
            return keys, scale, cache

        host_keys, host_scale, _ = run(fleet=False)
        fleet_keys, fleet_scale, cache = run(fleet=True)
        assert host_scale < 1.0            # the cycle really degraded
        assert host_scale == fleet_scale   # f32-quantized identically
        assert host_keys == fleet_keys
        assert cache == 1                  # scale writes never retraced


class TestCreditLedger:
    """Fetch-credit conservation: granted - returned - in_flight - dropped
    must stay 0, and a camera crash mid-poll must not leak the credits its
    in-flight fetch held (they return at ``reattach_camera``)."""

    def test_clean_stream_conserves_credits(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        while sub.poll(max_frames=8):
            pass
        sess.close()
        rep = sys.edge.credit_report()
        assert rep["granted"] > 0
        assert rep["leaked"] == 0
        assert rep["in_flight"] == 0
        assert rep["dropped"] == 0

    def test_crash_mid_poll_credits_return_on_reattach(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        assert sub.poll(max_frames=4)
        # scripted crash mid-poll: the next poll's fetch grants cam0 its
        # credit window, then the RPC dies -- the crashed node can never
        # hand the credits back itself
        sys.cams["cam0"].crash()
        sub.poll(max_frames=4)
        rep = sys.edge.credit_report()
        assert rep["in_flight"] > 0        # held by the dead camera
        assert rep["leaked"] == 0          # ... but accounted, not lost
        sys.cams["cam0"].recover()
        assert sys.edge.reattach_camera(sub.subscription_id,
                                        "cam0") is Status.OK
        rep = sys.edge.credit_report()
        assert rep["in_flight"] == 0       # returned at reattach
        assert rep["dropped"] == 0
        assert rep["leaked"] == 0
        # the stream resumes where it stopped and still conserves
        while sub.poll(max_frames=8):
            pass
        sess.close()
        rep = sys.edge.credit_report()
        assert (rep["leaked"], rep["in_flight"], rep["dropped"]) == (0, 0, 0)

    def test_repeated_crash_recover_cycles_do_not_accumulate(self, table):
        sys = build_system(table, n_cams=2, frames=12)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        for _ in range(3):
            sub.poll(max_frames=4)
            sys.cams["cam0"].crash()
            sub.poll(max_frames=4)         # strands cam0's window
            sys.cams["cam0"].recover()
            assert sys.edge.reattach_camera(sub.subscription_id,
                                            "cam0") is Status.OK
        rep = sys.edge.credit_report()
        assert rep["in_flight"] == 0 and rep["leaked"] == 0
        sess.close()

    def test_unsubscribe_while_crashed_writes_credits_off(self, table):
        """Detaching a crashed camera can never reattach it: its held
        credits are written off as dropped, not leaked."""
        sys = build_system(table, n_cams=2, frames=10)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        sub.poll(max_frames=4)
        sys.cams["cam0"].crash()
        sub.poll(max_frames=4)
        assert sys.edge.credit_report()["in_flight"] > 0
        assert sys.edge.unsubscribe("app", "cam0") is Status.OK
        rep = sys.edge.credit_report()
        assert rep["in_flight"] == 0
        assert rep["dropped"] > 0
        assert rep["leaked"] == 0
        sess.close()


class TestBoundedEventBuffer:
    """Session/subscription event buffers are bounded (HostLog's evict-
    before-overwrite contract): overflow evicts the oldest events, counts
    them, and surfaces one EVENTS_DROPPED marker on the next drain."""

    def test_overflow_surfaces_dropped_marker(self, table):
        sys = build_system(table, n_cams=1, frames=10)
        sess, sub = open_sub(sys, ["cam0"])
        rec = sys.edge._subscriptions[sub.subscription_id]
        rec.events.capacity = 4
        for i in range(10):
            rec.events.append(SessionEvent(
                EventKind.RPC_TIMEOUT, "cam0", sub.subscription_id,
                float(i), "synthetic overflow"))
        evs = sub.events()
        assert evs[0].kind is EventKind.EVENTS_DROPPED
        assert "6 events" in evs[0].detail
        assert len(evs) == 5               # marker + the 4 retained
        assert [e.timestamp for e in evs[1:]] == [6.0, 7.0, 8.0, 9.0]
        assert rec.events.dropped == 6
        # the marker is one-shot: a drained buffer doesn't re-emit it
        assert sub.events() == []
        sess.close()

    def test_no_marker_without_overflow(self, table):
        sys = build_system(table, n_cams=1, frames=10)
        sess, sub = open_sub(sys, ["cam0"])
        rec = sys.edge._subscriptions[sub.subscription_id]
        for i in range(3):
            rec.events.append(SessionEvent(
                EventKind.RPC_TIMEOUT, "cam0", sub.subscription_id,
                float(i), "under capacity"))
        evs = sub.events()
        assert len(evs) == 3
        assert all(e.kind is not EventKind.EVENTS_DROPPED for e in evs)
        sess.close()
