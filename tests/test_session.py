"""v2 session API: multi-camera fan-in, FrameBatch invariants, live QoS
renegotiation, events, lifecycle, and compat-shim equivalence."""

import numpy as np
import pytest

from repro.core.api import (EventKind, FrameBatch, RPCTimeout, Status,
                            SubscribeSpec, SubscriptionState)
from repro.core.broker import MezSystem
from repro.core.channel import calibrated_channel
from repro.core.characterization import characterize, fit_latency_regression
from repro.core import detector as det
from repro.core.session import MezClient
from repro.data.camera import CameraConfig, SyntheticCamera
from repro.data.pipeline import CameraBatcher


@pytest.fixture(scope="module")
def table():
    return characterize(
        lambda: SyntheticCamera(CameraConfig(dynamics="medium", seed=7)),
        clip_len=10)


def build_system(table, *, n_cams=2, frames=10, workload=None, seed=3):
    ch = calibrated_channel(seed=seed, workload=workload)
    sys = MezSystem(ch)
    sizes = np.linspace(table.sizes_sorted[0], table.sizes_sorted[-1], 12)
    reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=n_cams))
    for i in range(n_cams):
        cam = sys.add_camera(f"cam{i}")
        src = SyntheticCamera(CameraConfig(camera_id=f"cam{i}",
                                           dynamics="medium", seed=7))
        cam.background = src.background
        cam.set_target(0.100, 0.90, table, reg)
        for ts, f, gt in src.stream(frames):
            cam.publish(ts, f)
    return sys


def open_sub(sys, cameras, *, latency=0.1, accuracy=0.9, t_stop=100.0):
    sess = MezClient(sys).open_session("app")
    return sess, sess.subscribe(cameras, 0.0, t_stop,
                                latency=latency, accuracy=accuracy)


class TestFanIn:
    def test_multi_camera_chronological_merge(self, table):
        sys = build_system(table, n_cams=3, frames=10)
        sess, sub = open_sub(sys, ["cam0", "cam1", "cam2"])
        total, seen = 0, {f"cam{i}": [] for i in range(3)}
        while (batch := sub.poll(max_frames=9)):
            ts = batch.timestamps
            # merged batch is sorted, ties broken by camera id
            assert all((a.timestamp, a.camera_id) <= (b.timestamp, b.camera_id)
                       for a, b in zip(batch.frames, batch.frames[1:]))
            for d in batch.frames:
                seen[d.camera_id].append(d.timestamp)
            total += len(batch)
        assert total == 30
        # per-camera order is preserved end to end (at-most-once, no dupes)
        for cid, stamps in seen.items():
            assert stamps == sorted(stamps)
            assert len(stamps) == len(set(stamps)) == 10
        assert sub.state is SubscriptionState.DRAINED
        sess.close()

    def test_max_frames_respected(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        while (batch := sub.poll(max_frames=3)):
            assert len(batch) <= 3
        sess.close()

    def test_credit_backpressure_bounds_per_camera(self, table):
        """One poll never pulls more than credit_limit frames per camera."""
        sys = build_system(table, n_cams=2, frames=10)
        sess = MezClient(sys).open_session("app")
        sub = sess.subscribe(["cam0", "cam1"], 0.0, 100.0, latency=0.1,
                             accuracy=0.9, credit_limit=2)
        while (batch := sub.poll(max_frames=16)):
            per_cam = {}
            for d in batch.frames:
                per_cam[d.camera_id] = per_cam.get(d.camera_id, 0) + 1
            assert all(v <= 2 for v in per_cam.values())
        sess.close()


class TestFrameBatch:
    def test_stack_shape_and_valid_mask(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        batch = sub.poll(max_frames=4)
        payload, valid = batch.stack()
        assert payload.dtype == np.float32
        assert payload.ndim == 4
        assert payload.shape[0] == len(batch.delivered) == int(valid.sum())
        sess.close()

    def test_stack_fixed_batch_size_pads_with_zeros(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        batch = sub.poll(max_frames=4)
        n = len(batch.delivered)
        payload, valid = batch.stack(batch_size=8)
        assert payload.shape[0] == 8
        assert valid.tolist() == [True] * n + [False] * (8 - n)
        assert not payload[n:].any()
        if n >= 1:
            with pytest.raises(ValueError):
                batch.stack(batch_size=n - 1)
        sess.close()

    def test_stack_empty(self):
        payload, valid = FrameBatch(()).stack(batch_size=4)
        assert payload.shape[0] == 4 and not valid.any()
        assert not FrameBatch(())


class TestQosRenegotiation:
    def test_update_qos_retargets_in_place(self, table):
        sys = build_system(table, n_cams=5, frames=20, workload="dukemtmc")
        sess, sub = open_sub(sys, "cam0")
        for _ in range(3):
            sub.poll(max_frames=2)
        ctl = sys.cams["cam0"].controller
        ctl_id = id(ctl)
        q = sub.update_qos(latency=0.030)
        assert q.status is Status.OK and q.applied_cameras == ("cam0",)
        # same controller object: retarget happened IN PLACE, no teardown
        assert id(sys.cams["cam0"].controller) == ctl_id
        assert ctl.config.latency_target == 0.030
        assert ctl.config.accuracy_target == 0.90   # unchanged axis preserved
        assert sub.state is SubscriptionState.ACTIVE
        sess.close()

    def test_update_qos_effective_within_one_interval(self, table):
        """Retarget re-seeds the operating point: the setting moves toward
        the new target's nominal size immediately, not after N samples."""
        sys = build_system(table, n_cams=5, frames=20, workload="dukemtmc")
        sess, sub = open_sub(sys, "cam0", latency=0.030)
        for _ in range(4):
            sub.poll(max_frames=2)
        ctl = sys.cams["cam0"].controller
        size_tight = table.size_by_setting[ctl._current]
        sub.update_qos(latency=1.0)          # relax drastically
        size_relaxed = table.size_by_setting[ctl._current]
        assert size_relaxed >= size_tight    # reseeded before any feedback
        batch = sub.poll(max_frames=2)       # next interval ships bigger frames
        assert batch
        assert all(d.wire_bytes >= size_tight * 0.5 for d in batch.delivered)
        sess.close()

    def test_update_qos_recharacterize_hot_swaps_tables(self, table):
        """``update_qos(recharacterize=True)`` re-sweeps the camera's knob
        tables from its own recent frames and hot-swaps them into the live
        controller (host + padded jit twin) before applying the bounds."""
        sys = build_system(table, n_cams=1, frames=20)
        sess, sub = open_sub(sys, "cam0")
        for _ in range(3):
            sub.poll(max_frames=4)
        cam = sys.cams["cam0"]
        v0 = cam.table_version
        q = sub.update_qos(latency=0.080, recharacterize=True)
        assert q.status is Status.OK
        assert q.recharacterized == ("cam0",)
        assert cam.table_version == v0 + 1
        assert cam.controller.table is not table   # fresh live-clip table
        assert cam.controller.table.proxy is not None
        assert cam.controller.config.latency_target == 0.080
        assert int(cam.jax_tables.n_valid) == len(cam.controller.table.settings)
        assert sub.poll(max_frames=4)              # stream survives the swap
        sess.close()

    def test_session_update_qos_fans_out(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sess = MezClient(sys).open_session("app")
        sub0 = sess.subscribe("cam0", 0.0, 100.0, latency=0.1, accuracy=0.9)
        sub1 = sess.subscribe("cam1", 0.0, 100.0, latency=0.1, accuracy=0.9)
        updates = sess.update_qos(latency=0.050)
        assert len(updates) == 2
        assert {u.subscription_id for u in updates} == {
            sub0.subscription_id, sub1.subscription_id}
        assert all(u.status is Status.OK for u in updates)
        assert sys.cams["cam0"].controller.config.latency_target == 0.050
        sess.close()

    def test_update_qos_on_closed_subscription_fails(self, table):
        sys = build_system(table)
        sess, sub = open_sub(sys, "cam0")
        sub.close()
        assert sub.update_qos(latency=0.2).status is Status.FAIL
        sess.close()


class TestEventsAndFailures:
    def test_infeasible_surfaces_as_event(self, table):
        sys = build_system(table, n_cams=5, frames=12, workload="dukemtmc")
        sess, sub = open_sub(sys, "cam0", latency=0.001, accuracy=0.999)
        while sub.poll(max_frames=2):
            pass
        kinds = {e.kind for e in sub.events()}
        assert EventKind.INFEASIBLE in kinds
        sess.close()

    def test_partial_camera_failure_keeps_streaming(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sys.cams["cam0"].crash()
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        got = []
        while (batch := sub.poll(max_frames=4)):
            got.extend(batch.frames)
        assert len(got) == 10                      # cam1's stream survives
        assert {d.camera_id for d in got} == {"cam1"}
        evs = sub.events()
        assert any(e.kind is EventKind.RPC_TIMEOUT and e.camera_id == "cam0"
                   for e in evs)
        assert sub.state is SubscriptionState.FAILED
        sess.close()

    def test_all_cameras_failed_raises(self, table):
        sys = build_system(table)
        sys.cams["cam0"].crash()
        sess, sub = open_sub(sys, "cam0")
        with pytest.raises(RPCTimeout):
            sub.poll()
        sess.close()

    def test_session_events_aggregates_subscriptions(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sys.cams["cam0"].crash()
        sess = MezClient(sys).open_session("app")
        sub0 = sess.subscribe("cam0", 0.0, 100.0, latency=0.1, accuracy=0.9)
        sub1 = sess.subscribe("cam1", 0.0, 100.0, latency=0.1, accuracy=0.9)
        with pytest.raises(RPCTimeout):
            sub0.poll()
        while sub1.poll(max_frames=4):
            pass
        evs = sess.events()
        assert any(e.subscription_id == sub0.subscription_id for e in evs)
        assert sess.close() is Status.OK


class TestRoundRobinFairness:
    """Regression for the cached round-robin active order: the cache must
    be invalidated on crash/detach/drain/reattach so rotation stays fair
    across membership changes (satellite of the fused-tick PR)."""

    def test_rotation_fair_across_crash_and_reattach(self, table):
        cams = [f"cam{i}" for i in range(4)]
        sys = build_system(table, n_cams=4, frames=40)
        sess, sub = open_sub(sys, cams)

        def window(n):
            """n consecutive max_frames=1 polls -> the head camera of each
            rotation (every camera always has frames pending)."""
            ids = []
            for _ in range(n):
                batch = sub.poll(max_frames=1)
                assert len(batch) == 1
                ids.append(batch.frames[0].camera_id)
            return ids

        # 4 live cameras: every window of 4 polls visits each exactly once
        for _ in range(2):
            assert sorted(window(4)) == cams

        # crash one mid-stream; rotation discovers it (no cam1 frames) and
        # the cached order is rebuilt over the 3 survivors
        sys.cams["cam1"].crash()
        assert "cam1" not in window(4)
        survivors = ["cam0", "cam2", "cam3"]
        for _ in range(2):
            assert sorted(window(3)) == survivors
        assert any(e.kind is EventKind.RPC_TIMEOUT and e.camera_id == "cam1"
                   for e in sub.events())

        # recover + reattach: cache invalidates again, rotation is fair
        # over all 4 and the late camera resumes from its old cursor
        sys.cams["cam1"].recover()
        assert sys.edge.reattach_camera(sub.subscription_id,
                                        "cam1") is Status.OK
        for _ in range(2):
            assert sorted(window(4)) == cams
        sess.close()


class TestLifecycle:
    def test_close_is_idempotent(self, table):
        sys = build_system(table)
        sess, sub = open_sub(sys, "cam0")
        assert sub.close() is Status.OK
        assert sub.close() is Status.OK            # second close: still OK
        assert sub.state is SubscriptionState.CLOSED
        assert not sub.poll()                      # closed => empty batch
        assert sess.close() is Status.OK
        assert sess.close() is Status.OK

    def test_context_managers_close(self, table):
        sys = build_system(table)
        with MezClient(sys).open_session("app") as sess:
            with sess.subscribe("cam0", 0.0, 100.0, latency=0.1,
                                accuracy=0.9) as sub:
                assert sub.poll(max_frames=2)
            assert sub.state is SubscriptionState.CLOSED
        assert sess.closed

    def test_unknown_camera_rejected_at_create(self, table):
        sys = build_system(table)
        sess = MezClient(sys).open_session("app")
        with pytest.raises(RPCTimeout):
            sess.subscribe("ghost", 0.0, 1.0, latency=0.1, accuracy=0.9)
        sess.close()


class TestCompatShim:
    def test_v1_iterator_matches_v2_poll(self, table):
        """The old blocking iterator and the session API produce identical
        frame sequences (timestamps, wire bytes, knobs, latencies)."""
        key = lambda d: (d.timestamp, d.wire_bytes, d.knob_index,
                         round(d.latency.total, 12))
        sys_old = build_system(table, n_cams=5, frames=12, workload="jaad")
        old = [key(d) for d in sys_old.edge.subscribe(
            SubscribeSpec("app", "cam0", 0.0, 100.0, 0.1, 0.9))]
        sys_new = build_system(table, n_cams=5, frames=12, workload="jaad")
        sess, sub = open_sub(sys_new, "cam0")
        new = []
        while (batch := sub.poll(max_frames=2)):   # = shim's fetch_window
            new.extend(key(d) for d in batch.frames)
        assert old == new
        sess.close()

    def test_v1_unsubscribe_stops_v2_backed_stream(self, table):
        sys = build_system(table)
        it = sys.edge.subscribe(SubscribeSpec("app", "cam0", 0.0, 100.0,
                                              0.1, 0.9))
        next(it)
        assert sys.edge.unsubscribe("app", "cam0") is Status.OK
        assert len(list(it)) <= 1                  # current fetch drains only


class TestBatchConsumers:
    def test_camera_batcher_consumes_frame_batches(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        batcher = CameraBatcher(batch=4)
        model_batches, delivered = [], 0
        while (batch := sub.poll(max_frames=8)):
            delivered += len(batch.delivered)
            model_batches.extend(batcher.push_batch(batch))
        assert len(model_batches) == delivered // 4
        assert all(b.shape[0] == 4 for b in model_batches)
        sess.close()

    def test_detect_batch_runs_per_camera_background(self, table):
        sys = build_system(table, n_cams=2, frames=10)
        bgs = {f"cam{i}": SyntheticCamera(
            CameraConfig(camera_id=f"cam{i}", dynamics="medium",
                         seed=7)).background for i in range(2)}
        sess, sub = open_sub(sys, ["cam0", "cam1"])
        batch = sub.poll(max_frames=6)
        pairs = det.detect_batch(batch, lambda d: bgs[d.camera_id])
        assert len(pairs) == len(batch.delivered)
        for d, boxes in pairs:
            assert boxes.ndim == 2 and boxes.shape[1] == 4
        sess.close()
