"""Forced 8-device mesh parity (satellite 3's second half).

``XLA_FLAGS=--xla_force_host_platform_device_count=8`` must be set before
jax initializes, so the sharded half of the parity matrix runs in a child
interpreter: the child builds a 13-camera fleet on an 8-device ``cams``
mesh (lanes padded 13 -> 16), drives it through subset table swaps and
retargets against shadow host controllers, then replays the SceneShift +
InterferenceSpike scenario fused-vs-unfused -- asserting bit-identical
traces and a single placement-stable compiled dispatch throughout.
"""

import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

CHILD = r"""
import numpy as np

import jax

assert jax.device_count() == 8, jax.devices()

from benchmarks.common import synthetic_controller_table as synthetic_table
from repro.core.characterization import LatencyRegression
from repro.core.controller import (ControllerConfig, FleetController,
                                   LatencyController)
from repro.core.scenario import (CameraSpec, InterferenceSpike, SceneShift,
                                 ScenarioSpec, run_scenario)
from repro.sharding.partition import fleet_mesh, padded_lane_count

# -- manual parity: 13 cams on 8 devices (padded to 16 lanes) ---------------
mesh = fleet_mesh(8)
assert padded_lane_count(13, mesh) == 16

rng = np.random.default_rng(0)
reg = LatencyRegression(slope=1.2e-6, intercept=0.008)
cams, hosts = [], []


class _Cam:
    def __init__(self, cid, ctrl):
        self.camera_id, self.controller = cid, ctrl
        self.table_version = self.qos_version = 0


for i in range(13):
    tbl = synthetic_table(12 + i % 29, smin=2e3 + 37.0 * i,
                          smax=9e4 - 101.0 * i)
    cfg = ControllerConfig(latency_target=0.040 + 0.001 * (i % 17),
                           accuracy_target=0.90 + 0.002 * (i % 4))
    cams.append(_Cam(f"cam{i:03d}", LatencyController(cfg, tbl, reg)))
    hosts.append(LatencyController(cfg, tbl, reg))

fleet = FleetController(cams, capacity=128, mesh=mesh)
assert fleet._n_padded == 16

for step in range(36):
    if step == 10:
        for i in (2, 7, 12):
            fresh = synthetic_table(20 + i, smin=3e3 + 11.0 * i, smax=7e4)
            cams[i].controller.swap_table(fresh)
            cams[i].table_version += 1
            hosts[i].swap_table(fresh)
    if step == 22:
        for i in (0, 5):
            cams[i].controller.set_target(0.075, 0.91)
            cams[i].qos_version += 1
            hosts[i].set_target(0.075, 0.91)
    fb = {c.camera_id: float(rng.uniform(0.005, 0.5)) for c in cams}
    decisions = fleet.decide(fb)
    for i, cam in enumerate(cams):
        dh = hosts[i].update(fb[cam.camera_id])
        df = decisions[cam.camera_id]
        assert df.setting_index == dh.setting_index, (step, i)
        assert df.acted == dh.acted, (step, i)
        assert df.feasible == dh.feasible, (step, i)
assert fleet.cache_size() == 1, fleet.cache_size()

# -- scenario parity: fused 8-device replay == host trace -------------------


def spec(**kw):
    base = dict(
        name="fleet-sharded-parity",
        cameras=tuple(CameraSpec(f"cam{i}", dynamics="medium")
                      for i in range(3)),
        frames=30, seed=9, workload="jaad",
        latency=0.100, accuracy=0.92,
        events=(InterferenceSpike(start=2.0, end=4.0, factor=7.0),),
    )
    base.update(kw)
    return ScenarioSpec(**base)


tables = {"medium": synthetic_table()}
meshed = run_scenario(spec(fleet=True, mesh=mesh), tables=tables)
host = run_scenario(spec(fleet=False), tables=tables)
assert meshed.to_json() == host.to_json()
assert meshed.fleet_cache_size == 1, meshed.fleet_cache_size

print("PARITY_OK")
"""


def test_eight_device_mesh_parity_in_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.pathsep.join(
        [str(ROOT / "src"), str(ROOT)]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run([sys.executable, "-c", CHILD], cwd=ROOT, env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "PARITY_OK" in proc.stdout
