"""Pub-sub brokers: API semantics, on-demand transfer, fault tolerance."""

import numpy as np
import pytest

from repro.core.api import RPCTimeout, Status, SubscribeSpec
from repro.core.broker import MezSystem, NatsLikeSystem, SharedFrameCache
from repro.core.channel import calibrated_channel
from repro.core.characterization import characterize, fit_latency_regression
from repro.core.log import LogSegmentStore
from repro.data.camera import CameraConfig, SyntheticCamera


@pytest.fixture(scope="module")
def table():
    return characterize(
        lambda: SyntheticCamera(CameraConfig(dynamics="medium", seed=7)),
        clip_len=10)


def build_system(table, *, n_cams=2, frames=10, workload=None, store=None,
                 seed=3):
    ch = calibrated_channel(seed=seed, workload=workload)
    sys = MezSystem(ch, store=store)
    sizes = np.linspace(table.sizes_sorted[0], table.sizes_sorted[-1], 12)
    reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=n_cams))
    for i in range(n_cams):
        cam = sys.add_camera(f"cam{i}")
        src = SyntheticCamera(CameraConfig(camera_id=f"cam{i}",
                                           dynamics="medium", seed=7))
        cam.background = src.background
        cam.set_target(0.100, 0.90, table, reg)
        for ts, f, gt in src.stream(frames):
            cam.publish(ts, f)
    return sys


class TestAPI:
    def test_connect_and_camera_info(self, table):
        sys = build_system(table)
        cid = sys.edge.connect("mez://edge")
        assert cid.startswith("client-")
        assert sys.edge.get_camera_info() == ["cam0", "cam1"]

    def test_subscribe_delivers_in_order(self, table):
        sys = build_system(table)
        spec = SubscribeSpec("app", "cam0", 0.0, 100.0, 0.1, 0.9)
        out = list(sys.edge.subscribe(spec))
        ts = [d.timestamp for d in out]
        assert ts == sorted(ts)
        assert len(out) == 10

    def test_subscribe_time_window(self, table):
        sys = build_system(table)
        spec = SubscribeSpec("app", "cam0", 0.4, 1.2, 0.1, 0.9)
        out = [d for d in sys.edge.subscribe(spec) if d.frame is not None]
        assert all(0.4 <= d.timestamp <= 1.2 for d in out)

    def test_unsubscribe(self, table):
        sys = build_system(table)
        spec = SubscribeSpec("app", "cam0", 0.0, 100.0, 0.1, 0.9)
        it = sys.edge.subscribe(spec)
        next(it)
        assert sys.edge.unsubscribe("app", "cam0") is Status.OK
        assert sys.edge.unsubscribe("app", "cam0") is Status.FAIL

    def test_at_most_once_replica(self, table):
        """Frames land in the edge replica log exactly once."""
        sys = build_system(table)
        spec = SubscribeSpec("app", "cam0", 0.0, 100.0, 0.1, 0.9)
        delivered = [d for d in sys.edge.subscribe(spec) if d.frame is not None]
        replica = sys.edge.replicas["cam0"]
        assert len(replica) == len(delivered)

    def test_unknown_camera_times_out(self, table):
        sys = build_system(table)
        with pytest.raises(RPCTimeout):
            list(sys.edge.subscribe(
                SubscribeSpec("app", "nope", 0, 1, 0.1, 0.9)))

    def test_unsubscribe_idempotent(self, table):
        """Double-unsubscribe and unknown targets return a deterministic
        Status -- never a KeyError from registry dict state."""
        sys = build_system(table)
        spec = SubscribeSpec("app", "cam0", 0.0, 100.0, 0.1, 0.9)
        it = sys.edge.subscribe(spec)
        next(it)
        assert sys.edge.unsubscribe("app", "cam0") is Status.OK
        for _ in range(3):                         # arbitrarily repeatable
            assert sys.edge.unsubscribe("app", "cam0") is Status.FAIL

    def test_unsubscribe_unknown_targets_fail_cleanly(self, table):
        sys = build_system(table)
        assert sys.edge.unsubscribe("app", "ghost-cam") is Status.FAIL
        assert sys.edge.unsubscribe("ghost-app", "cam0") is Status.FAIL
        # registry still healthy: a real subscription works afterwards
        out = list(sys.edge.subscribe(
            SubscribeSpec("app", "cam0", 0.0, 100.0, 0.1, 0.9)))
        assert len(out) == 10


class TestControl:
    def test_controller_reduces_payload_under_interference(self, table):
        sys = build_system(table, n_cams=5, frames=24, workload="dukemtmc")
        spec = SubscribeSpec("app", "cam0", 0.0, 100.0, 0.100, 0.90)
        out = [d for d in sys.edge.subscribe(spec) if d.frame is not None]
        first, last = out[0], out[-1]
        # after settling the controller ships smaller frames
        assert last.wire_bytes < first.wire_bytes or \
            np.percentile([d.latency.total for d in out[8:]], 95) < 0.12

    def test_uncontrolled_is_larger(self, table):
        sys_c = build_system(table, n_cams=5, frames=12, workload="jaad")
        sys_u = build_system(table, n_cams=5, frames=12, workload="jaad")
        spec = SubscribeSpec("app", "cam0", 0.0, 100.0, 0.1, 0.9)
        ctl = [d.wire_bytes for d in sys_c.edge.subscribe(spec)
               if d.frame is not None]
        unc = [d.wire_bytes for d in sys_u.edge.subscribe(
            spec, controlled=False) if d.frame is not None]
        assert np.median(ctl) <= np.median(unc)


class TestNats:
    def test_message_limit(self):
        nats = NatsLikeSystem(calibrated_channel(workload="dukemtmc"))
        nats.add_camera("cam0")
        src = SyntheticCamera(CameraConfig(dynamics="complex", seed=7))
        ts, frame, _ = src.next_frame()
        with pytest.raises(ValueError, match="1MB"):
            nats.deliver("cam0", ts, frame)
        assert nats.rejected_oversize == 1

    def test_no_control_full_fidelity(self):
        nats = NatsLikeSystem(calibrated_channel())
        nats.add_camera("cam0")
        src = SyntheticCamera(CameraConfig(dynamics="simple", seed=7))
        ts, frame, _ = src.next_frame()
        d = nats.deliver("cam0", ts, frame)
        np.testing.assert_array_equal(d.frame, frame)
        assert d.knob_index == -1


class TestFaultTolerance:
    def test_cambroker_crash_detected_as_timeout(self, table):
        sys = build_system(table)
        sys.cams["cam0"].crash()
        with pytest.raises(RPCTimeout):
            list(sys.edge.subscribe(
                SubscribeSpec("app", "cam0", 0.0, 100.0, 0.1, 0.9)))

    def test_edge_crash_and_recover(self, table, tmp_path):
        store = LogSegmentStore(str(tmp_path))
        sys = build_system(table, store=store)
        list(sys.edge.subscribe(
            SubscribeSpec("app", "cam0", 0.0, 100.0, 0.1, 0.9)))
        n_before = len(sys.edge.replicas["cam0"])
        sys.edge.persist()
        sys.edge.crash()
        with pytest.raises(RPCTimeout):
            sys.edge.get_camera_info()
        sys.edge.recover()
        assert len(sys.edge.replicas["cam0"]) == n_before
        assert sys.edge.get_camera_info() == ["cam0", "cam1"]

    def test_cambroker_recover_from_disk(self, table, tmp_path):
        store = LogSegmentStore(str(tmp_path))
        sys = build_system(table, store=store)
        cam = sys.cams["cam0"]
        n = len(cam.log)
        cam.persist()
        cam.crash()
        cam.recover()
        assert not cam.crashed
        assert len(cam.log) == n

    def test_subscriber_retry_loop(self, table, tmp_path):
        """The paper's recovery protocol: retry until the broker answers."""
        store = LogSegmentStore(str(tmp_path))
        sys = build_system(table, store=store)
        sys.edge.persist()
        sys.edge.crash()
        attempts = 0
        for attempt in range(5):
            attempts += 1
            try:
                sys.edge.get_camera_info()
                break
            except RPCTimeout:
                if attempt == 2:
                    sys.edge.recover()      # "kubernetes" restarts it
        assert attempts == 4


class TestSharedFrameCacheLRU:
    """Eviction must be least-recently-USED, not least-recently-inserted:
    under tenant churn the oldest-inserted entry is usually the hottest
    one (every still-subscribed tenant re-reads it each poll)."""

    def test_hit_refreshes_recency(self):
        cache = SharedFrameCache(capacity=3)
        k = lambda ts: ("cam0", ts, ("t", 0))  # noqa: E731
        for ts in (0.0, 0.2, 0.4):
            cache.put(k(ts), [f"p{ts}", None])
        assert cache.get(k(0.0)) is not None   # touch the oldest-inserted
        cache.put(k(0.6), ["p0.6", None])      # over capacity: evict LRU
        assert cache.evictions == 1
        assert len(cache) == 3
        # the touched entry survived; the least-recently-used one did not
        assert cache.get(k(0.0)) is not None
        assert cache.get(k(0.2)) is None

    def test_eviction_order_without_hits_is_insertion_order(self):
        cache = SharedFrameCache(capacity=2)
        cache.put(("cam0", 0.0, "a"), ["p0", None])
        cache.put(("cam0", 0.2, "a"), ["p1", None])
        cache.put(("cam0", 0.4, "a"), ["p2", None])
        assert cache.get(("cam0", 0.0, "a")) is None
        assert cache.get(("cam0", 0.4, "a")) is not None

    def test_put_existing_key_updates_without_eviction(self):
        cache = SharedFrameCache(capacity=2)
        cache.put(("cam0", 0.0, "a"), ["p0", None])
        cache.put(("cam0", 0.2, "a"), ["p1", None])
        cache.put(("cam0", 0.0, "a"), ["p0'", None])   # refresh, no evict
        assert cache.evictions == 0
        cache.put(("cam0", 0.4, "a"), ["p2", None])    # now 0.2 is LRU
        assert cache.get(("cam0", 0.2, "a")) is None
        assert cache.get(("cam0", 0.0, "a")) == ["p0'", None]

    def test_invalidate_scopes_to_one_camera(self):
        cache = SharedFrameCache(capacity=8)
        cache.put(("cam0", 0.0, "a"), ["p0", None])
        cache.put(("cam1", 0.0, "a"), ["p1", None])
        cache.invalidate("cam0")
        assert cache.get(("cam0", 0.0, "a")) is None
        assert cache.get(("cam1", 0.0, "a")) is not None
