"""Latency controller (Algorithm 1): host + jittable implementations."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channel import calibrated_channel
from repro.core.characterization import (CharacterizationTable,
                                         LatencyRegression, characterize,
                                         fit_latency_regression)
from repro.core.controller import (ControllerConfig, JaxControllerTables,
                                   LatencyController, controller_init,
                                   controller_step)
from repro.core.knobs import KnobSetting
from repro.data.camera import CameraConfig, SyntheticCamera


def synthetic_table(n=32, *, smin=2e3, smax=90e3) -> CharacterizationTable:
    """Monotone size->accuracy table without running the detector."""
    sizes = np.linspace(smin, smax, n)
    accs = 0.90 + 0.10 * (sizes - smin) / (smax - smin)
    settings = tuple(KnobSetting(resolution=i % 5) for i in range(n))
    best_idx = np.arange(n)
    return CharacterizationTable(
        settings=settings, sizes_sorted=sizes, best_acc=accs,
        best_idx=best_idx, acc_by_setting=accs, size_by_setting=sizes)


@pytest.fixture(scope="module")
def regression():
    ch = calibrated_channel()
    sizes = np.linspace(2e3, 90e3, 16)
    return fit_latency_regression(sizes, ch.regression_points(sizes, n=5))


class TestHostController:
    def test_holds_when_in_band(self, regression):
        tbl = synthetic_table()
        c = LatencyController(ControllerConfig(0.050, 0.92), tbl, regression)
        before = c._current
        d = c.update(0.050)      # exactly on target: no action
        assert not d.acted and d.setting_index == before

    def test_shrinks_on_high_latency(self, regression):
        tbl = synthetic_table()
        c = LatencyController(ControllerConfig(0.050, 0.90), tbl, regression)
        d0 = c.update(0.050)
        d1 = c.update(0.500)     # 10x over target
        assert d1.acted
        assert d1.requested_size < d0.requested_size or not d0.acted
        assert tbl.size_by_setting[d1.setting_index] <= \
            tbl.size_by_setting[c.table.best_idx[-1]]

    def test_relaxes_on_low_latency(self, regression):
        tbl = synthetic_table()
        c = LatencyController(ControllerConfig(0.050, 0.90), tbl, regression)
        c.update(0.400)
        small = c.table.size_by_setting[c._current]
        for _ in range(6):
            d = c.update(0.005)
        assert c.table.size_by_setting[c._current] >= small

    def test_infeasible_notifies_but_degrades_gracefully(self, regression):
        tbl = synthetic_table()
        # demand more accuracy than ANY setting at the needed size offers
        c = LatencyController(ControllerConfig(0.012, 0.999), tbl, regression)
        d = c.update(0.500)
        assert not d.feasible
        assert d.setting is not None     # best-effort setting still returned

    def test_set_target_resets(self, regression):
        tbl = synthetic_table()
        c = LatencyController(ControllerConfig(0.050, 0.90), tbl, regression)
        c.update(0.5)
        c.set_target(0.100, 0.95)
        assert c.integral == 0.0
        assert c.config.latency_target == 0.100


class TestJaxController:
    def test_matches_host_decisions(self, regression):
        tbl = synthetic_table()
        cfg = ControllerConfig(0.050, 0.92)
        host = LatencyController(cfg, tbl, regression)
        jt = JaxControllerTables.from_table(tbl)
        state = controller_init(jt)
        step = jax.jit(lambda st, lat: controller_step(
            st, lat, jt, latency_target=cfg.latency_target,
            accuracy_target=cfg.accuracy_target, slope=regression.slope,
            intercept=regression.intercept, error_threshold=cfg.error_threshold,
            alpha_p=cfg.alpha_p, alpha_i=cfg.alpha_i))
        # jax controller starts at table max; align host for comparison
        samples = [0.3, 0.25, 0.12, 0.06, 0.05, 0.04, 0.04]
        for lat in samples:
            dh = host.update(lat)
            state, idx = step(state, lat)
            if dh.acted and dh.feasible:
                hs = tbl.size_by_setting[dh.setting_index]
                js = tbl.size_by_setting[int(idx)]
                # same table, same law -> same requested size region
                np.testing.assert_allclose(hs, js, rtol=0.35)

    def test_jit_traceable_no_host_sync(self, regression):
        tbl = synthetic_table()
        jt = JaxControllerTables.from_table(tbl)
        state = controller_init(jt)

        @jax.jit
        def run(state, lats):
            def body(st, lat):
                st, idx = controller_step(
                    st, lat, jt, latency_target=0.05, accuracy_target=0.9,
                    slope=regression.slope, intercept=regression.intercept)
                return st, idx
            return jax.lax.scan(body, state, lats)

        lats = jnp.asarray([0.3, 0.2, 0.08, 0.05, 0.04], jnp.float32)
        state, idxs = run(state, lats)
        assert idxs.shape == (5,)
        assert bool((idxs >= -1).all())


class TestClosedLoop:
    """The paper's Section 5.1 scenario in miniature."""

    def test_step_response_settles_under_target(self):
        camf = lambda: SyntheticCamera(CameraConfig(dynamics="complex", seed=7))
        tbl = characterize(camf, clip_len=12)
        ch = calibrated_channel(seed=3, workload="jaad")
        sizes = np.linspace(tbl.sizes_sorted[0], tbl.sizes_sorted[-1], 16)
        reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=5))
        c = LatencyController(ControllerConfig(0.100, 0.95), tbl, reg)
        for cam in range(5):
            ch.activate(f"cam{cam}")
        lat_series = []
        setting = c.current_setting
        size = tbl.size_by_setting[c._current]
        for step in range(30):
            lat = ch.transfer(float(size))
            lat_series.append(lat)
            d = c.update(lat)
            if d.setting_index >= 0:
                size = tbl.size_by_setting[d.setting_index]
        settled = np.asarray(lat_series[8:])
        assert np.percentile(settled, 95) < 0.13   # near the 100 ms bound
        assert float(tbl.acc_by_setting[c._current]) >= 0.90
