"""Latency controller (Algorithm 1): host + jittable implementations."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channel import calibrated_channel
from repro.core.characterization import (CharacterizationTable,
                                         LatencyRegression, characterize,
                                         fit_latency_regression)
from repro.core.controller import (ControllerConfig, ControllerState,
                                   JaxControllerTables, LatencyController,
                                   controller_init, controller_step,
                                   swap_tables)
from repro.core.knobs import KnobSetting
from repro.data.camera import CameraConfig, SyntheticCamera


def synthetic_table(n=32, *, smin=2e3, smax=90e3) -> CharacterizationTable:
    """Monotone size->accuracy table without running the detector."""
    sizes = np.linspace(smin, smax, n)
    accs = 0.90 + 0.10 * (sizes - smin) / (smax - smin)
    settings = tuple(KnobSetting(resolution=i % 5) for i in range(n))
    best_idx = np.arange(n)
    return CharacterizationTable(
        settings=settings, sizes_sorted=sizes, best_acc=accs,
        best_idx=best_idx, acc_by_setting=accs, size_by_setting=sizes)


@pytest.fixture(scope="module")
def regression():
    ch = calibrated_channel()
    sizes = np.linspace(2e3, 90e3, 16)
    return fit_latency_regression(sizes, ch.regression_points(sizes, n=5))


class TestHostController:
    def test_holds_when_in_band(self, regression):
        tbl = synthetic_table()
        c = LatencyController(ControllerConfig(0.050, 0.92), tbl, regression)
        before = c._current
        d = c.update(0.050)      # exactly on target: no action
        assert not d.acted and d.setting_index == before

    def test_shrinks_on_high_latency(self, regression):
        tbl = synthetic_table()
        c = LatencyController(ControllerConfig(0.050, 0.90), tbl, regression)
        d0 = c.update(0.050)
        d1 = c.update(0.500)     # 10x over target
        assert d1.acted
        assert d1.requested_size < d0.requested_size or not d0.acted
        assert tbl.size_by_setting[d1.setting_index] <= \
            tbl.size_by_setting[c.table.best_idx[-1]]

    def test_relaxes_on_low_latency(self, regression):
        tbl = synthetic_table()
        c = LatencyController(ControllerConfig(0.050, 0.90), tbl, regression)
        c.update(0.400)
        small = c.table.size_by_setting[c._current]
        for _ in range(6):
            d = c.update(0.005)
        assert c.table.size_by_setting[c._current] >= small

    def test_infeasible_notifies_but_degrades_gracefully(self, regression):
        tbl = synthetic_table()
        # demand more accuracy than ANY setting at the needed size offers
        c = LatencyController(ControllerConfig(0.012, 0.999), tbl, regression)
        d = c.update(0.500)
        assert not d.feasible
        assert d.setting is not None     # best-effort setting still returned

    def test_set_target_resets(self, regression):
        tbl = synthetic_table()
        c = LatencyController(ControllerConfig(0.050, 0.90), tbl, regression)
        c.update(0.5)
        c.set_target(0.100, 0.95)
        assert c.integral == 0.0
        assert c.config.latency_target == 0.100


class TestJaxController:
    def test_matches_host_decisions(self, regression):
        tbl = synthetic_table()
        cfg = ControllerConfig(0.050, 0.92)
        host = LatencyController(cfg, tbl, regression)
        jt = JaxControllerTables.from_table(tbl)
        state = controller_init(jt)
        step = jax.jit(lambda st, lat: controller_step(
            st, lat, jt, latency_target=cfg.latency_target,
            accuracy_target=cfg.accuracy_target, slope=regression.slope,
            intercept=regression.intercept, error_threshold=cfg.error_threshold,
            alpha_p=cfg.alpha_p, alpha_i=cfg.alpha_i))
        # jax controller starts at table max; align host for comparison
        samples = [0.3, 0.25, 0.12, 0.06, 0.05, 0.04, 0.04]
        for lat in samples:
            dh = host.update(lat)
            state, idx = step(state, lat)
            if dh.acted and dh.feasible:
                hs = tbl.size_by_setting[dh.setting_index]
                js = tbl.size_by_setting[int(idx)]
                # same table, same law -> same requested size region
                np.testing.assert_allclose(hs, js, rtol=0.35)

    def test_jit_traceable_no_host_sync(self, regression):
        tbl = synthetic_table()
        jt = JaxControllerTables.from_table(tbl)
        state = controller_init(jt)

        @jax.jit
        def run(state, lats):
            def body(st, lat):
                st, idx = controller_step(
                    st, lat, jt, latency_target=0.05, accuracy_target=0.9,
                    slope=regression.slope, intercept=regression.intercept)
                return st, idx
            return jax.lax.scan(body, state, lats)

        lats = jnp.asarray([0.3, 0.2, 0.08, 0.05, 0.04], jnp.float32)
        state, idxs = run(state, lats)
        assert idxs.shape == (5,)
        assert bool((idxs >= -1).all())


class TestHotSwapTables:
    """Online re-characterization contract: refreshed tables flow into a
    compiled ``controller_step`` as traced inputs -- same decisions as the
    host controller, and NO recompile across the swap."""

    def _step_fn(self, cfg, regression):
        @jax.jit
        def step(state, lat, tables):
            return controller_step(
                state, lat, tables, latency_target=cfg.latency_target,
                accuracy_target=cfg.accuracy_target, slope=regression.slope,
                intercept=regression.intercept,
                error_threshold=cfg.error_threshold,
                alpha_p=cfg.alpha_p, alpha_i=cfg.alpha_i)
        return step

    def test_swapped_tables_match_host_decision_sequence(self, regression):
        """After an ``update_qos``-style retarget + table refresh, the jit
        step's knob choices track the host ``LatencyController`` decision
        for decision on the SAME swapped tables."""
        cap = 64
        tbl_a = synthetic_table(32)
        tbl_b = synthetic_table(20, smin=3.2e3, smax=71e3)
        cfg = ControllerConfig(0.050, 0.90)
        host = LatencyController(cfg, tbl_a, regression)
        jt = JaxControllerTables.from_table(tbl_a, capacity=cap)
        step = self._step_fn(cfg, regression)
        state = controller_init(jt, start_idx=host._current)

        def run(samples, state, jt):
            for lat in samples:
                dh = host.update(lat)
                state, idx = step(state, lat, jt)
                assert int(idx) == dh.setting_index, lat
            return state

        state = run([0.31, 0.22, 0.113, 0.051, 0.047, 0.033], state, jt)

        # live refresh: host swaps its table, the jit twin swaps arrays of
        # the SAME capacity (different n_valid) into the same compiled step
        host.swap_table(tbl_b)
        fresh = JaxControllerTables.from_table(tbl_b, capacity=cap)
        jt = swap_tables(jt, fresh)
        assert int(jt.n_valid) == 20
        state = ControllerState(                  # re-seed like the host did
            integral=state.integral,
            current_idx=jnp.asarray(host._current, jnp.int32),
            feasible=state.feasible, last_error=state.last_error)
        run([0.027, 0.192, 0.094, 0.052, 0.041], state, jt)

        # the whole sequence -- both tables -- used ONE compiled step
        assert step._cache_size() == 1

    def test_capacity_padding_is_inert(self, regression):
        """Padded and unpadded tables produce identical step outputs."""
        tbl = synthetic_table(24)
        exact = JaxControllerTables.from_table(tbl)
        padded = JaxControllerTables.from_table(tbl, capacity=128)
        cfg = ControllerConfig(0.050, 0.92)
        se, sp = controller_init(exact), controller_init(padded)
        assert int(se.current_idx) == int(sp.current_idx)
        for lat in [0.28, 0.11, 0.06, 0.049, 0.038]:
            se, ie = controller_step(
                se, lat, exact, latency_target=cfg.latency_target,
                accuracy_target=cfg.accuracy_target, slope=regression.slope,
                intercept=regression.intercept)
            sp, ip = controller_step(
                sp, lat, padded, latency_target=cfg.latency_target,
                accuracy_target=cfg.accuracy_target, slope=regression.slope,
                intercept=regression.intercept)
            assert int(ie) == int(ip)
            np.testing.assert_allclose(float(se.integral),
                                       float(sp.integral))

    def test_capacity_too_small_rejected(self):
        tbl = synthetic_table(32)
        with pytest.raises(ValueError, match="capacity"):
            JaxControllerTables.from_table(tbl, capacity=8)

    def test_swap_shape_mismatch_falls_through(self):
        a = JaxControllerTables.from_table(synthetic_table(16), capacity=32)
        b = JaxControllerTables.from_table(synthetic_table(16), capacity=64)
        out = swap_tables(a, b)
        assert out.sizes_sorted.shape[0] == 64    # fresh wins, no error


class TestClosedLoop:
    """The paper's Section 5.1 scenario in miniature."""

    def test_step_response_settles_under_target(self):
        camf = lambda: SyntheticCamera(CameraConfig(dynamics="complex", seed=7))
        tbl = characterize(camf, clip_len=12)
        ch = calibrated_channel(seed=3, workload="jaad")
        sizes = np.linspace(tbl.sizes_sorted[0], tbl.sizes_sorted[-1], 16)
        reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=5))
        c = LatencyController(ControllerConfig(0.100, 0.95), tbl, reg)
        for cam in range(5):
            ch.activate(f"cam{cam}")
        lat_series = []
        setting = c.current_setting
        size = tbl.size_by_setting[c._current]
        for step in range(30):
            lat = ch.transfer(float(size))
            lat_series.append(lat)
            d = c.update(lat)
            if d.setting_index >= 0:
                size = tbl.size_by_setting[d.setting_index]
        settled = np.asarray(lat_series[8:])
        assert np.percentile(settled, 95) < 0.13   # near the 100 ms bound
        assert float(tbl.acc_by_setting[c._current]) >= 0.90
