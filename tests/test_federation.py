"""Federated broker tier: herd routing/poll parity, live camera migration
(exactly-once delivery, carried controller state, herd-wide credit
conservation), the overload shed policy, rolling upgrades, and the
scenario-DSL events that drive them."""

import numpy as np
import pytest

from repro.core.api import (EventKind, QosBounds, RPCTimeout,
                            SubscriptionState)
from repro.core.broker import MezSystem
from repro.core.channel import ChannelConfig, WirelessChannel, \
    calibrated_channel
from repro.core.characterization import characterize, fit_latency_regression
from repro.core.federation import FederatedMezSystem
from repro.core.scenario import (BrokerOverload, CameraMigrate, CameraSpec,
                                 RollingUpgrade, ScenarioSpec, run_scenario)
from repro.core.session import MezClient
from repro.data.camera import CameraConfig, SyntheticCamera

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # the property test degrades to scripted +
    HAVE_HYPOTHESIS = False  # seeded-random interleavings below

HYP = dict(max_examples=10, deadline=None)


@pytest.fixture(scope="module")
def table():
    return characterize(
        lambda: SyntheticCamera(CameraConfig(dynamics="medium", seed=7)),
        clip_len=10)


def build_federated(table, *, n_cams=3, frames=10, n_brokers=2, seed=3,
                    wire_budget=None, jitter=True):
    """A federated system with published streams; returns (system,
    {camera_id: [published timestamps]}).  ``jitter=False`` zeroes the
    channel's log-normal jitter so latencies -- and therefore controller
    decisions -- are independent of fetch order across brokers."""
    if jitter:
        ch = calibrated_channel(seed=seed)
    else:
        ch = WirelessChannel(ChannelConfig(jitter_sigma=0.0), seed=seed)
    sys = FederatedMezSystem(ch, n_brokers=n_brokers,
                             wire_budget=wire_budget)
    sizes = np.linspace(table.sizes_sorted[0], table.sizes_sorted[-1], 12)
    reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=n_cams))
    published = {}
    for i in range(n_cams):
        cam = sys.add_camera(f"cam{i}")
        src = SyntheticCamera(CameraConfig(camera_id=f"cam{i}",
                                           dynamics="medium", seed=7))
        cam.background = src.background
        cam.set_target(0.100, 0.90, table, reg)
        published[f"cam{i}"] = []
        for ts, f, _ in src.stream(frames):
            cam.publish(ts, f)
            published[f"cam{i}"].append(float(ts))
    return sys, published


def drain(sub, *, max_frames=6, max_polls=200, hook=None):
    """Poll to exhaustion; returns ({camera_id: [delivered timestamps]},
    delivered frames).  ``hook(poll_index)`` runs after each non-empty
    poll (migration injection point)."""
    seen: dict[str, list[float]] = {}
    rows = []
    for i in range(max_polls):
        batch = sub.poll(max_frames=max_frames)
        if not batch:
            break
        for d in batch.frames:
            seen.setdefault(d.camera_id, []).append(float(d.timestamp))
            rows.append(d)
        if hook is not None:
            hook(i)
    return seen, rows


def assert_exactly_once(seen, published):
    assert set(seen) == set(published)
    for cid, stamps in published.items():
        got = seen.get(cid, [])
        assert got == sorted(got), f"{cid} delivered out of order"
        assert got == stamps, (f"{cid}: delivered {len(got)}/{len(stamps)} "
                               f"(dupes={len(got) - len(set(got))})")


def assert_conserved(herd):
    rep = herd.credit_report()
    assert rep["leaked"] == 0, rep
    assert rep["in_flight"] == 0, rep


# =============================================================================
# Herd topology + poll parity
# =============================================================================


class TestHerdTopology:
    def test_default_routing_balances_brokers(self, table):
        sys, _ = build_federated(table, n_cams=4, frames=2)
        routes = [sys.herd.route_of(f"cam{i}") for i in range(4)]
        assert sorted(routes) == [0, 0, 1, 1]

    def test_single_broker_herd_matches_mezsystem(self, table):
        """An n_brokers=1 herd is byte-identical to a lone MezSystem: same
        channel seed, same fetch order, same jitter draws, same decisions."""
        sysf, _ = build_federated(table, n_cams=2, frames=8, n_brokers=1)
        ch = calibrated_channel(seed=3)
        syss = MezSystem(ch)
        sizes = np.linspace(table.sizes_sorted[0], table.sizes_sorted[-1],
                            12)
        reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=2))
        for i in range(2):
            cam = syss.add_camera(f"cam{i}")
            src = SyntheticCamera(CameraConfig(camera_id=f"cam{i}",
                                               dynamics="medium", seed=7))
            cam.background = src.background
            cam.set_target(0.100, 0.90, table, reg)
            for ts, f, _ in src.stream(8):
                cam.publish(ts, f)

        def run(system):
            sess = MezClient(system).open_session("app")
            sub = sess.subscribe(["cam0", "cam1"], 0.0, 100.0,
                                 qos=QosBounds(0.1, 0.9))
            _, rows = drain(sub)
            sess.close()
            return [(d.camera_id, d.timestamp, d.knob_index, d.wire_bytes,
                     d.latency.total) for d in rows]

        assert run(sysf) == run(syss)

    def test_merged_batches_stay_sorted_across_parts(self, table):
        sys, _ = build_federated(table, n_cams=4, frames=6)
        sess = MezClient(sys).open_session("app")
        sub = sess.subscribe([f"cam{i}" for i in range(4)], 0.0, 100.0,
                             qos=QosBounds(0.1, 0.9))
        while (batch := sub.poll(max_frames=8)):
            keys = [(d.timestamp, d.camera_id) for d in batch.frames]
            assert keys == sorted(keys)
        assert sub.state is SubscriptionState.DRAINED
        sess.close()

    def test_partial_herd_crash_keeps_serving(self, table):
        """One broker down: its part raises locally but the herd still
        delivers the live brokers' frames, and the dead broker's cameras
        resume after recovery with nothing lost or duplicated."""
        sys, published = build_federated(table, n_cams=2, frames=6)
        herd = sys.herd
        sess = MezClient(sys).open_session("app")
        sub = sess.subscribe(["cam0", "cam1"], 0.0, 100.0,
                             qos=QosBounds(0.1, 0.9))
        seen = {c: [] for c in published}

        def add(batch):
            for d in batch.frames:
                seen[d.camera_id].append(float(d.timestamp))

        down = herd.route_of("cam0")
        herd.crash(broker=down)
        batch = sub.poll(max_frames=4)
        assert batch and all(d.camera_id != "cam0" for d in batch.frames)
        add(batch)
        herd.recover(broker=down)
        while (batch := sub.poll(max_frames=4)):
            add(batch)
        assert_exactly_once(seen, published)
        assert_conserved(herd)
        sess.close()


# =============================================================================
# Live migration
# =============================================================================


class TestMigration:
    def test_exactly_once_across_migration(self, table):
        """A mid-stream migration loses no frame and duplicates none; the
        subscriber sees one CAMERA_MIGRATED event stamped with the herd
        subscription id, and the ledger conserves herd-wide."""
        sys, published = build_federated(table, n_cams=3, frames=12)
        herd = sys.herd
        sess = MezClient(sys).open_session("app")
        sub = sess.subscribe(["cam0", "cam1", "cam2"], 0.0, 100.0,
                             qos=QosBounds(0.1, 0.9))

        state = {"done": False}

        def hook(i):
            if i == 1 and not state["done"]:
                assert herd.migrate_camera("cam0", 1, at=1.0)
                state["done"] = True

        seen, _ = drain(sub, hook=hook)
        assert state["done"]
        assert_exactly_once(seen, published)
        assert herd.route_of("cam0") == 1
        assert herd.migrations == 1
        assert_conserved(herd)
        evs = [e for e in sess.events()
               if e.kind is EventKind.CAMERA_MIGRATED]
        assert len(evs) == 1
        assert evs[0].camera_id == "cam0"
        assert evs[0].subscription_id == sub.subscription_id
        assert "0 -> 1" in evs[0].detail
        sess.close()

    def test_migration_is_invisible_in_controller_decisions(self, table):
        """With order-independent (zero-jitter) latencies, the migrated
        lane's decisions are byte-identical to a no-migration run: knob
        index, wire bytes, latency, and the PI integral all survive the
        hand-off."""
        def run(migrate):
            sys, published = build_federated(table, n_cams=3, frames=12,
                                             jitter=False)
            herd = sys.herd
            sess = MezClient(sys).open_session("app")
            sub = sess.subscribe(["cam0", "cam1", "cam2"], 0.0, 100.0,
                                 qos=QosBounds(0.1, 0.9))

            def hook(i):
                if migrate and i == 1 and herd.route_of("cam0") == 0:
                    assert herd.migrate_camera("cam0", 1, at=1.0)

            seen, rows = drain(sub, hook=hook)
            assert_exactly_once(seen, published)
            trace = {}
            for d in rows:
                trace.setdefault(d.camera_id, []).append(
                    (float(d.timestamp), int(d.knob_index),
                     int(d.wire_bytes), float(d.latency.total)))
            integ = {cid: sys.cams[cid].controller.integral
                     for cid in published}
            sess.close()
            return trace, integ

        base_trace, base_integ = run(migrate=False)
        mig_trace, mig_integ = run(migrate=True)
        assert mig_trace == base_trace
        assert mig_integ == base_integ

    def test_pi_state_travels_with_the_camera(self, table):
        sys, _ = build_federated(table, n_cams=2, frames=8)
        herd = sys.herd
        sess = MezClient(sys).open_session("app")
        sub = sess.subscribe(["cam0", "cam1"], 0.0, 100.0,
                             qos=QosBounds(0.1, 0.9))
        sub.poll(max_frames=4)
        ctl = sys.cams["cam0"].controller
        before = (ctl.integral, ctl._current)
        assert herd.migrate_camera("cam0", 1, at=0.5)
        after = (sys.cams["cam0"].controller.integral,
                 sys.cams["cam0"].controller._current)
        assert sys.cams["cam0"].controller is ctl
        assert after == before
        sess.close()

    def test_same_broker_migration_is_noop(self, table):
        sys, _ = build_federated(table, n_cams=2, frames=4)
        herd = sys.herd
        src = herd.route_of("cam0")
        assert herd.migrate_camera("cam0", src) is False
        assert herd.migrations == 0

    def test_crashed_endpoint_refuses_migration(self, table):
        sys, published = build_federated(table, n_cams=2, frames=6)
        herd = sys.herd
        sess = MezClient(sys).open_session("app")
        sub = sess.subscribe(["cam0", "cam1"], 0.0, 100.0,
                             qos=QosBounds(0.1, 0.9))
        seen = {c: [] for c in published}
        for d in sub.poll(max_frames=4).frames:
            seen[d.camera_id].append(float(d.timestamp))
        herd.crash(broker=1)
        with pytest.raises(RPCTimeout):
            herd.migrate_camera("cam0", 1)
        assert herd.route_of("cam0") == 0      # route untouched
        herd.recover(broker=1)
        assert herd.migrate_camera("cam0", 1)
        while (batch := sub.poll(max_frames=4)):
            for d in batch.frames:
                seen[d.camera_id].append(float(d.timestamp))
        assert_exactly_once(seen, published)
        assert_conserved(herd)
        sess.close()

    def test_unknown_camera_raises(self, table):
        sys, _ = build_federated(table, n_cams=2, frames=2)
        with pytest.raises(RPCTimeout):
            sys.herd.migrate_camera("nope", 1)


# =============================================================================
# Overload policy + rolling upgrade
# =============================================================================


class TestOverloadPolicy:
    def _tenanted(self, table):
        """Herd with a gold lane (older, cam0) and a best_effort lane
        (newer, cam2), both riding broker 0."""
        sys, published = build_federated(table, n_cams=4, frames=6)
        client = MezClient(sys)
        gold_sess = client.open_session("gold-app", tenant="g", slo="gold")
        gold = gold_sess.subscribe(["cam0"], 0.0, 100.0,
                                   qos=QosBounds(0.1, 0.9))
        be_sess = client.open_session("be-app", tenant="b",
                                      slo="best_effort")
        be = be_sess.subscribe(["cam2"], 0.0, 100.0,
                               qos=QosBounds(0.1, 0.9))
        return sys, published, (gold_sess, gold), (be_sess, be)

    def test_shed_order_is_newest_best_effort_first(self, table):
        sys, _, (_, gold), (_, be) = self._tenanted(table)
        herd = sys.herd
        assert herd.route_of("cam0") == herd.route_of("cam2") == 0
        ranked = herd._shed_candidates(0)
        assert ranked, "no shed candidates on broker 0"
        first_sub, first_cam = ranked[0]
        assert first_cam == "cam2"              # the best_effort lane
        assert first_sub.sub_id == be.subscription_id
        slos = [herd.brokers[0].wire_report()["subscriptions"]
                [rec.part_of(cid).sub_id]["slo"]
                for rec, cid in ranked]
        assert slos.index("gold") > slos.index("best_effort")

    def test_rebalance_sheds_off_the_hot_broker(self, table):
        sys, _, (gold_sess, _), (be_sess, _) = self._tenanted(table)
        herd = sys.herd
        assert not herd.overloaded(0)
        herd.set_wire_budget(0, 1.0)            # degraded backhaul
        assert herd.overloaded(0)
        moves = herd.rebalance(at=1.0)
        assert moves
        assert moves[0][0] == "cam2"            # best_effort shed first
        assert all(src == 0 and dst == 1 for _, src, dst in moves)
        overload_evs = [e for e in be_sess.events()
                        if e.kind is EventKind.BROKER_OVERLOAD]
        assert overload_evs and "broker 0" in overload_evs[0].detail
        assert_conserved(herd)
        gold_sess.close()
        be_sess.close()

    def test_receiver_does_not_shed_back_in_same_pass(self, table):
        """With every broker past the watermark, one pass moves load in
        ONE direction only (no ping-pong)."""
        sys, _, (gold_sess, _), (be_sess, _) = self._tenanted(table)
        client = MezClient(sys)
        far_sess = client.open_session("far-app", tenant="f",
                                       slo="best_effort")
        far_sess.subscribe(["cam3"], 0.0, 100.0, qos=QosBounds(0.1, 0.9))
        herd = sys.herd
        herd.set_wire_budget(0, 1.0)
        herd.set_wire_budget(1, 2.0)
        assert herd.overloaded(0) and herd.overloaded(1)
        moves = herd.rebalance(at=1.0)
        assert moves
        sources = {src for _, src, _ in moves}
        targets = {dst for _, _, dst in moves}
        assert not (sources & targets), f"ping-pong moves: {moves}"
        gold_sess.close()
        be_sess.close()
        far_sess.close()

    def test_rolling_upgrade_is_invisible_to_subscribers(self, table):
        sys, published = build_federated(table, n_cams=4, frames=8)
        herd = sys.herd
        sess = MezClient(sys).open_session("app")
        sub = sess.subscribe([f"cam{i}" for i in range(4)], 0.0, 100.0,
                             qos=QosBounds(0.1, 0.9))

        def hook(i):
            if i == 1:
                herd.rolling_upgrade(at=1.0)

        seen, _ = drain(sub, max_frames=8, hook=hook)
        assert_exactly_once(seen, published)
        assert not herd.crashed
        assert herd.migrations >= 4          # every camera moved at least once
        assert_conserved(herd)
        sess.close()

    def test_rolling_upgrade_needs_two_brokers(self, table):
        sys, _ = build_federated(table, n_cams=2, frames=2, n_brokers=1)
        with pytest.raises(ValueError):
            sys.herd.rolling_upgrade()


# =============================================================================
# Herd-wide credit conservation under adversarial interleavings
# =============================================================================


def run_interleaving(table, ops):
    """Drive a 2-broker / 3-camera herd through an arbitrary interleaving
    of polls, migrations (including into or out of crashed brokers),
    crashes, and recoveries.  After EVERY op the herd-wide credit ledger
    must conserve (leaked == 0, in_flight == 0) and no frame may have been
    delivered twice; once every broker is back and the stream drains,
    every published frame was delivered exactly once."""
    sys, published = build_federated(table, n_cams=3, frames=6)
    herd = sys.herd
    sess = MezClient(sys).open_session("app")
    sub = sess.subscribe(["cam0", "cam1", "cam2"], 0.0, 100.0,
                         qos=QosBounds(0.1, 0.9))
    seen: dict[str, list[float]] = {c: [] for c in published}
    for op, a, b in ops:
        if op == "poll":
            try:
                for d in sub.poll(max_frames=5).frames:
                    seen[d.camera_id].append(float(d.timestamp))
            except RPCTimeout:
                pass                        # whole herd was down
        elif op == "migrate":
            try:
                herd.migrate_camera(f"cam{a}", b)
            except RPCTimeout:
                pass                        # an endpoint was down
        elif op == "crash":
            herd.crash(broker=a)
        else:
            herd.recover(broker=a)
        rep = herd.credit_report()
        assert rep["leaked"] == 0 and rep["in_flight"] == 0, (op, rep)
        for cid, stamps in seen.items():
            assert len(stamps) == len(set(stamps)), f"dup on {cid}"
    herd.recover()
    for _ in range(60):
        batch = sub.poll(max_frames=5)
        if not batch:
            break
        for d in batch.frames:
            seen[d.camera_id].append(float(d.timestamp))
    assert_exactly_once(seen, published)
    assert_conserved(herd)
    sess.close()


# hand-picked adversarial interleavings: the two the issue calls out
# (crash-during-migration, migrate-during-poll) plus a whole-herd outage
SCRIPTED_INTERLEAVINGS = [
    pytest.param([("crash", 1, 0), ("migrate", 0, 1), ("recover", 1, 0),
                  ("migrate", 0, 1), ("poll", 0, 0)],
                 id="crash-during-migration"),
    pytest.param([("poll", 0, 0), ("migrate", 0, 1), ("poll", 0, 0),
                  ("migrate", 0, 0), ("poll", 0, 0), ("migrate", 2, 1),
                  ("poll", 0, 0)],
                 id="migrate-during-poll"),
    pytest.param([("poll", 0, 0), ("crash", 0, 0), ("crash", 1, 0),
                  ("poll", 0, 0), ("migrate", 1, 0), ("recover", 0, 0),
                  ("migrate", 1, 0), ("poll", 0, 0), ("recover", 1, 0)],
                 id="whole-herd-outage"),
]


class TestCreditConservationProperty:
    @pytest.mark.parametrize("ops", SCRIPTED_INTERLEAVINGS)
    def test_scripted_interleavings_conserve(self, table, ops):
        run_interleaving(table, ops)

    @pytest.mark.parametrize("seed", range(6))
    def test_random_interleavings_conserve(self, table, seed):
        """Deterministic random walks over the op space (the fallback
        property sweep when hypothesis is unavailable)."""
        import random
        rng = random.Random(seed)
        ops = []
        for _ in range(rng.randint(4, 14)):
            kind = rng.choice(["poll", "poll", "migrate", "crash",
                               "recover"])
            ops.append((kind, rng.randrange(3 if kind == "migrate" else 2),
                        rng.randrange(2)))
        run_interleaving(table, ops)

    if HAVE_HYPOTHESIS:
        OPS = st.lists(
            st.one_of(
                st.tuples(st.just("poll"), st.just(0), st.just(0)),
                st.tuples(st.just("migrate"), st.integers(0, 2),
                          st.integers(0, 1)),
                st.tuples(st.just("crash"), st.integers(0, 1), st.just(0)),
                st.tuples(st.just("recover"), st.integers(0, 1),
                          st.just(0)),
            ),
            max_size=14)

        @given(OPS)
        @settings(**HYP)
        def test_herd_ledger_conserves_through_interleavings(self, table,
                                                             ops):
            run_interleaving(table, ops)


# =============================================================================
# Scenario DSL integration
# =============================================================================


class TestScenarioEvents:
    def _spec(self, **kw):
        base = dict(
            name="fed-test",
            cameras=(CameraSpec("cam0", dynamics="medium", fps=5.0),
                     CameraSpec("cam1", dynamics="medium", fps=5.0)),
            frames=16, seed=3, n_brokers=2)
        base.update(kw)
        return ScenarioSpec(**base)

    def test_scenario_runs_migration_and_upgrade(self, table):
        spec = self._spec(events=(
            CameraMigrate(at=1.0, camera_id="cam0", to_broker=1),
            RollingUpgrade(at=2.0),
        ))
        res = run_scenario(spec, tables={"medium": table})
        kinds = [e["kind"] for e in res.events_log]
        assert "CameraMigrate" in kinds and "RollingUpgrade" in kinds
        mig = next(e for e in res.events_log if e["kind"] == "CameraMigrate")
        assert mig["moved"] is True
        assert res.credit_stats["leaked"] == 0
        assert res.credit_stats["in_flight"] == 0
        # every published frame delivered despite migration + upgrade
        assert len(res.rows) == 32

    def test_broker_overload_event_sheds_and_logs(self, table):
        spec = self._spec(events=(
            BrokerOverload(at=1.0, broker=0, factor=1e-9),))
        res = run_scenario(spec, tables={"medium": table})
        ov = next(e for e in res.events_log if e["kind"] == "BrokerOverload")
        assert ov["broker"] == 0
        assert res.credit_stats["leaked"] == 0

    def test_federated_events_require_n_brokers(self, table):
        spec = self._spec(n_brokers=1, events=(
            CameraMigrate(at=1.0, camera_id="cam0", to_broker=1),))
        with pytest.raises(TypeError, match="n_brokers"):
            run_scenario(spec, tables={"medium": table})
