"""mezlint fixture: MZ04-clean dtype discipline (f32 lanes only)."""

import jax
import jax.numpy as jnp


@jax.jit
def entry(x):
    gain = jnp.asarray(1.5, dtype=jnp.float32)
    return gain * x.astype(jnp.float32)
