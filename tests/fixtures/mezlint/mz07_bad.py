"""mezlint fixture: MZ07 violations -- create_subscription called with the
deprecated per-kwarg config spelling (or opaque **kwargs forwarding)."""


def open_legacy(edge, session_id, specs):
    return edge.create_subscription(session_id, specs,
                                    controlled=True, fleet=True,
                                    feedback_window=4)


def open_tenanted_legacy(edge, session_id, specs):
    return edge.create_subscription(session_id, specs,
                                    tenant="acme", slo="gold")


def forward_blindly(edge, session_id, specs, **kw):
    return edge.create_subscription(session_id, specs, **kw)
