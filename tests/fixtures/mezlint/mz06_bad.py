"""mezlint fixture: MZ06 violations -- per-camera decision application
inside Python loops on the poll path (the pre-fused-tick broker shape)."""


class ControlDecision:
    def __init__(self, setting, index):
        self.setting = setting
        self.index = index


# mezlint: poll-path
def poll(cams, aux):
    decisions = {}
    for i, cam in enumerate(cams):                  # O(N) per poll
        idx = int(aux.idx[i])
        setting = cam.controller.table.setting_for(idx)
        decisions[cam.camera_id] = ControlDecision(setting, idx)
    return decisions


# mezlint: poll-path
def feed_back(cams, latencies):
    for cam, lat in zip(cams, latencies):
        cam.controller.update(lat)                  # host PI step per camera
