"""mezlint fixture: MZ03 violations -- guarded fields touched unlocked."""

import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0         # guarded-by: _lock
        self._peak = 0      # guarded-by: _lock

    def bump(self):
        with self._lock:
            self._n += 1
        self._peak = max(self._peak, self._n)    # lock already released

    def peek(self):
        return self._n                           # no lock at all

    # holds-lock: _lock
    def _reset_unsafe(self):
        self._n = 0

    def reset(self):
        self._reset_unsafe()                     # caller holds nothing
