"""mezlint fixture: MZ07 clean -- configuration travels as one frozen
SubscriptionOptions; positional/retarget/options keywords are fine."""


class SubscriptionOptions:
    def __init__(self, **cfg):
        self.cfg = cfg


def open_sub(edge, session_id, specs):
    opts = SubscriptionOptions(controlled=True, fleet=True, tenant="acme",
                               slo="gold")
    return edge.create_subscription(session_id, specs, options=opts)


def open_default(edge, session_id, specs):
    return edge.create_subscription(session_id, specs, retarget=False)
