"""mezlint fixture: MZ02-clean jit usage."""

import functools

import jax

CAPACITY = 512


@functools.partial(jax.jit, static_argnames=("k",))
def topk_sum(x, k: int):
    return x[:k].sum()


def sweep(xs):
    return [topk_sum(xs, k=4) for _ in range(8)]   # static arg held constant


def refresh(tables_cls, table):
    return tables_cls.from_table(table, capacity=CAPACITY)


class Engine:
    def __init__(self, fn):
        self._step = jax.jit(fn)          # once-per-object wrapper: blessed
