"""mezlint fixture: MZ03-clean lock discipline."""

import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0         # guarded-by: _lock
        self._peak = 0      # guarded-by: _lock

    def bump(self):
        with self._lock:
            self._n += 1
            self._peak = max(self._peak, self._n)

    def peek(self):
        with self._lock:
            return self._n

    # holds-lock: _lock
    def _reset_unsafe(self):
        self._n = 0

    def reset(self):
        with self._lock:
            self._reset_unsafe()

    def drain(self):
        lock = self._lock                # alias-tracked acquire/release
        lock.acquire()
        try:
            out, self._n = self._n, 0
            return out
        finally:
            lock.release()
