"""mezlint fixture: MZ08 violations -- EdgeBroker built directly (module
scope, helper function, and via a module alias), bypassing herd routing."""

import repro.core.broker as broker
from repro.core.broker import EdgeBroker

edge = EdgeBroker(log_capacity=64)


def build_benchmark_broker(wire_budget):
    return EdgeBroker(wire_budget=wire_budget)


def build_aliased_broker():
    return broker.EdgeBroker()
