"""mezlint fixture: MZ05 violations -- Pallas kernel hygiene.

No ``# mezlint: ref-parity:`` declaration either, which is itself a
finding for any module that calls ``pallas_call``.
"""

import jax
from jax.experimental import pallas as pl


def scale_all(x, scale):
    def _kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * scale      # closes over a traced local

    return pl.pallas_call(                   # no interpret= flag
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
