"""mezlint fixture: MZ02 violations -- retrace smells."""

import functools

import jax


@functools.partial(jax.jit, static_argnames=("k",))
def topk_sum(x, k: int):
    return x[:k].sum()


def rewrap_per_call(fn, xs):
    jitted = jax.jit(fn)                 # fresh wrapper (and cache) per call
    return [jitted(x) for x in xs]


def sweep(xs):
    out = []
    for k in range(8):
        out.append(topk_sum(xs, k=k))    # static arg varies per iteration
    return out


def refresh(tables_cls, table):
    return tables_cls.from_table(table)  # unpadded: shape follows kept-set
