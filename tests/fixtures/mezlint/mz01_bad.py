"""mezlint fixture: MZ01 violations -- host syncs inside traced code.

Never imported at runtime; parsed by tests/test_mezlint.py only.
"""

import jax
import numpy as np


@jax.jit
def entry(x, y):
    return helper(x) + y


def helper(x):
    if x > 0:                 # dynamic Python branch on a traced value
        return float(x)       # host cast of a traced parameter
    v = x.item()              # explicit host sync
    return np.abs(x) + v      # host-library call in traced code
