"""mezlint fixture: MZ01-clean traced code.

Branches only on trace-time-static values (shapes, static params,
`is None` checks); all math stays in jnp/lax.
"""

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("normalize",))
def entry(x, normalize: bool = True, bias=None):
    if x.ndim == 2:                       # shape: static under trace
        x = x[None]
    if bias is not None:                  # None-check: static
        x = x + bias
    return helper(x, normalize)


def helper(x, normalize):
    total = jnp.sum(x, axis=-1)
    return jnp.where(jnp.asarray(normalize), total / x.shape[-1], total)
