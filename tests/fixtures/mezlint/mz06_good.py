"""mezlint fixture: MZ06 clean -- the poll path consumes a fused, lazily
materialized decision mapping instead of applying decisions per camera."""


# mezlint: poll-path
def poll(fleet, lat, valid, cams):
    decisions = fleet.tick(lat, valid)      # one sharded dispatch
    out = []
    for cam in cams:                        # loop does I/O only
        out.append((cam.camera_id, decisions.get(cam.camera_id)))
    return out


def off_path_refresh(cams, aux):
    # Not marked poll-path: per-camera application is fine here (rare,
    # host-side maintenance such as table refreshes).
    for i, cam in enumerate(cams):
        cam.controller.update(float(aux.lat[i]))
