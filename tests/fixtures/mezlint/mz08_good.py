"""mezlint fixture: MZ08 clean -- brokers come from MezSystem (single) or
BrokerHerd / FederatedMezSystem (federated); referencing the EdgeBroker
*type* (annotations, isinstance) is fine, only construction is flagged."""

from repro.core.broker import EdgeBroker, MezSystem
from repro.core.federation import BrokerHerd, FederatedMezSystem


def build_single(channel):
    return MezSystem(channel, wire_budget=1e7)


def build_federated(channel):
    return FederatedMezSystem(channel, n_brokers=2)


def build_herd():
    return BrokerHerd(n_brokers=3, wire_budget=1e7)


def describe(edge: EdgeBroker) -> bool:
    return isinstance(edge, EdgeBroker)
