# mezlint: ref-parity: tests.fixtures.mezlint.mz05_good.scale_ref
"""mezlint fixture: MZ05-clean Pallas kernel."""

import functools

import jax
from jax.experimental import pallas as pl


def scale_ref(x, scale):
    return x * scale


def _scale_kernel(x_ref, o_ref, *, scale):
    o_ref[...] = x_ref[...] * scale


def scale_all(x, scale, interpret=False):
    kernel = functools.partial(_scale_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
