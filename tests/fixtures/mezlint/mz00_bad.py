"""mezlint fixture: MZ00 -- a suppression without a justification."""

import jax


def rewrap(fn):
    # mezlint: disable=MZ02
    return jax.jit(fn)
