"""mezlint fixture: MZ04 violations -- f64 leaking into traced code."""

import jax
import jax.numpy as jnp


@jax.jit
def entry(x):
    gain = jnp.asarray(1.5, dtype=jnp.float64)   # explicit f64 in the trace
    y = x.astype("float64")                      # dtype string
    z = x.astype(float)                          # python float == f64
    return gain * y + z
