"""mezlint regression fixture: the pre-PR-2 ``HostLog`` wrap-around race.

This is the host-side log as it stood before the seqlock snapshot fix
(commit 493fa89), trimmed to the locking-relevant methods, with the
``# guarded-by:`` annotations the current code carries.  The bug MZ03
must reproduce: ``point_query``/``range_query`` compute ``order`` under
``_meta_lock``, release it, then ``_timestamps`` reads
``self._entries[i].timestamp`` for the whole ring with NO lock held --
a concurrent wrap-around overwrite hands binary search an unsorted
array.  The per-entry ``_read_entry`` lock afterwards cannot un-tear the
already-scanned timestamps.
"""

import dataclasses
import threading
from typing import Iterator, Sequence

import numpy as np


class _RWLock:
    def __init__(self) -> None:
        self._cond = threading.Condition()
        self._readers = 0           # guarded-by: _cond
        self._writer = False        # guarded-by: _cond
        self._writers_waiting = 0   # guarded-by: _cond

    def acquire_read(self) -> None:
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1

    def release_read(self) -> None:
        with self._cond:
            self._readers -= 1
            if self._readers == 0:
                self._cond.notify_all()

    def acquire_write(self) -> None:
        with self._cond:
            self._writers_waiting += 1
            while self._writer or self._readers:
                self._cond.wait()
            self._writers_waiting -= 1
            self._writer = True

    def release_write(self) -> None:
        with self._cond:
            self._writer = False
            self._cond.notify_all()


@dataclasses.dataclass
class _Entry:
    timestamp: float
    frame: np.ndarray
    meta: dict


class HostLog:
    def __init__(self, capacity: int, *, num_segments: int = 8):
        if capacity < num_segments:
            num_segments = max(1, capacity)
        self.capacity = int(capacity)
        self.num_segments = int(num_segments)
        self._entries = [None] * self.capacity  # guarded-by: _seg_locks
        self._head = 0          # guarded-by: _meta_lock
        self._count = 0         # guarded-by: _meta_lock
        self._last_ts = -np.inf  # guarded-by: _meta_lock
        self._seg_locks = [_RWLock() for _ in range(self.num_segments)]
        self._meta_lock = threading.Lock()
        self.appends = 0        # guarded-by: _meta_lock
        self.rejects = 0        # guarded-by: _meta_lock

    def _segment_of(self, idx: int) -> int:
        return (idx * self.num_segments) // self.capacity

    def append(self, timestamp: float, frame: np.ndarray, **meta) -> bool:
        with self._meta_lock:
            if timestamp <= self._last_ts:
                self.rejects += 1
                return False
            idx = self._head
            seg = self._segment_of(idx)
        lock = self._seg_locks[seg]
        lock.acquire_write()
        try:
            self._entries[idx] = _Entry(timestamp, frame, dict(meta))
        finally:
            lock.release_write()
        with self._meta_lock:
            self._head = (idx + 1) % self.capacity
            self._count = min(self._count + 1, self.capacity)
            self._last_ts = timestamp
            self.appends += 1
        return True

    # holds-lock: _meta_lock
    def _ordered_indices(self) -> list:
        if self._count < self.capacity:
            return list(range(self._count))
        return [(self._head + i) % self.capacity
                for i in range(self.capacity)]

    def _timestamps(self, order: Sequence[int]) -> np.ndarray:
        # THE RACE: the whole-ring timestamp scan takes no lock, so a
        # wrap-around overwrite between _ordered_indices and this read
        # yields an unsorted array for searchsorted.
        return np.asarray([self._entries[i].timestamp for i in order])

    def _read_entry(self, idx: int) -> _Entry:
        seg = self._segment_of(idx)
        lock = self._seg_locks[seg]
        lock.acquire_read()
        try:
            entry = self._entries[idx]
        finally:
            lock.release_read()
        assert entry is not None
        return entry

    def point_query(self, timestamp: float):
        with self._meta_lock:
            order = self._ordered_indices()
        if not order:
            return None
        ts = self._timestamps(order)
        pos = int(np.searchsorted(ts, timestamp, side="right")) - 1
        if pos < 0:
            return None
        entry = self._read_entry(order[pos])
        return entry.timestamp, entry.frame

    def range_query(self, t_start: float, t_stop: float) -> Iterator:
        with self._meta_lock:
            order = self._ordered_indices()
        if not order:
            return
        ts = self._timestamps(order)
        lo = int(np.searchsorted(ts, t_start, side="left"))
        hi = int(np.searchsorted(ts, t_stop, side="right"))
        for i in range(lo, hi):
            entry = self._read_entry(order[i])
            yield entry.timestamp, entry.frame
