"""Runtime substrate: optimizer, checkpointing, pipeline, approx collectives."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.approx_comm import (LEVELS, _quant_roundtrip,
                                    characterize_fidelity, compressed_mean,
                                    make_grad_compressor)
from repro.data.pipeline import BackupFetcher, Prefetcher, TokenStream
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


class TestAdamW:
    def test_quadratic_converges(self):
        cfg = AdamWConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=1)
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = init_opt_state(params)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(150):
            g = jax.grad(loss)(params)
            params, state = adamw_update(cfg, params, g, state)
        assert float(loss(params)) < 1e-3

    def test_grad_clip_bounds_update(self):
        cfg = AdamWConfig(learning_rate=1.0, grad_clip=1e-3, warmup_steps=1,
                          weight_decay=0.0)
        params = {"w": jnp.zeros(4)}
        state = init_opt_state(params)
        huge = {"w": jnp.full(4, 1e9)}
        new, state = adamw_update(cfg, params, huge, state)
        assert float(jnp.abs(new["w"]).max()) < 2.0   # step ~ lr * mhat/sqrt(vhat)

    def test_weight_decay_on_matrices_only(self):
        cfg = AdamWConfig(learning_rate=0.01, weight_decay=0.5, warmup_steps=1)
        params = {"mat": jnp.ones((4, 4)), "vec": jnp.ones(4)}
        state = init_opt_state(params)
        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        new, _ = adamw_update(cfg, params, zeros, state)
        assert float(new["mat"][0, 0]) < 1.0
        np.testing.assert_allclose(np.asarray(new["vec"]), 1.0)


class TestCheckpointer:
    def _tree(self, x=0.0):
        return {"a": {"w": jnp.full((8, 8), 1.0 + x)},
                "b": jnp.arange(16, dtype=jnp.float32) + x}

    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(5, self._tree(1.0), meta={"loss": 3.0})
        restored, step = ck.restore(self._tree())
        assert step == 5
        np.testing.assert_allclose(np.asarray(restored["a"]["w"]), 2.0)

    def test_corruption_falls_back_to_previous(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, self._tree(1.0))
        ck.save(2, self._tree(2.0))
        ck.corrupt(2)
        assert ck.latest_valid_step() == 1
        restored, step = ck.restore(self._tree())
        assert step == 1
        np.testing.assert_allclose(np.asarray(restored["b"])[0], 1.0)

    def test_gc_keeps_last_k(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        for s in range(5):
            ck.save(s, self._tree(float(s)))
        assert ck.steps() == [3, 4]

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        t = ck.save_async(7, self._tree(7.0))
        t.join(timeout=30)
        assert ck.latest_valid_step() == 7

    def test_elastic_restore_with_shardings(self, tmp_path):
        """Restore onto an explicit (1-device) mesh sharding -- the elastic
        path: stored arrays are unsharded, any mesh works."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        ck = Checkpointer(str(tmp_path))
        ck.save(3, self._tree(3.0))
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sh = {"a": {"w": NamedSharding(mesh, P("data", "model"))},
              "b": NamedSharding(mesh, P(None))}
        restored, _ = ck.restore(self._tree(), shardings=sh)
        assert restored["a"]["w"].sharding.mesh.shape["data"] == 1


class TestPipeline:
    def test_token_stream_deterministic(self):
        a = TokenStream(512, 2, 32, seed=3).next_batch()
        b = TokenStream(512, 2, 32, seed=3).next_batch()
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        # next-token alignment
        s = TokenStream(512, 1, 16, seed=0)
        batch = s.next_batch()
        np.testing.assert_array_equal(batch["tokens"][0, 1:],
                                      batch["labels"][0, :-1])

    def test_prefetcher_order_and_completion(self):
        pf = Prefetcher(iter(range(10)), depth=3)
        assert list(pf) == list(range(10))

    def test_prefetcher_propagates_errors(self):
        def gen():
            yield 1
            raise RuntimeError("boom")
        pf = Prefetcher(gen(), depth=2)
        assert next(pf) == 1
        with pytest.raises(RuntimeError, match="boom"):
            next(pf)

    def test_backup_fetcher_hedges_stragglers(self):
        calls = {"n": 0}

        def fetch(i):
            calls["n"] += 1
            # every 5th fetch is a straggler
            if i % 5 == 4 and calls["n"] <= 20:
                time.sleep(0.25)
            else:
                time.sleep(0.005)
            return i

        bf = BackupFetcher(fetch, hedge_factor=3.0, min_history=4)
        out = [bf.fetch(i) for i in range(15)]
        assert out == list(range(15))
        assert bf.hedges_issued >= 1


class TestApproxComm:
    def test_roundtrip_error_small(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (512, 512))
        for bits, tol in ((8, 0.01), (4, 0.15)):
            rt = _quant_roundtrip(x, bits)
            rel = float(jnp.abs(rt - x).max() / jnp.abs(x).max())
            assert rel < tol, (bits, rel)

    def test_fidelity_table_monotone(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(1), (256, 512))}
        fid = characterize_fidelity(g)
        assert fid[16] == 1.0
        assert fid[16] >= fid[8] >= fid[4] > 0.95

    def test_compressed_mean_matches_pmean(self):
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        mesh = jax.make_mesh((1,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(2), (256, 512))

        f = shard_map(lambda v: compressed_mean(v, "pod", 8),
                      mesh=mesh, in_specs=P(), out_specs=P(),
                      check_rep=False)
        out = f(x)
        exact = x  # single member mean = itself (up to quantization)
        assert float(jnp.abs(out - exact).max() /
                     jnp.abs(exact).max()) < 0.01

    def test_grad_compressor_hook(self):
        grads = {"big": jnp.ones((512, 512)) * 0.37,
                 "small": jnp.ones((4,)) * 0.37}
        hook = make_grad_compressor(8, min_size=1024)
        out = hook(grads)
        # small leaves untouched; big leaves quantized (value changes slightly)
        np.testing.assert_array_equal(np.asarray(out["small"]),
                                      np.asarray(grads["small"]))
        assert np.abs(np.asarray(out["big"]) - 0.37).max() < 0.37 / 127

    def test_collective_controller_closed_loop(self):
        """ROADMAP PR 4 follow-up: the compression level is driven by the
        JITTED controller (one-lane ``fleet_controller_step`` on the
        shared ``ControllerParams`` path) -- decisions bit-identical to the
        host PI controller, levels drop under link contention and recover
        after, the fidelity floor governs every feasible decision, and the
        whole run compiles exactly once."""
        from repro.core.approx_comm import (CollectiveController,
                                            collective_bytes_for,
                                            fidelity_table)
        from repro.core.characterization import LatencyRegression
        from repro.core.controller import (ControllerConfig,
                                           LatencyController)
        grad_bytes = 4e6
        fidelity = {16: 1.0, 8: 0.999, 4: 0.985}
        bw = 3e9
        target = 1.5 * grad_bytes / bw
        ctl = CollectiveController(grad_bytes, fidelity,
                                   latency_target=target,
                                   fidelity_floor=0.98, slope=1.0 / bw)
        host = LatencyController(
            ControllerConfig(target, 0.98, error_threshold=0.05 * target),
            fidelity_table(grad_bytes, fidelity),
            LatencyRegression(slope=1.0 / bw, intercept=1e-4))
        bits, used = 16, []
        for step in range(60):
            contention = 8.0 if 20 <= step < 40 else 1.0
            lat = (collective_bytes_for(grad_bytes, bits)
                   / (bw / contention) + 1e-4)
            d = ctl.update(lat)
            dh = host.update(lat)
            assert d.setting_index == dh.setting_index, step
            assert d.acted == dh.acted, step
            assert d.feasible == dh.feasible, step
            if d.feasible and d.setting_index >= 0:
                assert fidelity[d.bits] >= 0.98
            bits = d.bits
            used.append(bits)
        assert min(used[20:40]) < 16       # compressed under contention
        assert used[-1] == 16              # relaxed back to exact transport
        assert ctl.cache_size() == 1
