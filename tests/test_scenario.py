"""Scenario harness: the paper-claim suite (10x latency tolerance), golden
-trace bit-reproducibility, and the scripted-event engine.

Run ``PYTHONPATH=src:. python tests/test_scenario.py`` (from the repo
root) to regenerate the golden trace after a DELIBERATE behavior change
(commit the diff with the change that caused it)."""

import json
import os

import numpy as np
import pytest

from benchmarks.common import synthetic_controller_table as synthetic_table
from repro.core.characterization import characterize
from repro.core.scenario import (CameraCrash, CameraRecover, CameraSpec,
                                 CongestionRamp, DistanceDrift, EdgeCrash,
                                 EdgeRecover, InterferenceSpike, PeerJoin,
                                 PeerLeave, QosChange, ScenarioSpec,
                                 TableRefresh, TenantJoin, TenantLeave,
                                 run_scenario)
from repro.data.camera import CameraConfig, SyntheticCamera

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


@pytest.fixture(scope="module")
def complex_table():
    """The paper's Section 5 operating point: complex dynamics, accuracy
    floor 0.95 (characterized settings all clear the F1 floor)."""
    return characterize(
        lambda: SyntheticCamera(CameraConfig(dynamics="complex", seed=7)),
        clip_len=12, min_accuracy=0.95)


# =============================================================================
# Paper-claim suite: 10x latency inflation absorbed, F1 drop <= 5%
# =============================================================================


def claim_spec(*, controlled: bool = True, fleet: bool = False
               ) -> ScenarioSpec:
    """PAPER.md Section 6: a latency-variation spike of 10x over the
    5-camera testbed, scripted as an external-interference window."""
    return ScenarioSpec(
        name="paper-claim-10x",
        cameras=tuple(CameraSpec(f"cam{i}", dynamics="complex")
                      for i in range(5)),
        frames=60, seed=3, workload="jaad",
        latency=0.100, accuracy=0.95, min_accuracy=0.95,
        controlled=controlled, fleet=fleet, record_decisions=fleet,
        events=(InterferenceSpike(start=4.0, end=9.0, factor=10.0),),
    )


class TestPaperClaim:
    def test_10x_latency_inflation_absorbed(self, complex_table):
        tables = {"complex": complex_table}
        ctl = run_scenario(claim_spec(), tables=tables)
        unc = run_scenario(claim_spec(controlled=False), tables=tables)

        # the script really inflates latency ~10x: the uncontrolled system's
        # spike-window p95 blows up relative to its own settled baseline
        unc_base = unc.p95_latency_ms(2.0, 4.0)
        unc_spike = unc.p95_latency_ms(5.0, 9.0)
        assert unc_spike / unc_base > 8.0

        # Mez absorbs it: F1 drop within the paper's worst case (4.2%,
        # asserted at the issue's 5% bound), every delivered frame holds
        # the 0.95 floor, and the spike-window latency is a fraction of
        # the uncontrolled system's
        base_acc = ctl.mean_accuracy(2.0, 4.0)
        spike_acc = ctl.mean_accuracy(4.5, 9.0)
        assert base_acc > 0
        assert 1.0 - spike_acc / base_acc <= 0.05
        assert ctl.min_accuracy(4.5, 9.0) >= 0.95
        assert ctl.p95_latency_ms(5.0, 9.0) <= 0.45 * unc_spike

        # and recovers: post-spike p95 returns to the target band
        assert ctl.p95_latency_ms(9.5, 12.0) < 130.0
        # feasibility never breaks at the paper operating point
        assert not any(r.infeasible for r in ctl.rows)

    def test_claim_scenario_is_deterministic(self, complex_table):
        a = run_scenario(claim_spec(), tables={"complex": complex_table})
        b = run_scenario(claim_spec(), tables={"complex": complex_table})
        assert a.to_json() == b.to_json()

    def test_claim_scenario_fleet_plane_matches_host(self, complex_table):
        """The SAME claim scenario on the fleet control plane (all cameras
        per poll in one compiled vmapped step) reproduces the host-path
        trace bit for bit, and compiles exactly once."""
        tables = {"complex": complex_table}
        host = run_scenario(claim_spec(), tables=tables)
        flt = run_scenario(claim_spec(fleet=True), tables=tables)
        assert flt.to_json() == host.to_json()
        assert flt.fleet_cache_size == 1
        assert len(flt.fleet_history) > 0


# =============================================================================
# Golden trace: fig11/table3-shaped run, bit-reproducible against a
# committed JSON
# =============================================================================


def golden_spec() -> ScenarioSpec:
    """A compact fig11/table3-shaped closed loop: complex dynamics, jaad
    workload, an interference spike mid-stream.  Synthetic tables keep the
    trace independent of the characterization sweep (and fast)."""
    return ScenarioSpec(
        name="golden-fig11-small",
        cameras=tuple(CameraSpec(f"cam{i}", dynamics="complex")
                      for i in range(3)),
        frames=24, seed=11, workload="jaad",
        latency=0.100, accuracy=0.92,
        events=(InterferenceSpike(start=2.0, end=3.5, factor=6.0),
                QosChange(at=4.0, latency=0.060)),
    )


def golden_tables() -> dict:
    return {"complex": synthetic_table()}


GOLDEN_PATH = os.path.join(GOLDEN_DIR, "scenario_fig11_small.json")


class TestGoldenTrace:
    def test_trace_matches_committed_golden(self):
        result = run_scenario(golden_spec(), tables=golden_tables())
        with open(GOLDEN_PATH) as fh:
            golden = json.load(fh)
        fresh = json.loads(result.to_json())
        assert fresh["rows"] == golden["rows"], (
            "scenario trace diverged from tests/golden/ -- if the change "
            "is deliberate, regenerate via "
            "`PYTHONPATH=src:. python tests/test_scenario.py`")
        assert fresh == golden


def regenerate_golden() -> str:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    result = run_scenario(golden_spec(), tables=golden_tables())
    with open(GOLDEN_PATH, "w") as fh:
        fh.write(result.to_json(indent=1))
        fh.write("\n")
    return GOLDEN_PATH


# =============================================================================
# Multi-tenant golden: a TenantJoin flood through admission control,
# bit-reproducible against a committed JSON
# =============================================================================


def tenant_flood_spec() -> ScenarioSpec:
    """A tenant flood over a budget-capped 2-camera fleet: a gold tenant
    joins and is degraded against the protected (untenanted) main stream,
    a best_effort tenant joins and is pushed to its accuracy floor, a
    second gold join under ``admission="reject"`` is infeasible even fully
    degraded (its floor alone busts the budget) and bounces, and the first
    gold tenant's leave restores the best_effort lane.

    The wire budget (16.5 MB/s) is sized against the synthetic table's
    lane loads: main demand ~9.7 MB/s (protected), gold demand ~8.7 MB/s /
    floor ~5.2 MB/s, best_effort floor ~0.2 MB/s."""
    return ScenarioSpec(
        name="tenant-flood",
        cameras=tuple(CameraSpec(f"cam{i}", dynamics="medium")
                      for i in range(2)),
        frames=20, seed=5, workload="jaad",
        latency=0.100, accuracy=0.92,
        wire_budget=1.65e7,
        events=(
            TenantJoin(at=0.5, tenant="acme", slo="gold"),
            TenantJoin(at=1.0, tenant="bulk", slo="best_effort"),
            TenantJoin(at=1.5, tenant="probe", slo="gold",
                       admission="reject"),
            TenantLeave(at=3.0, tenant="acme"),
        ),
    )


TENANT_GOLDEN_PATH = os.path.join(GOLDEN_DIR, "scenario_tenant_flood.json")


class TestTenantFloodGolden:
    @pytest.fixture(scope="class")
    def flood(self):
        return run_scenario(tenant_flood_spec(), tables=tables())

    def test_trace_matches_committed_golden(self, flood):
        with open(TENANT_GOLDEN_PATH) as fh:
            golden = json.load(fh)
        fresh = json.loads(flood.to_json())
        assert fresh["tenant_stats"] == golden["tenant_stats"], (
            "tenant admission trace diverged from tests/golden/ -- if the "
            "change is deliberate, regenerate via "
            "`PYTHONPATH=src:. python tests/test_scenario.py`")
        assert fresh == golden

    def test_admission_outcomes(self, flood):
        stats = flood.tenant_stats
        assert set(stats) == {"acme", "bulk", "probe"}
        # gold tenant admitted but degraded: the untenanted main stream's
        # demand is protected, so the shortfall lands on the only SLO lane
        assert stats["acme"]["slo"] == "gold"
        assert stats["acme"]["admitted"]
        assert stats["acme"]["delivered"] > 0
        assert 0.0 < stats["acme"]["min_budget_scale"] < 1.0
        # best_effort absorbs first: pushed far below the gold tenant
        assert stats["bulk"]["slo"] == "best_effort"
        assert stats["bulk"]["admitted"]
        assert stats["bulk"]["min_budget_scale"] < \
            stats["acme"]["min_budget_scale"]
        # the second gold join is infeasible even at floor -> rejected
        assert stats["probe"]["admitted"] is False
        assert stats["probe"]["delivered"] == 0

    def test_admission_events_logged(self, flood):
        kinds = [e["kind"] for e in flood.events_log]
        assert "admission_rejected" in kinds
        assert "tenant_degraded" in kinds
        rej = next(e for e in flood.events_log
                   if e["kind"] == "admission_rejected")
        assert rej["tenant"] == "probe"
        joins = [e for e in flood.events_log if e["kind"] == "TenantJoin"]
        assert [(e["tenant"], e["admitted"]) for e in joins] == \
            [("acme", True), ("bulk", True), ("probe", False)]
        leave = next(e for e in flood.events_log
                     if e["kind"] == "TenantLeave")
        assert leave["tenant"] == "acme" and leave["closed"]

    def test_flood_is_deterministic(self, flood):
        again = run_scenario(tenant_flood_spec(), tables=tables())
        assert again.to_json() == flood.to_json()


def regenerate_tenant_golden() -> str:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    result = run_scenario(tenant_flood_spec(), tables=tables())
    with open(TENANT_GOLDEN_PATH, "w") as fh:
        fh.write(result.to_json(indent=1))
        fh.write("\n")
    return TENANT_GOLDEN_PATH


# =============================================================================
# Scripted-event engine
# =============================================================================


def small_spec(**kw) -> ScenarioSpec:
    base = dict(
        name="engine",
        cameras=tuple(CameraSpec(f"cam{i}", dynamics="medium")
                      for i in range(2)),
        frames=16, seed=5, workload="jaad",
        latency=0.100, accuracy=0.92,
    )
    base.update(kw)
    return ScenarioSpec(**base)


TABLES = None


def tables():
    global TABLES
    if TABLES is None:
        TABLES = {"medium": synthetic_table()}
    return TABLES


class TestScenarioEngine:
    def test_camera_crash_recover_delivers_late_not_lost(self):
        spec = small_spec(events=(CameraCrash(at=1.0, camera_id="cam0"),
                                  CameraRecover(at=2.0, camera_id="cam0")))
        res = run_scenario(spec, tables=tables())
        per_cam = {cid: len(res.select(camera_id=cid)) +
                   sum(1 for r in res.rows
                       if r.camera_id == cid and r.dropped)
                   for cid in res.camera_ids}
        # every published frame arrives despite the outage (at-most-once,
        # delivered late rather than lost)
        assert per_cam == {"cam0": 16, "cam1": 16}
        kinds = [e["kind"] for e in res.events_log]
        assert "rpc_timeout" in kinds          # the crash surfaced
        assert any(e.get("kind") == "CameraRecover" and
                   e.get("reattach") == "ok" for e in res.events_log)

    def test_edge_crash_recover_resumes_stream(self):
        spec = small_spec(events=(EdgeCrash(at=1.2), EdgeRecover(at=2.0)))
        res = run_scenario(spec, tables=tables())
        assert len(res.rows) == 32
        assert any(e["kind"] == "RPCTimeout" for e in res.events_log)

    def test_congestion_ramp_inflates_latency(self):
        quiet = run_scenario(small_spec(frames=24), tables=tables())
        ramp = run_scenario(
            small_spec(frames=24,
                       events=(CongestionRamp(start=1.0, end=2.0, peers=4),)),
            tables=tables())
        assert ramp.p95_latency_ms(2.0, 4.8) > quiet.p95_latency_ms(2.0, 4.8)

    def test_peer_churn_changes_contention(self):
        spec = small_spec(frames=24,
                          events=(PeerJoin(at=1.0, node_id="forklift"),
                                  PeerJoin(at=1.2, node_id="agv"),
                                  PeerLeave(at=3.0, node_id="forklift"),
                                  PeerLeave(at=3.0, node_id="agv")))
        churn = run_scenario(spec, tables=tables())
        quiet = run_scenario(small_spec(frames=24), tables=tables())
        assert churn.p95_latency_ms(1.5, 3.0) > quiet.p95_latency_ms(1.5, 3.0)

    def test_distance_drift_applies(self):
        near = run_scenario(small_spec(frames=24), tables=tables())
        far = run_scenario(
            small_spec(frames=24,
                       events=(DistanceDrift("cam0", start=0.0, end=1.0,
                                             to_m=40.0),)),
            tables=tables())
        assert far.p95_latency_ms(2.0, 4.8, camera_id="cam0") > \
            near.p95_latency_ms(2.0, 4.8, camera_id="cam0")

    def test_qos_change_retargets_live_controllers(self):
        spec = small_spec(events=(QosChange(at=1.5, latency=0.042),))
        res = run_scenario(spec, tables=tables())
        assert any(e.get("kind") == "QosChange" and e.get("status") == "ok"
                   for e in res.events_log)
        assert len(res.rows) == 32

    def test_summary_shape(self):
        res = run_scenario(small_spec(), tables=tables())
        s = res.summary()
        assert set(s["per_camera"]) == {"cam0", "cam1"}
        assert s["frames"] == 32
        assert np.isfinite(s["p95_ms"])


@pytest.mark.slow
class TestSoakScenario:
    """Soak-length everything-at-once scenario (dedicated CI job; excluded
    from the default push matrix via the ``slow`` marker)."""

    def test_long_mixed_scenario_survives(self):
        spec = ScenarioSpec(
            name="soak",
            cameras=tuple(CameraSpec(f"cam{i}", dynamics="medium")
                          for i in range(5)),
            frames=200, seed=13, workload="jaad",
            latency=0.100, accuracy=0.92, fleet=True,
            events=(
                CongestionRamp(start=3.0, end=8.0, peers=4, leave_at=14.0),
                InterferenceSpike(start=10.0, end=16.0, factor=8.0),
                DistanceDrift("cam2", start=0.0, end=20.0, to_m=18.0),
                CameraCrash(at=6.0, camera_id="cam4"),
                CameraRecover(at=12.0, camera_id="cam4"),
                EdgeCrash(at=18.0), EdgeRecover(at=19.0),
                QosChange(at=22.0, latency=0.060),
                TableRefresh(at=26.0, camera_id="cam1"),
                QosChange(at=30.0, latency=0.100),
            ),
        )
        res = run_scenario(spec, tables={"medium": synthetic_table()})
        # every published frame accounted for, across every fault
        total = len(res.rows)
        assert total == 5 * 200
        # the fleet step stayed ONE compiled dispatch across the whole
        # timeline -- retargets, a mid-scenario per-camera table refresh,
        # crashes and recoveries included
        assert res.fleet_cache_size == 1
        refreshed = [e for e in res.events_log
                     if e.get("kind") == "TableRefresh"]
        assert refreshed and refreshed[0]["refreshed"] is True


@pytest.mark.slow
class TestOversubscriptionSoak:
    """Soak-length oversubscription: all three SLO classes share a fleet
    whose wire budget cannot fit their aggregate demand (dedicated CI job
    via the ``slow`` marker).

    The acceptance shape: admission control degrades ``best_effort`` lanes
    before ``silver`` before ``gold``, and the gold tenant's MEASURED
    detection F1 (scored against the full-quality pseudo-GT stream) holds
    its accuracy floor throughout."""

    def test_degradation_order_and_gold_floor(self):
        # 3-camera loads against the synthetic table: main (untenanted,
        # protected) ~14.6 MB/s, gold ~7.1 MB/s (nominal == accuracy floor
        # at the 50 ms target, so gold has no slack to take), silver
        # ~14.6 MB/s demand / ~3.4 MB/s floor, best_effort floor
        # ~0.3 MB/s.  Budget 31 MB/s => best_effort pinned at floor,
        # silver partially cut, gold untouched.
        spec = ScenarioSpec(
            name="oversubscription-soak",
            cameras=tuple(CameraSpec(f"cam{i}", dynamics="medium")
                          for i in range(3)),
            frames=120, seed=9, workload="jaad",
            latency=0.100, accuracy=0.92, score_frames=True,
            wire_budget=3.1e7,
            events=(
                TenantJoin(at=1.0, tenant="g", slo="gold"),
                TenantJoin(at=2.0, tenant="s", slo="silver"),
                TenantJoin(at=3.0, tenant="b", slo="best_effort"),
                TenantLeave(at=20.0, tenant="s"),
            ),
        )
        res = run_scenario(spec, tables=tables())
        stats = res.tenant_stats
        assert {n: s["admitted"] for n, s in stats.items()} == \
            {"g": True, "s": True, "b": True}
        assert all(s["delivered"] > 0 for s in stats.values())
        # degradation order: best_effort absorbs the shortfall first (down
        # to its accuracy floor), silver next (partial cut), gold last
        # (never touched)
        assert stats["b"]["min_budget_scale"] < 0.05
        assert stats["b"]["min_budget_scale"] < \
            stats["s"]["min_budget_scale"] < 1.0
        assert stats["g"]["min_budget_scale"] == 1.0
        degraded = {e["tenant"] for e in res.events_log
                    if e["kind"] == "tenant_degraded"}
        assert "b" in degraded and "s" in degraded and "g" not in degraded
        # the gold tenant's measured F1 (vs full-quality pseudo-GT) holds
        # its 0.95 accuracy floor across the whole oversubscribed run
        assert stats["g"]["f1"] >= 0.95
        # every delivered gold frame also claims the floor per the tables
        assert stats["g"]["mean_accuracy"] >= 0.95


if __name__ == "__main__":
    print("wrote", regenerate_golden())
    print("wrote", regenerate_tenant_golden())
