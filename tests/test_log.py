"""In-memory log semantics (paper Section 4.3) + CRC persistence (4.4)."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.log import (FrameLog, HostLog, LogSegmentStore,
                            frame_log_append, frame_log_init,
                            frame_log_point_query, frame_log_range_query)


def _frame(i, shape=(4, 4)):
    return np.full(shape, i % 251, np.uint8)


class TestHostLog:
    def test_append_ordering_and_rejection(self):
        log = HostLog(16)
        assert log.append(1.0, _frame(1))
        assert log.append(2.0, _frame(2))
        # out-of-order and duplicate timestamps are rejected
        assert not log.append(2.0, _frame(3))
        assert not log.append(0.5, _frame(4))
        assert len(log) == 2
        assert log.rejects == 2

    def test_wraparound_overwrites_oldest(self):
        log = HostLog(4)
        for i in range(10):
            log.append(float(i), _frame(i))
        assert len(log) == 4
        ts = [t for t, _ in log.snapshot()]
        assert ts == [6.0, 7.0, 8.0, 9.0]

    def test_point_query_binary_search(self):
        log = HostLog(8)
        for i in range(5):
            log.append(float(2 * i), _frame(i))
        ts, frame = log.point_query(5.0)      # newest <= 5.0 is ts=4.0
        assert ts == 4.0
        assert log.point_query(-1.0) is None
        ts, _ = log.point_query(100.0)
        assert ts == 8.0

    def test_range_query_inclusive(self):
        log = HostLog(16)
        for i in range(10):
            log.append(float(i), _frame(i))
        out = list(log.range_query(2.0, 5.0))
        assert [t for t, _ in out] == [2.0, 3.0, 4.0, 5.0]

    def test_concurrent_readers_single_writer(self):
        log = HostLog(256, num_segments=8)
        stop = threading.Event()
        errors = []

        def reader():
            while not stop.is_set():
                try:
                    for t, f in log.range_query(0, 1e9):
                        assert f is not None
                except Exception as e:  # noqa: BLE001
                    errors.append(e)

        threads = [threading.Thread(target=reader) for _ in range(4)]
        for t in threads:
            t.start()
        for i in range(500):
            log.append(float(i), _frame(i))
        stop.set()
        for t in threads:
            t.join()
        assert not errors
        assert len(log) == 256


class TestPersistence:
    def test_roundtrip(self, tmp_path):
        store = LogSegmentStore(str(tmp_path))
        log = HostLog(64, topic="cam1")
        for i in range(40):
            log.append(float(i), _frame(i, (6, 6)))
        store.persist(log, segment_entries=16)
        restored = store.recover("cam1")
        assert restored is not None
        assert len(restored) == 40
        np.testing.assert_array_equal(restored.snapshot()[0][1], _frame(0, (6, 6)))

    def test_corrupted_segment_discarded(self, tmp_path):
        store = LogSegmentStore(str(tmp_path))
        log = HostLog(64, topic="cam1")
        for i in range(40):
            log.append(float(i), _frame(i, (6, 6)))
        n = store.persist(log, segment_entries=16)
        assert n == 3
        store.corrupt_segment("cam1", 1)
        restored = store.recover("cam1")
        # middle segment (entries 16..31) dropped; recovery keeps the rest
        # but the log rejects out-of-order appends after the gap, so we get
        # segment 0 (0..15) + segment 2 (32..39)
        ts = [t for t, _ in restored.snapshot()]
        assert 16.0 not in ts and 31.0 not in ts
        assert 0.0 in ts and 39.0 in ts


class TestFrameLog:
    def test_append_query_jit(self):
        log = frame_log_init(8, (2, 2))
        append = jax.jit(frame_log_append)
        for i in range(5):
            log = append(log, float(i),
                         jnp.full((2, 2), i, jnp.uint8))
        found, ts, frame = jax.jit(frame_log_point_query)(log, 3.5)
        assert bool(found) and float(ts) == 3.0
        assert int(frame[0, 0]) == 3

    def test_out_of_order_rejected(self):
        log = frame_log_init(8, (2, 2))
        log = frame_log_append(log, 5.0, jnp.ones((2, 2), jnp.uint8))
        log = frame_log_append(log, 4.0, jnp.ones((2, 2), jnp.uint8))
        assert int(log.rejects) == 1
        assert int(log.count) == 1

    def test_wraparound(self):
        log = frame_log_init(4, (1,))
        for i in range(7):
            log = frame_log_append(log, float(i), jnp.asarray([i], jnp.uint8))
        valid, ts, frames = frame_log_range_query(log, 0.0, 100.0, 4)
        assert list(np.asarray(ts)) == [3.0, 4.0, 5.0, 6.0]
        assert all(np.asarray(valid))

    def test_range_query_window(self):
        log = frame_log_init(16, (1,))
        for i in range(10):
            log = frame_log_append(log, float(i), jnp.asarray([i], jnp.uint8))
        valid, ts, frames = frame_log_range_query(log, 2.0, 5.0, 8)
        ts = np.asarray(ts)[np.asarray(valid)]
        np.testing.assert_array_equal(ts, [2.0, 3.0, 4.0, 5.0])


class TestConcurrentTimestampScan:
    def test_range_query_monotone_under_wraparound_writes(self):
        """Regression: ``_timestamps`` used to read entries without the
        segment read locks, so a wrap-around append racing a reader could
        overwrite the oldest slot with the newest timestamp mid-scan and
        hand binary search an unsorted array (misordered range results).
        With the locks held for the scan, every query sees a consistent,
        strictly-increasing view."""
        log = HostLog(32, num_segments=4, topic="race")
        stop = threading.Event()
        errors: list[str] = []

        def writer():
            ts = 0.0
            while not stop.is_set():
                ts += 1.0
                log.append(ts, np.asarray([ts], np.float32))

        def reader():
            while not stop.is_set():
                got = [t for t, _ in log.range_query(-np.inf, np.inf)]
                if any(b <= a for a, b in zip(got, got[1:])):
                    errors.append(f"unsorted range result: {got}")
                    return
                pq = log.point_query(np.inf)
                if pq is not None and got and pq[0] < got[0]:
                    errors.append(f"point query behind range head: "
                                  f"{pq[0]} < {got[0]}")
                    return

        threads = [threading.Thread(target=writer)] + [
            threading.Thread(target=reader) for _ in range(3)]
        for t in threads:
            t.start()
        import time
        time.sleep(0.8)
        stop.set()
        for t in threads:
            t.join(timeout=5.0)
        assert not errors, errors[0]
        assert log.appends > 100
