"""Shared test configuration.

When ``MEZLINT_RACE_GUARD=1`` (the CI slow-soak job), every test runs
with ``HostLog``/``CamBroker`` locks wrapped in the lockset-checking
proxies from ``repro.analysis.race_guard``: exclusion violations,
lock-order cycles, and leaked locks fail the test that produced them.
"""

import pytest

from repro.analysis.race_guard import from_env


@pytest.fixture(autouse=True)
def _race_guard():
    guard = from_env()
    if guard is None:
        yield
        return
    with guard:
        yield
