"""Drift detection + automatic recharacterization (core/drift.py and its
broker/scenario integration).

Covers the issue's property bars -- the detector never fires on a
stationary scene, always fires within one window under a sustained error
step, and hysteresis bounds re-fires -- plus the closed loop: a
``TableStaleness`` injection / ``SceneShift`` regime change is detected and
exactly the drifted cameras re-sweep their tables from live frames, with
the committed golden trace pinning the whole loop bit-for-bit.

Run ``PYTHONPATH=src:. python tests/test_drift.py`` (from the repo root)
to regenerate the golden trace after a DELIBERATE behavior change (commit
the diff with the change that caused it).
"""

import json
import os

import numpy as np
import pytest

from repro.analysis.trace_guard import assert_compiled_once, trace_guard
from repro.core.characterization import characterize
from repro.core.drift import (HI_CEILING, SPREAD_MULTIPLE, DriftConfig,
                              DriftMonitor, DriftParams, drift_init,
                              drift_update, learned_thresholds)
from repro.core.scenario import (CameraSpec, ScenarioSpec, SceneShift,
                                 TableStaleness, run_scenario)
from repro.data.camera import CameraConfig, SyntheticCamera

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN_PATH = os.path.join(GOLDEN_DIR, "scenario_sceneshift_refresh.json")

CFG = DriftConfig(window=8, hi=0.35, lo=0.15, min_samples=4)


def step_sequence(errs, cfg=CFG):
    """Drive one lane through an error sequence; return per-step fire flags."""
    state = drift_init(None, cfg.window)
    params = DriftParams.from_config(cfg)
    fires = []
    for e in errs:
        state, fired, score = drift_update(state, e, True, params)
        fires.append(bool(fired))
    return fires, state


# =============================================================================
# Detector properties, deterministic arm (the hypothesis-randomized
# versions of the first three live in tests/test_properties.py)
# =============================================================================


class TestDriftProperties:
    def test_never_fires_on_stationary_scene(self):
        """False-positive bound: samples at or below hi never fire --
        the windowed mean of values <= hi cannot exceed hi."""
        rng = np.random.default_rng(0)
        fires, _ = step_sequence(rng.uniform(0.0, CFG.hi * 0.98, 60))
        assert not any(fires)

    def test_sustained_step_fires_within_one_window(self):
        """Whatever quiet history the window holds, a sustained error step
        above hi fires within W samples (after W pushes the window holds
        only step samples, so the mean exceeds hi; min_samples <= W)."""
        warmup = [CFG.lo * 0.5] * 30
        fires, _ = step_sequence(warmup + [CFG.hi * 1.05] * CFG.window)
        assert not any(fires[:len(warmup)])
        assert any(fires[len(warmup):])

    def test_hysteresis_no_flapping_without_recovery(self):
        """Once fired, the lane disarms; it re-arms only after the
        windowed score drops below lo.  A sequence that never scores
        below lo fires at most once."""
        rng = np.random.default_rng(1)
        fires, state = step_sequence(
            rng.uniform(CFG.lo * 1.05, 5.0, 120))
        assert sum(fires) == 1
        assert not bool(state.armed)

    def test_refires_after_genuine_recovery(self):
        """The hysteresis cycle: fire -> recover below lo (re-arm) ->
        a SECOND sustained step fires again.  Exactly two fires."""
        w, ms = CFG.window, CFG.min_samples
        errs = [1.0] * ms            # first regime shift -> fire
        errs += [0.01] * w           # refreshed tables: residuals collapse
        errs += [1.0] * w            # second regime shift -> fire again
        fires, _ = step_sequence(errs)
        assert sum(fires) == 2
        assert fires[ms - 1]                       # fired ASAP the first time

    def test_fire_requires_min_samples(self):
        fires, _ = step_sequence([10.0] * (CFG.min_samples - 1))
        assert not any(fires)

    def test_invalid_observations_hold_the_lane(self):
        state = drift_init(None, CFG.window)
        params = DriftParams.from_config(CFG)
        for _ in range(20):
            state, fired, _ = drift_update(state, 99.0, False, params)
            assert not bool(fired)
        assert int(state.count) == 0


# =============================================================================
# Learned hysteresis thresholds (satellite: quantile-based hi/lo from the
# calibration clip's residual spread, constants as the floor/fallback)
# =============================================================================


class TestLearnedThresholds:
    def test_degenerate_spread_falls_back_to_constants(self):
        base = DriftConfig()
        for spread in (None, 0.0, -1.0, float("nan"), float("inf")):
            assert learned_thresholds(spread, base) == (base.hi, base.lo)

    def test_quiet_clip_floors_at_the_proven_constants(self):
        """A clean calibration clip (spread well under hi/SPREAD_MULTIPLE)
        keeps the hand-set 0.35/0.15 hysteresis exactly -- which is why the
        committed golden traces are unaffected by learning."""
        base = DriftConfig()
        assert learned_thresholds(0.01, base) == (base.hi, base.lo)
        assert learned_thresholds(base.hi / SPREAD_MULTIPLE * 0.999,
                                  base) == (base.hi, base.lo)

    def test_noisy_clip_raises_its_own_bar_keeping_the_ratio(self):
        base = DriftConfig()
        hi, lo = learned_thresholds(0.2, base)
        assert hi == pytest.approx(SPREAD_MULTIPLE * 0.2)
        assert lo / hi == pytest.approx(base.lo / base.hi)

    def test_ceiling_stays_below_regime_shift_scale(self):
        hi, _ = learned_thresholds(10.0)
        assert hi == HI_CEILING < 1.0

    def test_monitor_learns_per_lane_params_from_spreads(self):
        base = DriftConfig()
        m = DriftMonitor(["a", "b", "c"],
                         spreads={"a": 0.2, "b": None, "c": 0.001})
        np.testing.assert_allclose(
            np.asarray(m.params.hi),
            [SPREAD_MULTIPLE * 0.2, base.hi, base.hi], rtol=1e-6)
        assert m.thresholds["a"][0] == pytest.approx(SPREAD_MULTIPLE * 0.2)
        assert m.thresholds["b"] == (base.hi, base.lo)

    def test_explicit_config_disables_learning(self):
        m = DriftMonitor(["a"], CFG, spreads={"a": 0.5})
        assert m.thresholds["a"] == (CFG.hi, CFG.lo)
        assert float(m.params.hi[0]) == pytest.approx(CFG.hi)

    def test_characterized_tables_carry_a_quiet_spread(self, simple_tables):
        """End to end: ``characterize`` measures each clip's residual
        spread, and on the standard synthetic clips it lands far enough
        under the floor that learning == the proven constants."""
        base = DriftConfig()
        for tbl in simple_tables.values():
            assert tbl.residual_spread is not None
            assert 0.0 < tbl.residual_spread < base.hi / SPREAD_MULTIPLE
            assert learned_thresholds(tbl.residual_spread) == (base.hi,
                                                               base.lo)


# =============================================================================
# The vectorized monitor
# =============================================================================


class TestDriftMonitor:
    def test_flags_exactly_the_drifted_lanes_one_compile(self):
        cams = [f"cam{i:02d}" for i in range(16)]
        m = DriftMonitor(cams, CFG)
        drifted = {"cam03", "cam11"}
        fired_total = set()
        with trace_guard(m):
            for _ in range(CFG.window):
                samples = {c: (1.0 if c in drifted else 0.02) for c in cams}
                fired_total |= set(m.observe(samples))
        assert fired_total == drifted
        counts = m.fire_counts()
        assert all(counts[c] == (1 if c in drifted else 0) for c in cams)

    def test_partial_and_unknown_samples(self):
        m = DriftMonitor(["a", "b"], CFG)
        for _ in range(CFG.window):
            fired = m.observe({"a": 5.0, "ghost": 5.0})   # b holds, ghost
            pass                                          # is ignored
        assert m.fire_counts() == {"a": 1, "b": 0}

    def test_threshold_changes_do_not_retrace(self):
        m = DriftMonitor(["a"], CFG)
        with trace_guard(m):
            m.observe({"a": 0.1})
            m.params = DriftParams.from_config(
                DriftConfig(window=CFG.window, hi=0.9, lo=0.4), n=1)
            m.observe({"a": 0.1})


# =============================================================================
# Closed loop: broker integration via the scenario harness
# =============================================================================


@pytest.fixture(scope="module")
def simple_tables():
    """Per-camera tables characterized on each camera's OWN stream (a
    shared table is already mildly stale for the other cameras, which
    would fire the monitor before the scripted event)."""
    def table(cid):
        return characterize(
            lambda: SyntheticCamera(CameraConfig(
                camera_id=cid, dynamics="simple", seed=7)),
            clip_len=10, min_accuracy=0.90)
    return {cid: table(cid) for cid in ("cam0", "cam1")}


def _spec(**kw):
    base = dict(
        name="drift",
        cameras=tuple(CameraSpec(f"cam{i}", dynamics="simple")
                      for i in range(2)),
        frames=40, seed=5, workload="jaad",
        latency=0.100, accuracy=0.95, min_accuracy=0.90,
        auto_recharacterize=True,
    )
    base.update(kw)
    return ScenarioSpec(**base)


class TestAutoRecharacterization:
    def test_staleness_injection_refreshes_exactly_that_camera(
            self, simple_tables):
        res = run_scenario(
            _spec(events=(TableStaleness(at=2.0, camera_id="cam0",
                                         factor=0.5),)),
            tables=simple_tables)
        refreshed = [e for e in res.events_log
                     if e["kind"] == "table_refresh"]
        assert refreshed, res.events_log
        assert {e["camera_id"] for e in refreshed} == {"cam0"}
        assert all("re-swept" in e["detail"] for e in refreshed)
        inject = [e for e in res.events_log
                  if e["kind"] == "TableStaleness"]
        assert inject and inject[0]["stale"] is True
        # the refresh landed AFTER the injection, detected from the stream
        assert min(e["t"] for e in refreshed) > 2.0
        assert res.drift_fire_counts == {"cam0": 1, "cam1": 0}
        assert_compiled_once(res.drift_cache_size, "drift step")

    def test_scene_shift_detected_and_tables_governed_live(
            self, simple_tables):
        """simple -> complex movers on cam1: the activity channel fires,
        cam1 re-sweeps from its own live frames, cam0 is untouched."""
        spec = _spec(events=(SceneShift(at=3.0, camera_id="cam1",
                                        dynamics="complex"),),
                     frames=50)
        res = run_scenario(spec, tables=simple_tables)
        shift = [e for e in res.events_log if e["kind"] == "SceneShift"]
        assert shift and shift[0]["camera_id"] == "cam1"
        refreshed = [e for e in res.events_log
                     if e["kind"] == "table_refresh"]
        assert refreshed, res.events_log
        assert {e["camera_id"] for e in refreshed} == {"cam1"}
        assert min(e["t"] for e in refreshed) > 3.0
        assert res.drift_fire_counts["cam0"] == 0
        assert res.drift_fire_counts["cam1"] >= 1

    def test_without_auto_recharacterize_nothing_fires(self, simple_tables):
        res = run_scenario(
            _spec(auto_recharacterize=False,
                  events=(TableStaleness(at=2.0, camera_id="cam0",
                                         factor=0.5),)),
            tables=simple_tables)
        assert res.drift_fire_counts is None
        assert not [e for e in res.events_log
                    if e["kind"] == "table_refresh"]

    def test_stationary_run_never_refreshes(self, simple_tables):
        """The false-positive bound end to end: per-camera calibrated
        tables on an unchanged scene -- the monitor stays quiet for the
        whole stream."""
        res = run_scenario(_spec(frames=50), tables=simple_tables)
        assert res.drift_fire_counts == {"cam0": 0, "cam1": 0}
        assert not [e for e in res.events_log
                    if e["kind"] == "table_refresh"]


class TestBrokerDriftSurface:
    def test_subscription_drift_accessor_and_validation(self, simple_tables):
        from repro.core.broker import MezSystem
        from repro.core.channel import calibrated_channel
        from repro.core.characterization import fit_latency_regression
        from repro.core.session import MezClient
        sys_ = MezSystem(calibrated_channel(seed=1, workload="jaad"))
        cam = sys_.add_camera("cam0")
        src = SyntheticCamera(CameraConfig(camera_id="cam0",
                                           dynamics="simple", seed=7))
        cam.background = src.background
        tbl = simple_tables["cam0"]
        sizes = np.linspace(tbl.sizes_sorted[0], tbl.sizes_sorted[-1], 8)
        reg = fit_latency_regression(
            sizes, sys_.channel.regression_points(sizes, n=1))
        cam.set_target(0.1, 0.9, tbl, reg)
        for ts, f, _ in src.stream(8):
            cam.publish(ts, f)
        client = MezClient(sys_)
        with client.open_session("app") as sess:
            with pytest.raises(ValueError, match="auto_recharacterize"):
                sess.subscribe("cam0", 0, 2, latency=0.1, accuracy=0.9,
                               controlled=False, auto_recharacterize=True)
            sub = sess.subscribe("cam0", 0, 2, latency=0.1, accuracy=0.9,
                                 auto_recharacterize=True,
                                 drift_config=DriftConfig(window=4))
            mon = sys_.edge.subscription_drift(sub.subscription_id)
            assert mon is not None and mon.cam_ids == ["cam0"]
            assert mon.config.window == 4
            plain = sess.subscribe("cam0", 0, 2, latency=0.1, accuracy=0.9)
            assert sys_.edge.subscription_drift(plain.subscription_id) is None

    def test_inject_table_staleness_contract(self, simple_tables):
        """The fault injection follows the hot-swap contract: size axis
        scaled, accuracy kept, jit twin + version bumped, PI integral
        carried, proxy dropped."""
        from repro.core.broker import MezSystem
        from repro.core.channel import calibrated_channel
        from repro.core.characterization import fit_latency_regression
        sys_ = MezSystem(calibrated_channel(seed=1, workload="jaad"))
        cam = sys_.add_camera("cam0")
        tbl = simple_tables["cam0"]
        sizes = np.linspace(tbl.sizes_sorted[0], tbl.sizes_sorted[-1], 8)
        reg = fit_latency_regression(
            sizes, sys_.channel.regression_points(sizes, n=1))
        cam.set_target(0.1, 0.9, tbl, reg)
        cam.controller.update(0.4)              # accumulate PI state
        integral = cam.controller.integral
        v = cam.table_version
        assert cam.inject_table_staleness(0.5) is True
        live = cam.controller.table
        np.testing.assert_allclose(live.sizes_sorted,
                                   tbl.sizes_sorted * 0.5)
        np.testing.assert_array_equal(live.acc_by_setting,
                                      tbl.acc_by_setting)
        assert live.proxy is None
        assert live.source == "stale-injected"
        assert live.activity == tbl.activity
        assert cam.controller.integral == integral
        assert cam.table_version == v + 1


# =============================================================================
# Golden trace: seeded SceneShift + auto-refresh, bit-reproducible
# =============================================================================


def golden_spec() -> ScenarioSpec:
    return ScenarioSpec(
        name="golden-sceneshift-refresh",
        cameras=(CameraSpec("cam0", dynamics="simple"),
                 CameraSpec("cam1", dynamics="simple")),
        frames=30, seed=17, workload="jaad",
        latency=0.100, accuracy=0.95, min_accuracy=0.90,
        auto_recharacterize=True,
        events=(SceneShift(at=2.0, camera_id="cam1", dynamics="complex"),),
    )


def golden_tables():
    def table(cid):
        return characterize(
            lambda: SyntheticCamera(CameraConfig(
                camera_id=cid, dynamics="simple", seed=7)),
            clip_len=10, min_accuracy=0.90)
    return {cid: table(cid) for cid in ("cam0", "cam1")}


class TestGoldenDriftTrace:
    def test_trace_matches_committed_golden(self):
        result = run_scenario(golden_spec(), tables=golden_tables())
        with open(GOLDEN_PATH) as fh:
            golden = json.load(fh)
        fresh = json.loads(result.to_json())
        assert fresh["rows"] == golden["rows"], (
            "SceneShift+auto-refresh trace diverged from tests/golden/ -- "
            "if the change is deliberate, regenerate via "
            "`PYTHONPATH=src:. python tests/test_drift.py`")
        assert fresh == golden
        # the committed trace must actually contain the drift loop firing
        assert any(e["kind"] == "table_refresh" and "re-swept" in e["detail"]
                   for e in golden["events"])


def regenerate_golden() -> str:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    result = run_scenario(golden_spec(), tables=golden_tables())
    with open(GOLDEN_PATH, "w") as fh:
        fh.write(result.to_json(indent=1))
        fh.write("\n")
    return GOLDEN_PATH


# =============================================================================
# Soak variant (dedicated CI job via the slow marker)
# =============================================================================


@pytest.mark.slow
class TestDriftSoak:
    def test_long_shift_heavy_scenario_survives(self):
        """Soak: repeated regime shifts + a staleness injection + channel
        stress on the fleet control plane with the drift loop armed --
        every frame accounted for, both compiled steps stay at one
        variant, and every shifted/injected camera re-swept at least
        once."""
        tables = {
            cid: characterize(
                lambda cid=cid: SyntheticCamera(CameraConfig(
                    camera_id=cid, dynamics="simple", seed=7)),
                clip_len=10, min_accuracy=0.90)
            for cid in ("cam0", "cam1", "cam2")
        }
        from repro.core.scenario import CongestionRamp, InterferenceSpike
        spec = ScenarioSpec(
            name="drift-soak",
            cameras=tuple(CameraSpec(f"cam{i}", dynamics="simple")
                          for i in range(3)),
            frames=160, seed=23, workload="jaad",
            latency=0.100, accuracy=0.95, min_accuracy=0.90,
            fleet=True, auto_recharacterize=True,
            events=(
                SceneShift(at=4.0, camera_id="cam0", dynamics="complex"),
                InterferenceSpike(start=8.0, end=12.0, factor=6.0),
                TableStaleness(at=14.0, camera_id="cam1", factor=0.5),
                SceneShift(at=20.0, camera_id="cam2", dynamics="medium"),
                CongestionRamp(start=22.0, end=26.0, peers=3, leave_at=28.0),
            ),
        )
        res = run_scenario(spec, tables=tables)
        assert len(res.rows) == 3 * 160
        assert_compiled_once(res.fleet_cache_size, "fleet step")
        assert_compiled_once(res.drift_cache_size, "drift step")
        refreshed = {e["camera_id"] for e in res.events_log
                     if e["kind"] == "table_refresh"
                     and "re-swept" in e["detail"]}
        assert refreshed >= {"cam0", "cam1", "cam2"}


if __name__ == "__main__":
    print("wrote", regenerate_golden())
