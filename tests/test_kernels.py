"""Per-kernel allclose vs ref.py oracles, shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.frame_knobs import frame_knobs
from repro.kernels.linear_scan import wkv_linear_scan
from repro.kernels.quantize import dequantize_blocks, quantize_blocks
from repro.models.attention import repeat_kv

KEY = jax.random.PRNGKey(42)


def rand(i, shape, dtype=jnp.float32, scale=0.5):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape) * scale
            ).astype(dtype)


class TestQuantize:
    @pytest.mark.parametrize("shape", [(256, 512), (512, 1024), (256, 1536)])
    @pytest.mark.parametrize("bits", [8, 4])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, bits, dtype):
        x = rand(0, shape, dtype)
        q, s = quantize_blocks(x, bits=bits, interpret=True)
        qr, sr = ref.quantize_ref(x, bits=bits)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
        # exact except at half-integer ties, where XLA's reciprocal-multiply
        # division may land one level away (bounded by 1 quantization step)
        d = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
        assert d.max() <= 1 and (d != 0).mean() < 0.01
        xd = dequantize_blocks(q, s, interpret=True)
        xdr = ref.dequantize_ref(qr, sr)
        step = np.repeat(np.repeat(np.asarray(sr), min(256, x.shape[0]), 0),
                         min(512, x.shape[1]), 1)
        assert np.abs(np.asarray(xd) - np.asarray(xdr)).max() <= step.max() + 1e-7

    def test_roundtrip_error_bound(self):
        """|dequant(x) - x| <= scale/2 per block (symmetric rounding)."""
        x = rand(1, (256, 512))
        q, s = quantize_blocks(x, interpret=True)
        xd = dequantize_blocks(q, s, interpret=True)
        err = jnp.abs(xd - x)
        bound = jnp.repeat(jnp.repeat(s, 256, 0), 512, 1) * 0.5 + 1e-7
        assert bool((err <= bound).all())

    def test_int4_levels(self):
        x = rand(2, (256, 512))
        q, _ = quantize_blocks(x, bits=4, interpret=True)
        assert int(jnp.abs(q).max()) <= 7


class TestFlashAttention:
    @pytest.mark.parametrize("s,qh,kh,d", [
        (256, 8, 8, 64),    # MHA
        (256, 8, 2, 64),    # GQA
        (320, 4, 1, 32),    # MQA, padded seq
        (128, 8, 8, 128),
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, s, qh, kh, d, causal):
        q = rand(3, (2, s, qh, d))
        k = rand(4, (2, s, kh, d))
        v = rand(5, (2, s, kh, d))
        out = flash_attention(q, k, v, causal=causal, block_q=128,
                              block_k=128, interpret=True)
        exp = ref.flash_attention_ref(q, repeat_kv(k, qh), repeat_kv(v, qh),
                                      causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=3e-5, atol=3e-5)

    def test_bf16(self):
        q = rand(6, (1, 128, 4, 64), jnp.bfloat16)
        k = rand(7, (1, 128, 4, 64), jnp.bfloat16)
        v = rand(8, (1, 128, 4, 64), jnp.bfloat16)
        out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
        exp = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32),
                                   rtol=2e-2, atol=2e-2)


class TestDecodeAttention:
    @pytest.mark.parametrize("smax,qh,kh,d,length", [
        (512, 8, 8, 64, 512), (512, 8, 2, 64, 300), (1024, 4, 1, 128, 7),
    ])
    def test_matches_ref(self, smax, qh, kh, d, length):
        q = rand(9, (2, 1, qh, d))
        kc = rand(10, (2, smax, kh, d))
        vc = rand(11, (2, smax, kh, d))
        ln = jnp.asarray(length, jnp.int32)
        out = decode_attention(q, kc, vc, ln, block_k=128, interpret=True)
        exp = ref.decode_attention_ref(q, repeat_kv(kc, qh),
                                       repeat_kv(vc, qh), ln)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=3e-5, atol=3e-5)


class TestLinearScan:
    @pytest.mark.parametrize("s,h,kd,bt", [(64, 2, 16, 16), (128, 3, 32, 32),
                                           (96, 1, 64, 96)])
    def test_matches_ref(self, s, h, kd, bt):
        r = rand(12, (2, s, h, kd))
        k = rand(13, (2, s, h, kd))
        v = rand(14, (2, s, h, kd))
        logw = -jnp.exp(rand(15, (2, s, h, kd)) - 2.0)
        u = rand(16, (h, kd))
        y, st = wkv_linear_scan(r, k, v, logw, u, block_t=bt, interpret=True)
        yr, sr = ref.wkv_ref(r, k, v, logw, u)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                                   rtol=3e-4, atol=3e-4)

    def test_state_carry_composes(self):
        """Running two halves with carried state == one full run."""
        r = rand(17, (1, 64, 2, 16)); k = rand(18, (1, 64, 2, 16))
        v = rand(19, (1, 64, 2, 16))
        logw = -jnp.exp(rand(20, (1, 64, 2, 16)) - 2.0)
        u = rand(21, (2, 16))
        y_full, st_full = ref.wkv_ref(r, k, v, logw, u)
        y1, st1 = ref.wkv_ref(r[:, :32], k[:, :32], v[:, :32], logw[:, :32], u)
        y2, st2 = ref.wkv_ref(r[:, 32:], k[:, 32:], v[:, 32:], logw[:, 32:],
                              u, state0=st1)
        np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, 32:]),
                                   rtol=1e-5, atol=1e-6)


class TestFrameKnobs:
    @pytest.mark.parametrize("h,w,blur", [(64, 128, 5), (48, 96, 3),
                                          (64, 128, 1)])
    def test_matches_ref(self, h, w, blur):
        f = (rand(22, (3, h, w), scale=60.0) + 128).clip(0, 255)
        p = (rand(23, (3, h, w), scale=60.0) + 128).clip(0, 255)
        out, ch = frame_knobs(f, p, blur_k=blur, interpret=True)
        outr, chr_ = ref.frame_knobs_ref(f, p, blur_k=blur)
        np.testing.assert_allclose(np.asarray(out), np.asarray(outr),
                                   rtol=1e-5, atol=1e-3)
        np.testing.assert_allclose(np.asarray(ch), np.asarray(chr_),
                                   rtol=1e-6, atol=1e-7)

    def test_change_metric_detects_motion(self):
        base = jnp.full((1, 32, 64), 100.0)
        moved = base.at[0, 8:16, 20:40].set(200.0)
        _, ch_same = frame_knobs(base, base, interpret=True)
        _, ch_moved = frame_knobs(moved, base, interpret=True)
        assert float(ch_same[0]) == 0.0
        np.testing.assert_allclose(float(ch_moved[0]), (8 * 20) / (32 * 64),
                                   rtol=1e-6)


class TestFrameKnobGrid:
    """Oracle sweep for the generalized grid kernel: every (resolution,
    colorspace) plan, all blur widths batched, interpret mode vs
    ``ref.frame_knob_grid_ref`` (bit-exact) and vs the float64 NumPy host
    pipeline ``knobs.transform_frame`` (within one grey level)."""

    H, W, F = 32, 48, 2

    @pytest.fixture(scope="class")
    def clip(self):
        rng = np.random.default_rng(11)
        base = rng.integers(40, 200, (self.H, self.W, 3))
        frames = np.clip(base[None] + rng.normal(0, 12, (self.F, self.H,
                                                         self.W, 3)),
                         0, 255).astype(np.uint8)
        prev = np.concatenate([frames[:1], frames[:-1]])
        return frames, prev

    @pytest.mark.parametrize("res", range(5))
    @pytest.mark.parametrize("cs", range(3))
    def test_matches_ref_and_numpy(self, clip, res, cs):
        from repro.core import knobs as K
        from repro.kernels.frame_knobs import build_transform_plan, \
            frame_knob_grid

        frames, prev = clip
        plan = build_transform_plan(
            self.H, self.W, scale=K.RESOLUTION_SCALES[res], cs=cs,
            blur_ks=K.BLUR_KERNELS)
        pk, fk, ck = frame_knob_grid(jnp.asarray(frames), jnp.asarray(prev),
                                     plan, interpret=True)
        pr, fr, cr = ref.frame_knob_grid_ref(jnp.asarray(frames),
                                             jnp.asarray(prev), plan)
        # bit-exact against the oracle
        np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
        np.testing.assert_array_equal(np.asarray(fk), np.asarray(fr))
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        # one grey level of the float64 host pipeline (f32 vs f64 rounding)
        for b in range(len(K.BLUR_KERNELS)):
            for fi in range(self.F):
                want = K.transform_frame(frames[fi], K.KnobSetting(res, cs, b))
                got = np.asarray(pk)[b, fi]
                got = np.moveaxis(got, 0, -1) if cs == 0 else got[0]
                assert got.shape == want.shape
                d = np.abs(got.astype(np.int32) - want.astype(np.int32))
                assert d.max() <= 1
                assert (d != 0).mean() < 0.01

    def test_change_metric_matches_frame_difference(self, clip):
        from repro.core import knobs as K
        from repro.kernels.frame_knobs import build_transform_plan, \
            frame_knob_grid

        frames, prev = clip
        plan = build_transform_plan(self.H, self.W, scale=1.0, cs=1,
                                    blur_ks=(0,))
        _, _, ch = frame_knob_grid(jnp.asarray(frames), jnp.asarray(prev),
                                   plan, interpret=True)
        # knob5 semantics: the kernel's fraction drives the same drop
        # decision as the host frame_difference at every threshold
        for fi in range(1, self.F):
            frac = float(np.asarray(ch)[0, fi])
            for thresh in K.DIFF_THRESHOLDS:
                want = K.frame_difference(frames[fi], prev[fi], thresh)
                got = thresh >= 0.0 and frac <= thresh
                assert got == want


class TestFrameKnobGridArtifact:
    """knob4 (artifact removal / background subtraction) as a device-side
    per-setting operator: interpret-mode kernel vs ``frame_knob_grid_ref``
    (bit-exact) and vs the host ``knobs.apply_knobs`` pipeline (within one
    grey level), with the per-frame enable gating the characterization
    engine relies on."""

    H, W, F = 32, 48, 3

    @pytest.fixture(scope="class")
    def scene(self):
        rng = np.random.default_rng(23)
        base = rng.integers(40, 200, (self.H, self.W, 3))
        bg = np.clip(base + rng.normal(0, 2, base.shape), 0,
                     255).astype(np.uint8)
        frames = np.clip(base[None] + rng.normal(0, 10, (self.F, self.H,
                                                         self.W, 3)),
                         0, 255).astype(np.uint8)
        frames[1, 8:16, 10:22] = 245            # a bright mover
        frames[2, 20:28, 30:42] = 8             # a dark mover
        prev = np.concatenate([frames[:1], frames[:-1]])
        return frames, prev, bg

    @pytest.mark.parametrize("res,cs", [(0, 0), (2, 1), (1, 2), (4, 0)])
    def test_matches_ref_and_numpy(self, scene, res, cs):
        from repro.core import knobs as K
        from repro.kernels.frame_knobs import build_transform_plan, \
            frame_knob_grid

        frames, prev, bg = scene
        plan = build_transform_plan(
            self.H, self.W, scale=K.RESOLUTION_SCALES[res], cs=cs,
            blur_ks=(0, 5, 10), art_modes=(0, 1, 2))
        pk, fk, ck = frame_knob_grid(jnp.asarray(frames), jnp.asarray(prev),
                                     plan, background=jnp.asarray(bg),
                                     interpret=True)
        pr, fr, cr = ref.frame_knob_grid_ref(
            jnp.asarray(frames), jnp.asarray(prev), plan,
            background=jnp.asarray(bg))
        np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
        np.testing.assert_array_equal(np.asarray(fk), np.asarray(fr))
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        # vs the host pipeline: artifact removal then transform, one grey
        n_blur = 3
        for a in range(3):
            for b in range(n_blur):
                si = a * n_blur + b
                for fi in range(self.F):
                    s = K.KnobSetting(res, cs, [0, 1, 3][b], a, 0)
                    r = K.apply_knobs(frames[fi], s, background=bg,
                                      last_sent=None)
                    got = np.asarray(pk)[si, fi]
                    got = np.moveaxis(got, 0, -1) if cs == 0 else got[0]
                    assert got.shape == r.frame.shape
                    d = np.abs(got.astype(np.int32)
                               - r.frame.astype(np.int32))
                    assert d.max() <= 1
                    assert (d != 0).mean() < 0.02

    def test_enable_gates_artifact_per_frame(self, scene):
        from repro.core import knobs as K
        from repro.kernels.frame_knobs import build_transform_plan, \
            frame_knob_grid

        frames, prev, bg = scene
        plan = build_transform_plan(self.H, self.W, scale=1.0, cs=1,
                                    blur_ks=(0,), art_modes=(1,))
        enable = np.asarray([0, 1, 1], np.int32)
        pk, _, _ = frame_knob_grid(jnp.asarray(frames), jnp.asarray(prev),
                                   plan, background=jnp.asarray(bg),
                                   art_enable=jnp.asarray(enable),
                                   interpret=True)
        # frame 0: knob4 disabled -> plain transform of the raw frame
        want = K.transform_frame(frames[0], K.KnobSetting(0, 1, 0))
        d = np.abs(np.asarray(pk)[0, 0, 0].astype(np.int32)
                   - want.astype(np.int32))
        assert d.max() <= 1
        # frames 1/2: knob4 live -> static background zeroed
        assert (np.asarray(pk)[0, 1] == 0).mean() > 0.5

    def test_artifact_plan_requires_background(self, scene):
        from repro.kernels.frame_knobs import build_transform_plan, \
            frame_knob_grid

        frames, prev, _ = scene
        plan = build_transform_plan(self.H, self.W, scale=1.0, cs=0,
                                    blur_ks=(0,), art_modes=(0, 1))
        with pytest.raises(ValueError, match="background"):
            frame_knob_grid(jnp.asarray(frames), jnp.asarray(prev), plan,
                            interpret=True)
