"""mezlint: known-good / known-bad fixtures per rule, CLI exit codes,
baseline mechanics, and the runtime guards (trace_guard / race_guard)."""

import json
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import race_guard as rg
from repro.analysis.mezlint import main, run_paths
from repro.analysis.trace_guard import (TraceGuardError, assert_compiled_once,
                                        trace_guard)

ROOT = Path(__file__).resolve().parent.parent
FIXDIR = ROOT / "tests" / "fixtures" / "mezlint"


def lint(name: str):
    return run_paths([str(FIXDIR / name)])


def rules_of(findings):
    return {f.rule for f in findings}


# =============================================================================
# Static rules on fixtures
# =============================================================================


class TestRuleFixtures:
    @pytest.mark.parametrize("name,rule", [
        ("mz00_bad.py", "MZ00"),
        ("mz01_bad.py", "MZ01"),
        ("mz02_bad.py", "MZ02"),
        ("mz03_bad.py", "MZ03"),
        ("mz04_bad.py", "MZ04"),
        ("mz05_bad.py", "MZ05"),
        ("mz06_bad.py", "MZ06"),
        ("mz07_bad.py", "MZ07"),
        ("mz08_bad.py", "MZ08"),
    ])
    def test_bad_fixture_triggers_rule(self, name, rule):
        assert rule in rules_of(lint(name))

    @pytest.mark.parametrize("name", [
        "mz01_good.py", "mz02_good.py", "mz03_good.py", "mz04_good.py",
        "mz05_good.py", "mz06_good.py", "mz07_good.py", "mz08_good.py",
    ])
    def test_good_fixture_is_clean(self, name):
        assert lint(name) == []

    def test_mz01_flags_each_sync_kind(self):
        details = {f.detail for f in lint("mz01_bad.py")}
        assert any(d.startswith("branch:if") for d in details)
        assert any(d.startswith("cast:float") for d in details)
        assert "sync:item" in details
        assert "host-call:np.abs" in details

    def test_mz02_flags_each_smell(self):
        details = {f.detail for f in lint("mz02_bad.py")}
        assert any(d.startswith("jit-wrap") for d in details)
        assert any(d.startswith("loop-static:topk_sum.k") for d in details)
        assert any(d.startswith("from_table") for d in details)

    def test_mz03_caller_side_holds_lock(self):
        details = {f.detail for f in lint("mz03_bad.py")}
        assert "call-unlocked:_reset_unsafe@Counter.reset" in details

    def test_mz06_flags_each_application_site(self):
        details = {f.detail for f in lint("mz06_bad.py")}
        assert any("setting_for" in d for d in details)
        assert any("ControlDecision" in d for d in details)
        assert any("update" in d for d in details)

    def test_mz07_flags_legacy_kwargs_and_star_forwarding(self):
        details = {f.detail for f in lint("mz07_bad.py")}
        assert any(d.startswith("legacy-kwargs:controlled,feedback_window,"
                                "fleet") for d in details)
        assert any(d.startswith("legacy-kwargs:slo,tenant") for d in details)
        assert any(d.startswith("star-kwargs") for d in details)

    def test_mz08_flags_every_construction_spelling(self):
        findings = [f for f in lint("mz08_bad.py") if f.rule == "MZ08"]
        # module-scope, helper-function, and module-alias spellings
        assert len(findings) == 3
        scopes = {f.scope for f in findings}
        assert "<module>" in scopes
        assert "build_benchmark_broker" in scopes
        assert "build_aliased_broker" in scopes

    def test_mz05_flags_closure_and_interpret_and_parity(self):
        details = {f.detail for f in lint("mz05_bad.py")}
        assert "closure:_kernel.scale" in details
        assert "no-interpret@scale_all" in details
        assert "no-ref-parity" in details

    def test_prepr2_hostlog_wraparound_race_reproduced(self):
        """The pre-PR-2 HostLog (commit 493fa89) read the whole timestamp
        ring with no lock held -- MZ03 must pin the race to exactly that
        scan and nothing else."""
        findings = lint("mz03_prepr2_hostlog.py")
        assert [f.rule for f in findings] == ["MZ03"]
        (f,) = findings
        assert f.scope == "HostLog._timestamps"
        assert "_entries" in f.detail

    def test_current_src_is_clean(self):
        """The shipped tree lints clean against the committed baseline."""
        rc = main([str(ROOT / "src"),
                   "--baseline", str(ROOT / "mezlint.baseline.json")])
        assert rc == 0


# =============================================================================
# CLI / baseline mechanics
# =============================================================================


class TestCli:
    @pytest.mark.parametrize("name", [
        "mz01_bad.py", "mz02_bad.py", "mz03_bad.py", "mz04_bad.py",
        "mz05_bad.py", "mz06_bad.py", "mz07_bad.py", "mz08_bad.py",
    ])
    def test_bad_fixture_exits_nonzero(self, name):
        assert main([str(FIXDIR / name), "--no-baseline"]) == 1

    def test_baseline_accepts_known_findings(self, tmp_path):
        base = tmp_path / "base.json"
        target = str(FIXDIR / "mz04_bad.py")
        assert main([target, "--write-baseline", "--baseline",
                     str(base)]) == 0
        assert main([target, "--baseline", str(base)]) == 0
        keys = json.loads(base.read_text())["findings"]
        assert keys and all(k.startswith("MZ04|") for k in keys)

    def test_check_shrink_rejects_growth(self, tmp_path):
        old = tmp_path / "old.json"
        new = tmp_path / "new.json"
        assert main([str(FIXDIR / "mz04_good.py"), "--write-baseline",
                     "--baseline", str(old)]) == 0
        assert main([str(FIXDIR / "mz04_bad.py"), "--write-baseline",
                     "--baseline", str(new)]) == 0
        assert main(["--check-shrink", str(old),
                     "--baseline", str(new)]) == 1
        assert main(["--check-shrink", str(new),
                     "--baseline", str(old)]) == 0

    def test_rules_subset(self):
        findings = run_paths([str(FIXDIR / "mz01_bad.py")], rules={"MZ04"})
        assert findings == []


# =============================================================================
# trace_guard
# =============================================================================


class _FakeJitted:
    def __init__(self, size=0):
        self._n = size

    def cache_size(self):
        return self._n

    def compile(self):
        self._n += 1


class TestTraceGuard:
    def test_allows_warmup_compile(self):
        fn = _FakeJitted()
        with trace_guard(fn):
            fn.compile()
        assert fn.cache_size() == 1

    def test_fails_on_recompile(self):
        fn = _FakeJitted()
        with pytest.raises(TraceGuardError, match="0 -> 2"):
            with trace_guard(fn):
                fn.compile()
                fn.compile()

    def test_warm_target_must_not_grow(self):
        fn = _FakeJitted(size=3)
        with trace_guard(fn):
            pass                           # warm: no growth allowed
        with pytest.raises(TraceGuardError):
            with trace_guard(fn):
                fn.compile()

    def test_expect_raises_allowance(self):
        fn = _FakeJitted()
        with trace_guard(fn, expect=2):
            fn.compile()
            fn.compile()

    def test_assert_compiled_once(self):
        assert_compiled_once(1)
        with pytest.raises(TraceGuardError, match="got 7"):
            assert_compiled_once(7, "fleet cache")


# =============================================================================
# race_guard
# =============================================================================


class _PermissiveRW:
    """An RW lock that excludes nothing -- the proxy must notice."""

    def acquire_read(self):
        pass

    def release_read(self):
        pass

    def acquire_write(self):
        pass

    def release_write(self):
        pass


class TestRaceGuard:
    def test_detects_broken_rw_exclusion(self):
        guard = rg.race_guard(strict=False)
        proxy = rg._RWLockProxy(_PermissiveRW(), guard.shared, "seg[0]")
        done = threading.Event()

        def writer():
            proxy.acquire_write()
            done.wait(1.0)
            proxy.release_write()

        t = threading.Thread(target=writer)
        t.start()
        try:
            while not proxy._writers:
                pass
            proxy.acquire_read()           # admitted during a write: bug
            proxy.release_read()
        finally:
            done.set()
            t.join()
        assert any("reader admitted" in v for v in guard.violations)

    def test_detects_lock_order_cycle(self):
        guard = rg.race_guard(strict=False)
        a = rg._LockProxy(threading.Lock(), guard.shared, "A")
        b = rg._LockProxy(threading.Lock(), guard.shared, "B")
        with a:
            with b:
                pass
        with b:
            with a:
                pass
        assert any("lock-order cycle" in v for v in guard.violations)

    def test_hostlog_soak_is_clean(self):
        """Threaded append/query hammering on the CURRENT HostLog records
        no violations -- the seqlock snapshot never breaks lock discipline."""
        from repro.core.log import HostLog

        with rg.race_guard() as guard:
            log = HostLog(32, num_segments=4)
            frame = np.zeros((4, 4), dtype=np.uint8)
            stop = threading.Event()

            def reader():
                while not stop.is_set():
                    log.point_query(1e9)
                    len(log)

            threads = [threading.Thread(target=reader) for _ in range(2)]
            for t in threads:
                t.start()
            try:
                for i in range(400):
                    log.append(float(i), frame)
            finally:
                stop.set()
                for t in threads:
                    t.join()
        assert guard.violations == []

    def test_from_env(self, monkeypatch):
        monkeypatch.delenv("MEZLINT_RACE_GUARD", raising=False)
        assert rg.from_env() is None
        monkeypatch.setenv("MEZLINT_RACE_GUARD", "1")
        assert isinstance(rg.from_env(), rg.race_guard)
