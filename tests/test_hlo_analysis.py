"""Unit tests for the loop-aware HLO analyzer (the roofline's foundation)."""

import pytest

from benchmarks.hlo_analysis import analyze_hlo

MINI_HLO = """\
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%dot.1), replica_groups={}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,16]) -> f32[8,16] {
  %x = f32[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %x)
  %loop = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %out = f32[8,16] get-tuple-element(%loop), index=1
}
"""


class TestAnalyzer:
    def test_trip_corrected_dot_flops(self):
        a = analyze_hlo(MINI_HLO)
        # dot: 2 * (8*16) * 16 = 4096 flops, executed 4 times
        assert a.dot_flops == 4096 * 4

    def test_trip_corrected_collectives(self):
        a = analyze_hlo(MINI_HLO)
        # all-reduce payload f32[8,16] = 512 B, executed 4 times
        assert a.collective_bytes["all-reduce"] == 512 * 4

    def test_trip_count_from_backend_config(self):
        a = analyze_hlo(MINI_HLO)
        assert 4 in a.trip_counts.values()

    def test_free_ops_excluded_from_traffic(self):
        a = analyze_hlo(MINI_HLO)
        # parameter/get-tuple-element/tuple/constant contribute nothing;
        # surface traffic = (add s32 + compare pred ~ negligible) and NOT
        # the 512 B tuple plumbing per iteration
        assert a.elem_bytes < 512 * 4

    def test_fallback_trip_from_condition_constant(self):
        hlo = MINI_HLO.replace(
            ', backend_config={"known_trip_count":{"n":"4"}}', "")
        a = analyze_hlo(hlo)
        assert a.dot_flops == 4096 * 4   # recovered from %n = constant(4)

    def test_tuple_typed_while_parses(self):
        # regression: "(s32[], f32[...]) while(...)" must not be mistaken
        # for an op named after the tuple type
        a = analyze_hlo(MINI_HLO)
        assert a.dot_flops > 0
