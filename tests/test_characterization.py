"""Batched characterization engine vs the per-frame reference oracle (knob4
included), the wire-size proxy's calibration bound, online
re-characterization (``refresh_tables`` / ``CamBroker.recharacterize``),
the broker's pre-screen, and the knob-pipeline satellites (YUV packing
round-trip, transform memo, broker payload reuse)."""

import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import detector as det
from repro.core import grid_engine
from repro.core import knobs as K
from repro.core.broker import TABLE_CAPACITY, CamBroker, MezSystem
from repro.core.channel import calibrated_channel
from repro.core.characterization import characterize, fit_latency_regression
from repro.data.camera import CameraConfig, SyntheticCamera
from repro.kernels import frame_knobs as FK

CAMF = lambda: SyntheticCamera(CameraConfig(dynamics="medium", seed=7))
CLIP_LEN = 8


@pytest.fixture(scope="module")
def tables():
    return (characterize(CAMF, clip_len=CLIP_LEN, engine="batched"),
            characterize(CAMF, clip_len=CLIP_LEN, engine="reference"))


@pytest.fixture(scope="module")
def grid():
    cam = CAMF()
    bg = cam.background
    clip = [cam.next_frame() for _ in range(CLIP_LEN)]
    return bg, clip, grid_engine.run_grid(bg, [f for _, f, _ in clip])


class TestEngineEquivalence:
    def test_kept_settings_agree(self, tables):
        batched, reference = tables
        sb, sr = set(batched.settings), set(reference.settings)
        # proxy sizes can flip settings hovering exactly at the accuracy /
        # size boundaries; the characterized set must still agree broadly
        assert len(sb & sr) >= 0.9 * max(len(sb), len(sr))

    def test_accuracies_agree(self, tables):
        batched, reference = tables
        accb = {s: a for s, a in zip(batched.settings,
                                     batched.acc_by_setting)}
        accr = {s: a for s, a in zip(reference.settings,
                                     reference.acc_by_setting)}
        shared = set(accb) & set(accr)
        diffs = np.asarray([abs(accb[s] - accr[s]) for s in shared])
        # detector scoring is the same algorithm batched: identical up to
        # f32-vs-f64 threshold rounding on a handful of border pixels
        assert np.median(diffs) == 0.0
        assert diffs.max() <= 0.05

    def test_sizes_within_proxy_tolerance(self, tables):
        batched, reference = tables
        szb = {s: v for s, v in zip(batched.settings,
                                    batched.size_by_setting)}
        szr = {s: v for s, v in zip(reference.settings,
                                    reference.size_by_setting)}
        shared = set(szb) & set(szr)
        rel = np.asarray([abs(szb[s] - szr[s]) / szr[s] for s in shared])
        assert np.median(rel) < 0.10

    def test_deterministic(self):
        a = characterize(CAMF, clip_len=4, engine="batched")
        b = characterize(CAMF, clip_len=4, engine="batched")
        assert a.settings == b.settings
        np.testing.assert_array_equal(a.sizes_sorted, b.sizes_sorted)
        np.testing.assert_array_equal(a.best_acc, b.best_acc)

    def test_residual_spread_measured_and_quiet(self, tables):
        """Both engines report the calibration clip's wire-size residual
        spread, and on the synthetic clips it stays well under the drift
        floor -- learned hysteresis falls back to the proven constants, so
        characterization changes never perturb the committed goldens."""
        from repro.core.drift import (SPREAD_MULTIPLE, DriftConfig,
                                      learned_thresholds)
        base = DriftConfig()
        for tbl in tables:
            assert tbl.residual_spread is not None
            assert np.isfinite(tbl.residual_spread)
            assert 0.0 < tbl.residual_spread < base.hi / SPREAD_MULTIPLE
            assert learned_thresholds(tbl.residual_spread) == (base.hi,
                                                               base.lo)

    def test_auto_covers_artifact_knob_batched(self):
        """knob4 no longer forces the reference fallback: auto resolves to
        the batched engine and still characterizes artifact settings."""
        tbl = characterize(CAMF, clip_len=3, include_artifact=True,
                           min_accuracy=0.0)
        assert any(s.artifact > 0 for s in tbl.settings)
        assert tbl.proxy is not None       # batched-engine fingerprint

    def test_controller_closed_loop_on_batched_table(self, tables):
        """The proxy-sized table drives the PI loop to its latency bound."""
        from repro.core.controller import ControllerConfig, LatencyController
        batched, _ = tables
        ch = calibrated_channel(seed=3, workload="jaad")
        sizes = np.linspace(batched.sizes_sorted[0], batched.sizes_sorted[-1],
                            12)
        reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=2))
        c = LatencyController(ControllerConfig(0.100, 0.90), batched, reg)
        ch.activate("cam0")
        size = batched.size_by_setting[c._current]
        lats = []
        for _ in range(25):
            lat = ch.transfer(float(size))
            lats.append(lat)
            d = c.update(lat)
            if d.setting_index >= 0:
                size = batched.size_by_setting[d.setting_index]
        assert np.percentile(lats[8:], 95) < 0.14


class TestKnob4Equivalence:
    """knob4 on device: ``characterize(engine='batched',
    include_artifact=True)`` against the NumPy reference oracle."""

    @pytest.fixture(scope="class")
    def art_tables(self):
        return (characterize(CAMF, clip_len=6, engine="batched",
                             include_artifact=True),
                characterize(CAMF, clip_len=6, engine="reference",
                             include_artifact=True))

    def test_kept_settings_identical(self, art_tables):
        batched, reference = art_tables
        assert set(batched.settings) == set(reference.settings)

    def test_accuracies_agree(self, art_tables):
        batched, reference = art_tables
        accb = dict(zip(batched.settings, batched.acc_by_setting))
        accr = dict(zip(reference.settings, reference.acc_by_setting))
        diffs = np.asarray([abs(accb[s] - accr[s])
                            for s in set(accb) & set(accr)])
        assert np.median(diffs) == 0.0
        assert diffs.max() <= 0.05

    def test_artifact_settings_scored(self):
        """The batched engine actually scores knob4 settings (visible with
        the accuracy floor dropped) instead of skipping them."""
        tbl = characterize(CAMF, clip_len=3, engine="batched",
                           include_artifact=True, min_accuracy=0.0)
        art = [s for s in tbl.settings if s.artifact > 0]
        assert len(art) > 0
        assert tbl.proxy is not None

    def test_odd_geometry_raises_clear_error(self):
        """Regression: engine='batched' must REFUSE unsupported odd
        geometry loudly -- the seed behaviour was a silent minutes-long
        fallback to the reference path."""
        camf = lambda: SyntheticCamera(CameraConfig(
            dynamics="medium", seed=7, height=30, width=41))
        with pytest.raises(ValueError, match="even-dimension"):
            characterize(camf, clip_len=2, engine="batched")
        # the error must point at the escape hatches
        try:
            characterize(camf, clip_len=2, engine="batched")
        except ValueError as e:
            assert "reference" in str(e) and "auto" in str(e)


class TestOnlineRecharacterization:
    def test_refresh_tables_pseudo_gt(self):
        """``refresh_tables`` characterizes an unlabeled live clip: the
        full-quality detections act as ground truth, so the unmodified
        setting scores accuracy 1.0 and the table is controller-ready."""
        cam = CAMF()
        bg = cam.background
        clip = [cam.next_frame()[1] for _ in range(6)]
        table, jt = grid_engine.refresh_tables(bg, clip, capacity=64)
        assert len(table.settings) > 0
        assert table.proxy is not None
        full = table.settings.index(K.KnobSetting(0, 0, 0, 0, 0))
        np.testing.assert_allclose(table.acc_by_setting[full], 1.0)
        assert jt.sizes_sorted.shape[0] == 64
        assert int(jt.n_valid) == len(table.settings)
        assert np.isinf(np.asarray(jt.sizes_sorted)[int(jt.n_valid):]).all()

    def test_cambroker_recharacterize_swaps_live_tables(self, tables):
        batched, _ = tables
        ch = calibrated_channel(seed=3)
        sys = MezSystem(ch)
        cam = sys.add_camera("cam0")
        src = CAMF()
        cam.background = src.background
        sizes = np.linspace(batched.sizes_sorted[0],
                            batched.sizes_sorted[-1], 8)
        reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=1))
        cam.set_target(0.1, 0.9, batched, reg)
        v0 = cam.table_version
        assert cam.jax_tables is not None          # installed by set_target
        for ts, f, _ in src.stream(6):
            cam.publish(ts, f)
        assert cam.recharacterize(clip_len=6)
        assert cam.table_version == v0 + 1
        assert cam.controller.table is not batched
        assert cam.controller.table.proxy is not None
        assert int(cam.jax_tables.n_valid) == len(cam.controller.table.settings)
        assert cam.jax_tables.sizes_sorted.shape[0] >= TABLE_CAPACITY
        # the refreshed table still drives fetch end to end
        out = cam.fetch(0.0, 10.0, latency_feedback=0.1)
        assert any(d.frame is not None for d in out)

    def test_recharacterize_without_state_is_refused(self):
        cam = CamBroker("cam0", calibrated_channel(seed=1))
        assert not cam.recharacterize()            # no controller yet

    def test_recharacterize_preserves_floor_and_knob4(self):
        """A refresh must not silently reshape the trade space: the live
        table's accuracy floor and knob4 coverage carry over by default."""
        tbl = characterize(CAMF, clip_len=4, engine="batched",
                           include_artifact=True, min_accuracy=0.0)
        assert tbl.includes_artifact
        ch = calibrated_channel(seed=3)
        sys = MezSystem(ch)
        cam = sys.add_camera("cam0")
        src = CAMF()
        cam.background = src.background
        sizes = np.linspace(tbl.sizes_sorted[0], tbl.sizes_sorted[-1], 8)
        reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=1))
        cam.set_target(0.1, 0.5, tbl, reg)
        for ts, f, _ in src.stream(4):
            cam.publish(ts, f)
        assert cam.recharacterize(clip_len=4)
        fresh = cam.controller.table
        assert fresh is not tbl
        assert fresh.min_accuracy == 0.0           # floor carried over
        assert fresh.includes_artifact             # knob4 axis survived


class TestTransformGroupTwin:
    def test_honors_actual_mode_ids(self):
        """The XLA twin must key knob4 masks by the plan's ACTUAL mode ids
        (like the kernel's per-setting art_ids), not by block position --
        regression for art_modes=(0, 2) applying the movers mask to the
        contours block."""
        rng = np.random.default_rng(3)
        h, w, f = 16, 24, 2
        bg = rng.integers(40, 200, (h, w, 3)).astype(np.uint8)
        frames = np.clip(bg[None] + rng.normal(0, 5, (f, h, w, 3)),
                         0, 255).astype(np.uint8)
        frames[1, 4:10, 6:14] = 250
        prev = np.concatenate([frames[:1], frames[:-1]])
        enable = np.ones(f, np.int32)
        plan = FK.build_transform_plan(h, w, scale=1.0, cs=0,
                                       blur_ks=(0,), art_modes=(0, 2))
        from repro.kernels import ref
        pr, _, _ = ref.frame_knob_grid_ref(
            jnp.asarray(frames), jnp.asarray(prev), plan,
            background=jnp.asarray(bg), art_enable=jnp.asarray(enable))
        pt, _, _ = grid_engine._transform_group(
            jnp.asarray(frames), jnp.asarray(plan.ry),
            jnp.asarray(plan.rx), jnp.asarray(plan.bys),
            jnp.asarray(plan.bxs), 0, bg=jnp.asarray(bg),
            enable=jnp.asarray(enable), art_modes=(0, 2))
        d = np.abs(np.asarray(pt).astype(np.int32)
                   - np.asarray(pr).astype(np.int32))
        assert d.max() <= 1                        # same masks, same math


class TestWireSizePrescreen:
    def test_proxy_features_host_matches_device(self):
        rng = np.random.default_rng(5)
        frame = rng.integers(0, 256, (24, 32, 3)).astype(np.uint8)
        for cs in range(3):
            for blur in (0, 2):
                s = K.KnobSetting(1, cs, blur)
                wire = K.transform_frame(frame, s)
                got = FK.proxy_features_host(wire)
                # device layout: planes
                planes = (jnp.moveaxis(jnp.asarray(wire), -1, 0)
                          if wire.ndim == 3 else jnp.asarray(wire)[None])
                want = np.asarray(FK.proxy_features(planes))
                np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)

    def _broker(self, table, accuracy=0.9):
        ch = calibrated_channel(seed=3)
        sys = MezSystem(ch)
        cam = sys.add_camera("cam0")
        src = CAMF()
        cam.background = src.background
        sizes = np.linspace(table.sizes_sorted[0], table.sizes_sorted[-1], 8)
        reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=1))
        cam.set_target(0.1, accuracy, table, reg)
        return cam, src

    def test_fetch_runs_prescreen_on_acting_decisions(self, tables):
        batched, _ = tables
        cam, src = self._broker(batched)
        for ts, f, _ in src.stream(4):
            cam.publish(ts, f)
        out = cam.fetch(0.0, 10.0, latency_feedback=0.25)
        assert cam.prescreen_evals > 0             # features ran in fetch
        assert all(f.knob_index >= 0 for f in out if f.frame is not None)

    def test_overshooting_candidate_steps_down(self, tables):
        """A candidate whose predicted wire size blows the controller's
        budget is stepped down the table from byte-delta features alone --
        deflate never runs on the rejected candidate."""
        from repro.core.controller import ControlDecision
        batched, _ = tables
        cam, src = self._broker(batched, accuracy=0.0)
        ts, frame, _ = src.next_frame()
        # the PI asked for the HIGHEST-fidelity setting but granted only a
        # third of its clip-median bytes (interference mid-renegotiation)
        idx = int(np.argmax(batched.size_by_setting))
        budget = float(batched.size_by_setting[idx]) * 0.3
        decision = ControlDecision(True, batched.setting_for(idx), idx,
                                   1.0, budget, 0.05, True)
        eff_setting, eff_idx, entry = cam._prescreen(ts, frame, decision)
        assert cam.prescreen_stepdowns > 0
        assert eff_idx != idx
        assert (batched.size_by_setting[eff_idx]
                < batched.size_by_setting[idx])
        # the returned entry is the ACCEPTED setting's payload, held in the
        # fleet-shared degraded-frame cache (the per-camera dict only backs
        # unregistered brokers)...
        key = (cam.camera_id, ts, eff_setting.resolution,
               eff_setting.colorspace, eff_setting.blur,
               eff_setting.artifact)
        assert cam.shared_cache._entries[key] is entry
        # ...and no deflate was paid along the walk
        assert all(e[1] is None for e in cam.shared_cache._entries.values())

    def test_prescreen_inert_without_proxy(self, tables):
        """Reference-engine tables carry no proxy: fetch must behave
        exactly as before (no evals, controller decision shipped as-is)."""
        _, reference = tables
        assert reference.proxy is None
        ch = calibrated_channel(seed=3)
        sys = MezSystem(ch)
        cam = sys.add_camera("cam0")
        src = CAMF()
        cam.background = src.background
        sizes = np.linspace(reference.sizes_sorted[0],
                            reference.sizes_sorted[-1], 8)
        reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=1))
        cam.set_target(0.1, 0.9, reference, reg)
        for ts, f, _ in src.stream(4):
            cam.publish(ts, f)
        out = cam.fetch(0.0, 10.0, latency_feedback=0.25)
        assert cam.prescreen_evals == 0
        assert len(out) == 4


class TestWireSizeProxy:
    def test_median_error_vs_zlib(self, grid):
        """Acceptance bound: proxy within 10% median relative error of
        real zlib level-1 across the whole (res, cs, blur) x frame grid."""
        bg, clip, g = grid
        rels = []
        for (res, cs, b, art), pred in g.sizes.items():
            setting = K.KnobSetting(res, cs, b, art)
            for fi, (_, frame, _) in enumerate(clip):
                payload = K.transform_frame(frame, setting)
                true = len(zlib.compress(
                    np.ascontiguousarray(payload).tobytes(), 1))
                rels.append(abs(pred[fi] - true) / true)
        rels = np.asarray(rels)
        assert np.median(rels) < 0.10
        assert np.percentile(rels, 90) < 0.25
        assert g.proxy.median_rel_err < 0.10
        # deflate left the hot path: one calibration call per combo
        assert g.zlib_calls == len(g.sizes)

    def test_sizes_monotone_with_payload(self, grid):
        """Sanity: the proxy ranks a downscaled gray payload far below the
        full-resolution BGR one."""
        _, _, g = grid
        full = float(np.median(g.sizes[(0, 0, 0, 0)]))
        tiny = float(np.median(g.sizes[(4, 1, 0, 0)]))
        assert tiny < 0.25 * full


class TestDropPatterns:
    def test_match_frame_difference_walk(self, grid):
        bg, clip, g = grid
        for thresh in K.DIFF_THRESHOLDS:
            want = np.zeros(len(clip), bool)
            last = None
            for fi, (_, frame, _) in enumerate(clip):
                if K.frame_difference(frame, last, thresh):
                    want[fi] = True
                else:
                    last = frame
            np.testing.assert_array_equal(g.drop_pattern(thresh), want)


class TestSegmentBoxes:
    def test_matches_host_helper(self, grid):
        """The vectorized box extractor agrees with the per-component
        reference helper on real detector masks."""
        bg, clip, _ = grid
        for _, frame, _ in clip[:4]:
            g = frame.astype(np.float32).mean(-1)
            b = bg.astype(np.float32).mean(-1)
            diff = np.abs(g - b)
            mask = det.dilate_cross(diff > 12.0)
            labels, _ = grid_engine._label_host(mask[None])
            want = det.boxes_from_labels(labels[0], diff, background_label=0,
                                         sy=1.0, sx=1.0, min_px=4.0)
            got = grid_engine._segment_boxes(labels[0], diff,
                                             background_label=0,
                                             sy=1.0, sx=1.0, min_px=4.0)
            np.testing.assert_allclose(got, want, atol=1e-5)


class TestYuvPacking:
    @pytest.mark.parametrize("h,w", [(16, 24), (16, 25), (15, 25), (18, 33)])
    def test_round_trip_planes(self, h, w):
        """U and V planes are both fully recoverable from the packed
        payload -- the seed silently truncated V's last column when the
        frame width was odd (w < 2 * ceil(w/2))."""
        rng = np.random.default_rng(h * 100 + w)
        frame = rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
        packed = K._to_colorspace(frame, "yuv420")
        uh, uw = -(-h // 2), -(-w // 2)
        pw = max(w, 2 * uw)
        assert packed.shape == (h + uh, pw)

        f = frame.astype(np.float32)
        b, g, r = f[..., 0], f[..., 1], f[..., 2]
        y = 0.114 * b + 0.587 * g + 0.299 * r
        u = np.clip(np.round(0.492 * (b - y) + 128.0), 0, 255)[::2, ::2]
        v = np.clip(np.round(0.877 * (r - y) + 128.0), 0, 255)[::2, ::2]
        np.testing.assert_array_equal(packed[h:, :uw], u.astype(np.uint8))
        np.testing.assert_array_equal(packed[h:, uw:2 * uw],
                                      v.astype(np.uint8))

    def test_even_width_layout_unchanged(self):
        """Even geometries keep the seed's exact payload (Y on top, U|V
        below, width w) -- no wire-size regression for the common case."""
        rng = np.random.default_rng(3)
        frame = rng.integers(0, 256, (12, 20, 3)).astype(np.uint8)
        packed = K._to_colorspace(frame, "yuv420")
        assert packed.shape == (12 + 6, 20)


class TestTransformMemoAndBroker:
    def test_memo_caches_per_transform_key(self):
        bg = CAMF().background
        memo = K.TransformMemo(bg)
        s1 = K.KnobSetting(1, 1, 2, 0, 0)
        s2 = K.KnobSetting(1, 1, 2, 0, 3)      # same transform, other diff
        a, b = memo.get(s1), memo.get(s2)
        assert a is b
        np.testing.assert_array_equal(a, K.transform_frame(bg, s1))

    def test_degraded_background_tracks_background(self):
        cam = CamBroker("cam0", calibrated_channel(seed=1))
        assert cam.degraded_background(K.KnobSetting()) is None
        src = CAMF()
        cam.background = src.background
        s = K.KnobSetting(2, 1, 1, 0, 0)
        np.testing.assert_array_equal(
            cam.degraded_background(s), K.transform_frame(src.background, s))
        cam.background = np.zeros_like(src.background)
        assert cam.degraded_background(s).max() == 0

    def test_payload_cache_reused_across_subscriptions(self, tables):
        """Two subscriptions fanning out from one camera share the knob
        transform work, with identical delivered payloads."""
        batched, _ = tables
        ch = calibrated_channel(seed=3)
        sys = MezSystem(ch)
        cam = sys.add_camera("cam0")
        src = CAMF()
        cam.background = src.background
        sizes = np.linspace(batched.sizes_sorted[0], batched.sizes_sorted[-1],
                            8)
        reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=1))
        cam.set_target(0.1, 0.9, batched, reg)
        for ts, f, _ in src.stream(6):
            cam.publish(ts, f)
        # latency_feedback=None -> the controller's current setting is used
        # verbatim for both walks (no PI update between them)
        a = cam.fetch(0.0, 10.0)
        hits_before = cam.payload_cache_hits
        b = cam.fetch(0.0, 10.0)
        # second fetch walked the same frames at the same knob setting
        assert cam.payload_cache_hits > hits_before
        for da, db in zip(a, b):
            if da.frame is not None and db.frame is not None:
                np.testing.assert_array_equal(da.frame, db.frame)
                assert da.wire_bytes == db.wire_bytes
