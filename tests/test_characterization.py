"""Batched characterization engine vs the per-frame reference oracle, the
wire-size proxy's calibration bound, and the knob-pipeline satellites
(YUV packing round-trip, transform memo, broker payload reuse)."""

import zlib

import numpy as np
import pytest

from repro.core import detector as det
from repro.core import grid_engine
from repro.core import knobs as K
from repro.core.broker import CamBroker, MezSystem
from repro.core.channel import calibrated_channel
from repro.core.characterization import characterize, fit_latency_regression
from repro.data.camera import CameraConfig, SyntheticCamera

CAMF = lambda: SyntheticCamera(CameraConfig(dynamics="medium", seed=7))
CLIP_LEN = 8


@pytest.fixture(scope="module")
def tables():
    return (characterize(CAMF, clip_len=CLIP_LEN, engine="batched"),
            characterize(CAMF, clip_len=CLIP_LEN, engine="reference"))


@pytest.fixture(scope="module")
def grid():
    cam = CAMF()
    bg = cam.background
    clip = [cam.next_frame() for _ in range(CLIP_LEN)]
    return bg, clip, grid_engine.run_grid(bg, [f for _, f, _ in clip])


class TestEngineEquivalence:
    def test_kept_settings_agree(self, tables):
        batched, reference = tables
        sb, sr = set(batched.settings), set(reference.settings)
        # proxy sizes can flip settings hovering exactly at the accuracy /
        # size boundaries; the characterized set must still agree broadly
        assert len(sb & sr) >= 0.9 * max(len(sb), len(sr))

    def test_accuracies_agree(self, tables):
        batched, reference = tables
        accb = {s: a for s, a in zip(batched.settings,
                                     batched.acc_by_setting)}
        accr = {s: a for s, a in zip(reference.settings,
                                     reference.acc_by_setting)}
        shared = set(accb) & set(accr)
        diffs = np.asarray([abs(accb[s] - accr[s]) for s in shared])
        # detector scoring is the same algorithm batched: identical up to
        # f32-vs-f64 threshold rounding on a handful of border pixels
        assert np.median(diffs) == 0.0
        assert diffs.max() <= 0.05

    def test_sizes_within_proxy_tolerance(self, tables):
        batched, reference = tables
        szb = {s: v for s, v in zip(batched.settings,
                                    batched.size_by_setting)}
        szr = {s: v for s, v in zip(reference.settings,
                                    reference.size_by_setting)}
        shared = set(szb) & set(szr)
        rel = np.asarray([abs(szb[s] - szr[s]) / szr[s] for s in shared])
        assert np.median(rel) < 0.10

    def test_deterministic(self):
        a = characterize(CAMF, clip_len=4, engine="batched")
        b = characterize(CAMF, clip_len=4, engine="batched")
        assert a.settings == b.settings
        np.testing.assert_array_equal(a.sizes_sorted, b.sizes_sorted)
        np.testing.assert_array_equal(a.best_acc, b.best_acc)

    def test_auto_falls_back_for_artifact_knob(self):
        tbl = characterize(CAMF, clip_len=3, include_artifact=True,
                           min_accuracy=0.0)
        assert any(s.artifact > 0 for s in tbl.settings)

    def test_controller_closed_loop_on_batched_table(self, tables):
        """The proxy-sized table drives the PI loop to its latency bound."""
        from repro.core.controller import ControllerConfig, LatencyController
        batched, _ = tables
        ch = calibrated_channel(seed=3, workload="jaad")
        sizes = np.linspace(batched.sizes_sorted[0], batched.sizes_sorted[-1],
                            12)
        reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=2))
        c = LatencyController(ControllerConfig(0.100, 0.90), batched, reg)
        ch.activate("cam0")
        size = batched.size_by_setting[c._current]
        lats = []
        for _ in range(25):
            lat = ch.transfer(float(size))
            lats.append(lat)
            d = c.update(lat)
            if d.setting_index >= 0:
                size = batched.size_by_setting[d.setting_index]
        assert np.percentile(lats[8:], 95) < 0.14


class TestWireSizeProxy:
    def test_median_error_vs_zlib(self, grid):
        """Acceptance bound: proxy within 10% median relative error of
        real zlib level-1 across the whole (res, cs, blur) x frame grid."""
        bg, clip, g = grid
        rels = []
        for (res, cs, b), pred in g.sizes.items():
            setting = K.KnobSetting(res, cs, b)
            for fi, (_, frame, _) in enumerate(clip):
                payload = K.transform_frame(frame, setting)
                true = len(zlib.compress(
                    np.ascontiguousarray(payload).tobytes(), 1))
                rels.append(abs(pred[fi] - true) / true)
        rels = np.asarray(rels)
        assert np.median(rels) < 0.10
        assert np.percentile(rels, 90) < 0.25
        assert g.proxy.median_rel_err < 0.10
        # deflate left the hot path: one calibration call per combo
        assert g.zlib_calls == len(g.sizes)

    def test_sizes_monotone_with_payload(self, grid):
        """Sanity: the proxy ranks a downscaled gray payload far below the
        full-resolution BGR one."""
        _, _, g = grid
        full = float(np.median(g.sizes[(0, 0, 0)]))
        tiny = float(np.median(g.sizes[(4, 1, 0)]))
        assert tiny < 0.25 * full


class TestDropPatterns:
    def test_match_frame_difference_walk(self, grid):
        bg, clip, g = grid
        for thresh in K.DIFF_THRESHOLDS:
            want = np.zeros(len(clip), bool)
            last = None
            for fi, (_, frame, _) in enumerate(clip):
                if K.frame_difference(frame, last, thresh):
                    want[fi] = True
                else:
                    last = frame
            np.testing.assert_array_equal(g.drop_pattern(thresh), want)


class TestSegmentBoxes:
    def test_matches_host_helper(self, grid):
        """The vectorized box extractor agrees with the per-component
        reference helper on real detector masks."""
        bg, clip, _ = grid
        for _, frame, _ in clip[:4]:
            g = frame.astype(np.float32).mean(-1)
            b = bg.astype(np.float32).mean(-1)
            diff = np.abs(g - b)
            mask = det.dilate_cross(diff > 12.0)
            labels, _ = grid_engine._label_host(mask[None])
            want = det.boxes_from_labels(labels[0], diff, background_label=0,
                                         sy=1.0, sx=1.0, min_px=4.0)
            got = grid_engine._segment_boxes(labels[0], diff,
                                             background_label=0,
                                             sy=1.0, sx=1.0, min_px=4.0)
            np.testing.assert_allclose(got, want, atol=1e-5)


class TestYuvPacking:
    @pytest.mark.parametrize("h,w", [(16, 24), (16, 25), (15, 25), (18, 33)])
    def test_round_trip_planes(self, h, w):
        """U and V planes are both fully recoverable from the packed
        payload -- the seed silently truncated V's last column when the
        frame width was odd (w < 2 * ceil(w/2))."""
        rng = np.random.default_rng(h * 100 + w)
        frame = rng.integers(0, 256, (h, w, 3)).astype(np.uint8)
        packed = K._to_colorspace(frame, "yuv420")
        uh, uw = -(-h // 2), -(-w // 2)
        pw = max(w, 2 * uw)
        assert packed.shape == (h + uh, pw)

        f = frame.astype(np.float32)
        b, g, r = f[..., 0], f[..., 1], f[..., 2]
        y = 0.114 * b + 0.587 * g + 0.299 * r
        u = np.clip(np.round(0.492 * (b - y) + 128.0), 0, 255)[::2, ::2]
        v = np.clip(np.round(0.877 * (r - y) + 128.0), 0, 255)[::2, ::2]
        np.testing.assert_array_equal(packed[h:, :uw], u.astype(np.uint8))
        np.testing.assert_array_equal(packed[h:, uw:2 * uw],
                                      v.astype(np.uint8))

    def test_even_width_layout_unchanged(self):
        """Even geometries keep the seed's exact payload (Y on top, U|V
        below, width w) -- no wire-size regression for the common case."""
        rng = np.random.default_rng(3)
        frame = rng.integers(0, 256, (12, 20, 3)).astype(np.uint8)
        packed = K._to_colorspace(frame, "yuv420")
        assert packed.shape == (12 + 6, 20)


class TestTransformMemoAndBroker:
    def test_memo_caches_per_transform_key(self):
        bg = CAMF().background
        memo = K.TransformMemo(bg)
        s1 = K.KnobSetting(1, 1, 2, 0, 0)
        s2 = K.KnobSetting(1, 1, 2, 0, 3)      # same transform, other diff
        a, b = memo.get(s1), memo.get(s2)
        assert a is b
        np.testing.assert_array_equal(a, K.transform_frame(bg, s1))

    def test_degraded_background_tracks_background(self):
        cam = CamBroker("cam0", calibrated_channel(seed=1))
        assert cam.degraded_background(K.KnobSetting()) is None
        src = CAMF()
        cam.background = src.background
        s = K.KnobSetting(2, 1, 1, 0, 0)
        np.testing.assert_array_equal(
            cam.degraded_background(s), K.transform_frame(src.background, s))
        cam.background = np.zeros_like(src.background)
        assert cam.degraded_background(s).max() == 0

    def test_payload_cache_reused_across_subscriptions(self, tables):
        """Two subscriptions fanning out from one camera share the knob
        transform work, with identical delivered payloads."""
        batched, _ = tables
        ch = calibrated_channel(seed=3)
        sys = MezSystem(ch)
        cam = sys.add_camera("cam0")
        src = CAMF()
        cam.background = src.background
        sizes = np.linspace(batched.sizes_sorted[0], batched.sizes_sorted[-1],
                            8)
        reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=1))
        cam.set_target(0.1, 0.9, batched, reg)
        for ts, f, _ in src.stream(6):
            cam.publish(ts, f)
        # latency_feedback=None -> the controller's current setting is used
        # verbatim for both walks (no PI update between them)
        a = cam.fetch(0.0, 10.0)
        hits_before = cam.payload_cache_hits
        b = cam.fetch(0.0, 10.0)
        # second fetch walked the same frames at the same knob setting
        assert cam.payload_cache_hits > hits_before
        for da, db in zip(a, b):
            if da.frame is not None and db.frame is not None:
                np.testing.assert_array_equal(da.frame, db.frame)
                assert da.wire_bytes == db.wire_bytes
