"""Property-based tests (hypothesis) on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.channel import calibrated_channel
from repro.core.characterization import CharacterizationTable
from repro.core.controller import ControllerConfig, LatencyController
from repro.core.knobs import KnobSetting
from repro.core.log import HostLog
from repro.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


class TestLogProperties:
    @given(st.lists(st.floats(min_value=0, max_value=1e6,
                              allow_nan=False), min_size=1, max_size=60))
    @settings(**SETTINGS)
    def test_log_matches_python_model(self, timestamps):
        """HostLog == a plain 'sorted unique suffix' model, any input order."""
        cap = 16
        log = HostLog(cap)
        model: list[float] = []
        for t in timestamps:
            accepted = log.append(t, np.zeros((2, 2), np.uint8))
            if model and t <= model[-1]:
                assert not accepted
            else:
                assert accepted
                model.append(t)
        expect = model[-cap:]
        got = [t for t, _ in log.snapshot()]
        assert got == expect

    @given(st.integers(1, 50), st.floats(0, 100), st.floats(0, 100))
    @settings(**SETTINGS)
    def test_range_query_subset_of_point_semantics(self, n, a, b):
        log = HostLog(64)
        for i in range(n):
            log.append(float(i), np.zeros((1,), np.uint8))
        lo, hi = min(a, b), max(a, b)
        out = [t for t, _ in log.range_query(lo, hi)]
        assert out == [float(i) for i in range(n) if lo <= i <= hi]


class TestChannelProperties:
    @given(st.floats(min_value=1e3, max_value=3e6),
           st.integers(1, 8), st.integers(1, 8))
    @settings(**SETTINGS)
    def test_latency_monotone_in_peers(self, size, n1, n2):
        ch = calibrated_channel()
        l1 = ch.mean_latency(size, n=min(n1, n2))
        l2 = ch.mean_latency(size, n=max(n1, n2))
        assert l2 >= l1 - 1e-12

    @given(st.floats(min_value=1e3, max_value=2e6),
           st.floats(min_value=1e3, max_value=2e6), st.integers(1, 6))
    @settings(**SETTINGS)
    def test_latency_monotone_in_size(self, s1, s2, n):
        ch = calibrated_channel()
        assert (ch.mean_latency(max(s1, s2), n=n)
                >= ch.mean_latency(min(s1, s2), n=n) - 1e-12)


class TestControllerProperties:
    @staticmethod
    def _table(sizes, accs):
        order = np.argsort(sizes)
        sizes = np.asarray(sizes, float)[order]
        accs = np.asarray(accs, float)[order]
        best_acc, best_idx, run = [], [], (-1.0, -1)
        for i, a in enumerate(accs):
            if a > run[0]:
                run = (a, i)
            best_acc.append(run[0])
            best_idx.append(run[1])
        return CharacterizationTable(
            settings=tuple(KnobSetting() for _ in sizes),
            sizes_sorted=sizes, best_acc=np.asarray(best_acc),
            best_idx=np.asarray(best_idx), acc_by_setting=accs,
            size_by_setting=sizes)

    @given(st.lists(st.tuples(st.floats(1e3, 1e5), st.floats(0.5, 1.0)),
                    min_size=3, max_size=20),
           st.lists(st.floats(0.0, 1.0), min_size=1, max_size=20))
    @settings(**SETTINGS)
    def test_decisions_always_within_table(self, pairs, lat_samples):
        """Whatever the table and the latency series, a feasible decision's
        setting always satisfies the accuracy floor, and requested sizes are
        clamped to the characterized range."""
        sizes = [p[0] for p in pairs]
        accs = [p[1] for p in pairs]
        tbl = self._table(sizes, accs)
        from repro.core.characterization import LatencyRegression
        reg = LatencyRegression(slope=1e-6, intercept=0.005)
        c = LatencyController(ControllerConfig(0.05, 0.9), tbl, reg)
        for lat in lat_samples:
            d = c.update(lat)
            assert tbl.sizes_sorted[0] <= d.requested_size \
                <= tbl.sizes_sorted[-1]
            if d.feasible and d.acted:
                assert tbl.acc_by_setting[d.setting_index] >= 0.9 - 1e-9

    @given(st.floats(1e3, 9e4))
    @settings(**SETTINGS)
    def test_query_size_never_exceeds_budget(self, budget):
        tbl = self._table(np.linspace(2e3, 9e4, 12),
                          np.linspace(0.9, 1.0, 12))
        acc, idx = tbl.query_size(budget)
        if idx >= 0:
            assert tbl.size_by_setting[idx] <= budget + 1e-6


class TestControlLawProperties:
    """Algorithm 1 invariants under arbitrary tables and latency series."""

    TARGET = 0.050

    def _controller(self, pairs, floor=0.9, **cfg_kw):
        sizes = [p[0] for p in pairs]
        accs = [p[1] for p in pairs]
        tbl = TestControllerProperties._table(sizes, accs)
        from repro.core.characterization import LatencyRegression
        reg = LatencyRegression(slope=1e-6, intercept=0.005)
        cfg = ControllerConfig(self.TARGET, floor, **cfg_kw)
        return LatencyController(cfg, tbl, reg), tbl

    @given(st.lists(st.tuples(st.floats(1e3, 1e5), st.floats(0.5, 1.0)),
                    min_size=3, max_size=20),
           st.lists(st.floats(0.011, 5.0), min_size=1, max_size=15))
    @settings(**SETTINGS)
    def test_positive_error_never_increases_requested_size(self, pairs,
                                                           errors):
        """Outside the error band, a positive latency error can only pull
        the requested size DOWN from the nominal operating point (K1, K2 <
        0 and the integral stays positive under a positive-error history);
        the only way up is the table's own size floor."""
        c, tbl = self._controller(pairs)
        floor_size = tbl.sizes_sorted[0]
        bound = max(c._nominal, floor_size)
        for e in errors:
            d = c.update(self.TARGET + e)
            assert d.acted
            assert d.requested_size <= bound + 1e-9

    @given(st.lists(st.tuples(st.floats(1e3, 1e5), st.floats(0.5, 1.0)),
                    min_size=3, max_size=20),
           st.floats(0.011, 5.0), st.floats(0.011, 5.0))
    @settings(**SETTINGS)
    def test_requested_size_monotone_in_error(self, pairs, e1, e2):
        """From identical state, a larger positive error never requests a
        larger size (fresh controllers; integral = clipped error)."""
        lo, hi = min(e1, e2), max(e1, e2)
        c_lo, _ = self._controller(pairs)
        c_hi, _ = self._controller(pairs)
        d_lo = c_lo.update(self.TARGET + lo)
        d_hi = c_hi.update(self.TARGET + hi)
        assert d_hi.requested_size <= d_lo.requested_size + 1e-9

    @given(st.lists(st.tuples(st.floats(1e3, 1e5), st.floats(0.5, 1.0)),
                    min_size=3, max_size=20),
           st.lists(st.floats(0.0, 10.0), min_size=1, max_size=40),
           st.floats(0.05, 2.0))
    @settings(**SETTINGS)
    def test_integral_respects_clip(self, pairs, lats, clip):
        """Anti-windup: whatever the latency series, the integral never
        leaves [-integral_clip, integral_clip]."""
        c, _ = self._controller(pairs, integral_clip=clip)
        for lat in lats:
            c.update(lat)
            assert abs(c.integral) <= clip + 1e-12

    @given(st.lists(st.tuples(st.floats(1e3, 1e5), st.floats(0.5, 1.0)),
                    min_size=3, max_size=20),
           st.lists(st.floats(0.0, 5.0), min_size=1, max_size=20),
           st.floats(0.55, 0.999))
    @settings(**SETTINGS)
    def test_infeasible_iff_no_row_meets_floor(self, pairs, lats, floor):
        """An acted decision reports INFEASIBLE exactly when no
        characterized row within the requested size budget clears the
        accuracy floor -- re-derived from the raw per-setting arrays, not
        from the prefix-max tables the controller itself queries."""
        c, tbl = self._controller(pairs, floor=floor)
        for lat in lats:
            d = c.update(lat)
            if not d.acted:
                continue
            within = tbl.size_by_setting <= d.requested_size
            feasible_model = bool(within.any()) and \
                float(tbl.acc_by_setting[within].max()) >= floor
            assert d.feasible == feasible_model
            if not d.feasible and within.any():
                # best-effort degradation: still serving the best setting
                # available within the budget
                assert d.setting is not None


class TestDriftDetectorProperties:
    """Staleness-monitor invariants (core/drift.py) under arbitrary
    residual sequences -- the drift-aware auto-recharacterization loop's
    false-positive / detection-latency / no-flapping bars."""

    from repro.core.drift import DriftConfig as _DC
    CFG = _DC(window=8, hi=0.35, lo=0.15, min_samples=4)

    @classmethod
    def _run(cls, errs):
        from repro.core.drift import DriftParams, drift_init, drift_update
        state = drift_init(None, cls.CFG.window)
        params = DriftParams.from_config(cls.CFG)
        fires = []
        for e in errs:
            state, fired, _ = drift_update(state, e, True, params)
            fires.append(bool(fired))
        return fires, state

    @given(st.lists(st.floats(0.0, 0.35 * 0.98), min_size=1, max_size=60))
    @settings(**SETTINGS)
    def test_never_fires_on_stationary_scene(self, errs):
        """False-positive bound: whatever the sequence, samples at or
        below the hi threshold never fire (a windowed mean of values <= hi
        cannot exceed hi)."""
        fires, _ = self._run(errs)
        assert not any(fires)

    @given(st.lists(st.floats(0.0, 0.15 * 0.9), min_size=0, max_size=30),
           st.floats(0.35 * 1.01, 50.0))
    @settings(**SETTINGS)
    def test_sustained_step_fires_within_one_window(self, warmup, step):
        """Detection-latency bound: whatever quiet history the window
        holds, a sustained residual step above hi fires within W samples
        (after W pushes only step samples remain, so the mean exceeds
        hi; min_samples <= W)."""
        fires, _ = self._run(list(warmup) + [step] * self.CFG.window)
        assert not any(fires[:len(warmup)])
        assert any(fires[len(warmup):])

    @given(st.floats(0.0, 0.5),
           st.lists(st.floats(0.0, 1.0), min_size=1, max_size=60))
    @settings(**SETTINGS)
    def test_learned_thresholds_keep_stationary_bound(self, spread, raw):
        """Quantile-learned hysteresis preserves the false-positive bound:
        for any calibration spread, hi floors at the proven constant, caps
        below 1.0, keeps the lo/hi ratio, and residuals at or below the
        learned hi still never fire."""
        from repro.core.drift import (DriftParams, drift_init, drift_update,
                                      learned_thresholds)
        hi, lo = learned_thresholds(spread, self.CFG)
        assert self.CFG.hi <= hi <= 0.90
        assert lo / hi == pytest.approx(self.CFG.lo / self.CFG.hi)
        cfg = self._DC(window=8, hi=hi, lo=lo, min_samples=4)
        state = drift_init(None, cfg.window)
        params = DriftParams.from_config(cfg)
        for r in raw:
            state, fired, _ = drift_update(state, r * hi * 0.98, True,
                                           params)
            assert not bool(fired)

    @given(st.lists(st.floats(0.15 * 1.05, 50.0), min_size=1,
                    max_size=120))
    @settings(**SETTINGS)
    def test_hysteresis_prevents_flapping(self, errs):
        """Once fired, the lane disarms and only re-arms after the score
        drops below lo: a sequence that never recovers below lo fires at
        most once, however long it stays elevated."""
        fires, state = self._run(errs)
        assert sum(fires) <= 1
        if any(fires):
            assert not bool(state.armed)


class TestQuantizeProperties:
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([8, 4]))
    @settings(**SETTINGS)
    def test_roundtrip_bounded_by_half_step(self, seed, bits):
        x = jax.random.normal(jax.random.PRNGKey(seed), (256, 512))
        q, s = ref.quantize_ref(x, bits=bits)
        xd = ref.dequantize_ref(q, s)
        step = np.repeat(np.repeat(np.asarray(s), 256, 0), 512, 1)
        assert (np.abs(np.asarray(xd - x)) <= step * 0.5 + 1e-7).all()

    @given(st.integers(0, 2 ** 31 - 1))
    @settings(**SETTINGS)
    def test_quantize_scale_invariance(self, seed):
        """q(c*x) ~= q(x) for positive per-tensor scale c (symmetric quant);
        exact except where fp32 division lands on a rounding tie (+-1 level,
        rare)."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (256, 512))
        q1, _ = ref.quantize_ref(x)
        q2, _ = ref.quantize_ref(x * 7.5)
        d = np.abs(np.asarray(q1, np.int32) - np.asarray(q2, np.int32))
        assert d.max() <= 1 and (d != 0).mean() < 1e-3


class TestWkvProperties:
    @given(st.integers(0, 1000), st.sampled_from([16, 32]))
    @settings(max_examples=10, deadline=None)
    def test_chunk_invariance(self, seed, chunk):
        """wkv output is independent of the chunk partition (exactness)."""
        from repro.models.rwkv6 import wkv_chunked
        key = jax.random.PRNGKey(seed)
        B, S, H, K = 1, 64, 2, 8
        mk = lambda i: jax.random.normal(jax.random.fold_in(key, i),
                                         (B, S, H, K)) * 0.5
        r, k, v = mk(0), mk(1), mk(2)
        logw = -jnp.exp(mk(3) - 2.0)
        u = jax.random.normal(jax.random.fold_in(key, 4), (H, K)) * 0.5
        y1, s1 = wkv_chunked(r, k, v, logw, u, chunk=chunk)
        y2, s2 = ref.wkv_ref(r, k, v, logw, u)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=3e-4, atol=3e-4)
