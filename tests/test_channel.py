"""Channel model calibration against the paper's measurements."""

import numpy as np
import pytest

from repro.core.channel import WirelessChannel, calibrated_channel

# Paper Table 1: (size_kB, ONE_Lat_ms, FIVE_Lat_ms)
TABLE1 = [
    (610, 32.09, 150.28), (760, 35.16, 164.56), (970, 46.09, 262.43),
    (1390, 59.71, 382.47), (1670, 68.73, 606.98), (1740, 72.72, 617.16),
]
# Paper Table 2: DukeMTMC complex (1740 kB), 5 fps at 6 m, n = 1..5
TABLE2 = [72.72, 128.97, 341.18, 518.31, 617.16]


class TestCalibration:
    def test_table1_within_tolerance(self):
        ch = calibrated_channel()
        for size_kb, one, five in TABLE1:
            p1 = ch.p95_latency(size_kb * 1e3, n=1) * 1e3
            p5 = ch.p95_latency(size_kb * 1e3, n=5) * 1e3
            assert abs(p1 - one) / one < 0.12, (size_kb, p1, one)
            assert abs(p5 - five) / five < 0.12, (size_kb, p5, five)

    def test_contention_ratio_range(self):
        """FIVE/ONE is 4.6x-8.8x in the paper, growing with size."""
        ch = calibrated_channel()
        r_small = (ch.p95_latency(610e3, n=5) / ch.p95_latency(610e3, n=1))
        r_big = (ch.p95_latency(1740e3, n=5) / ch.p95_latency(1740e3, n=1))
        assert 4.0 < r_small < 5.5
        assert 7.5 < r_big < 9.5
        assert r_big > r_small

    def test_table2_node_sweep_shape(self):
        ch = calibrated_channel()
        pred = [ch.p95_latency(1740e3, n=n) * 1e3 for n in range(1, 6)]
        # endpoints tight, interior within 35% (the paper's interior points
        # carry single-run noise; the trend is what matters)
        assert abs(pred[0] - TABLE2[0]) / TABLE2[0] < 0.1
        assert abs(pred[4] - TABLE2[4]) / TABLE2[4] < 0.1
        for p, o in zip(pred, TABLE2):
            assert abs(p - o) / o < 0.35
        assert all(b > a for a, b in zip(pred, pred[1:]))

    def test_fps_and_distance_secondary(self):
        """Paper: 15 fps ~ 1.02x, 12 m ~ 1.06x at n=5."""
        ch = calibrated_channel()
        base = ch.p95_latency(1740e3, n=5, fps=5, distance_m=6)
        hi_fps = ch.p95_latency(1740e3, n=5, fps=15, distance_m=6)
        far = ch.p95_latency(1740e3, n=5, fps=5, distance_m=12)
        assert 1.0 < hi_fps / base < 1.10
        assert 1.0 < far / base < 1.12


class TestMechanics:
    def test_latency_linear_in_size_at_fixed_n(self):
        """Paper Fig. 5: approximately linear latency vs size."""
        ch = calibrated_channel()
        sizes = np.linspace(50e3, 900e3, 12)
        lats = ch.regression_points(sizes, n=5)
        a, b = np.polyfit(sizes, lats, 1)
        pred = a * sizes + b
        r2 = 1 - np.sum((lats - pred) ** 2) / np.sum((lats - lats.mean()) ** 2)
        # "approximately linear" (paper Fig. 5); the calibrated contention has
        # a mild super-linear component that matches Table 1 better
        assert r2 > 0.95

    def test_transfer_jitter_seeded(self):
        a = WirelessChannel(seed=7)
        b = WirelessChannel(seed=7)
        la = [a.transfer(500e3, n=3) for _ in range(20)]
        lb = [b.transfer(500e3, n=3) for _ in range(20)]
        np.testing.assert_allclose(la, lb)

    def test_active_set_tracking(self):
        ch = calibrated_channel()
        ch.activate("a"); ch.activate("b"); ch.activate("b")
        assert ch.num_active == 2
        ch.deactivate("a")
        assert ch.num_active == 1

    def test_interference_scales_latency(self):
        base = calibrated_channel().p95_latency(500e3, n=5)
        x10 = calibrated_channel(interference=10.0).p95_latency(500e3, n=5)
        assert abs(x10 / base - 10.0) < 1e-6

    def test_workload_scale(self):
        raw = calibrated_channel().p95_latency(90e3, n=5)
        jaad = calibrated_channel(workload="jaad").p95_latency(90e3, n=5)
        duke = calibrated_channel(workload="dukemtmc").p95_latency(90e3, n=5)
        assert raw < jaad < duke
