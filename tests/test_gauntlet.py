"""MQTT ingress bridge (topic scheme, QoS 0/1 onto credit backpressure)
and the gauntlet heavy-traffic harness (seeded determinism, regression
gate, full soak behind the slow marker)."""

import numpy as np
import pytest

from benchmarks.check_regression import check_gauntlet
from benchmarks.common import synthetic_controller_table
from benchmarks.gauntlet import PHASES, run_gauntlet, run_phase
from repro.core.broker import MezSystem
from repro.core.channel import calibrated_channel
from repro.core.characterization import fit_latency_regression
from repro.core.mqtt_bridge import (MQTT_ERR_NO_CONN, MQTT_ERR_QUEUE_SIZE,
                                    MQTT_ERR_SUCCESS, MqttBridge,
                                    parse_topic, topic_for, topic_matches)
from repro.data.camera import CameraConfig, SyntheticCamera


@pytest.fixture(scope="module")
def table():
    return synthetic_controller_table()


def bridge_system(table, *, n_cams=2, seed=3):
    """A fleet with registered (empty-log) cameras: the bridge, not the
    builder, is the ingress path."""
    ch = calibrated_channel(seed=seed)
    sys = MezSystem(ch)
    sizes = np.linspace(table.sizes_sorted[0], table.sizes_sorted[-1], 12)
    reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=n_cams))
    for i in range(n_cams):
        cam = sys.add_camera(f"cam{i}")
        src = SyntheticCamera(CameraConfig(camera_id=f"cam{i}",
                                           dynamics="medium", seed=7))
        cam.background = src.background
        cam.set_target(0.100, 0.90, table, reg)
    return sys


def frames_for(camera_id, n, *, seed=7):
    src = SyntheticCamera(CameraConfig(camera_id=camera_id,
                                       dynamics="medium", seed=seed))
    return list(src.stream(n))


class TestTopicScheme:
    def test_topic_round_trip(self):
        assert topic_for("cam3") == "mez/cam3/frames"
        assert parse_topic("mez/cam3/frames") == "cam3"

    def test_parse_rejects_non_frame_topics(self):
        for bad in ("mez/cam0", "mez/cam0/control", "other/cam0/frames",
                    "mez//frames", "mez/+/frames", "mez/#"):
            assert parse_topic(bad) is None

    def test_wildcard_matching(self):
        assert topic_matches("mez/+/frames", "mez/cam0/frames")
        assert topic_matches("mez/#", "mez/cam0/frames")
        assert topic_matches("#", "mez/cam0/frames")
        assert topic_matches("mez/cam0/frames", "mez/cam0/frames")
        assert not topic_matches("mez/+/frames", "mez/cam0/control")
        assert not topic_matches("mez/+", "mez/cam0/frames")
        assert not topic_matches("mez/cam1/frames", "mez/cam0/frames")


class TestMqttRoundTrip:
    def test_publish_subscribe_round_trip(self, table):
        """The acceptance path: frames in over MQTT topics, FrameBatches
        back out as topic messages, callbacks in paho shape."""
        sys = bridge_system(table, n_cams=2)
        bridge = MqttBridge(sys)
        seen, acked = [], []
        bridge.on_publish = lambda c, u, mid: acked.append(mid)
        rc, _mid = bridge.subscribe("mez/+/frames",
                                    lambda c, u, m: seen.append(m))
        assert rc == MQTT_ERR_SUCCESS
        for cid in ("cam0", "cam1"):
            for ts, frame, _ in frames_for(cid, 5):
                info = bridge.publish(topic_for(cid), frame, qos=1,
                                      timestamp=ts)
                assert info.rc == MQTT_ERR_SUCCESS and info.is_published()
        msgs = bridge.pump(max_frames=32)
        assert len(msgs) == len(seen) == 10
        assert len(acked) == 10
        per_cam = {}
        for m in msgs:
            cid = parse_topic(m.topic)
            per_cam.setdefault(cid, []).append(m.timestamp)
        for cid, stamps in per_cam.items():
            assert stamps == sorted(stamps) and len(stamps) == 5
        # frames landed in the broker logs exactly once (at-most-once)
        assert len(sys.edge.replicas["cam0"]) == 5
        assert bridge.stats()["delivered"] == 10

    def test_unknown_topic_is_no_conn(self, table):
        bridge = MqttBridge(bridge_system(table))
        info = bridge.publish("mez/ghost/frames", None)
        assert info.rc == MQTT_ERR_NO_CONN and not info.is_published()
        assert bridge.subscribe("mez/ghost/frames")[0] == MQTT_ERR_NO_CONN


class TestQosSemantics:
    def test_qos0_drops_vs_qos1_retries_under_loss(self, table):
        """Same seeded lossy hop: at-most-once sheds what the channel
        eats; at-least-once retransmits (DUPs deduped by the log's
        ordering rule) and delivers nearly everything."""
        results = {}
        for qos in (0, 1):
            sys = bridge_system(table, n_cams=1)
            bridge = MqttBridge(sys, loss_rate=0.4, seed=7)
            for ts, frame, _ in frames_for("cam0", 30):
                bridge.publish(topic_for("cam0"), frame, qos=qos,
                               timestamp=ts)
            results[qos] = (bridge.published, bridge.stats(),
                            len(sys.cams["cam0"].log))
        pub0, stats0, log0 = results[0]
        pub1, stats1, log1 = results[1]
        assert pub0 < 30 and stats0["dropped_qos0"] == 30 - pub0
        assert stats0["retries"] == 0          # at most once: never retried
        assert pub1 > pub0                     # retries recover losses
        assert stats1["retries"] > 0
        assert log1 == pub1                    # DUPs deduped: log sees one
        assert stats1["give_ups"] == 30 - pub1

    def test_qos1_duplicates_are_deduped_by_log_order(self, table):
        """A lost PUBACK forces a DUP retransmission the log must reject
        (timestamp <= last) -- the frame is delivered once."""
        sys = bridge_system(table, n_cams=1)
        bridge = MqttBridge(sys, loss_rate=0.35, seed=11)
        for ts, frame, _ in frames_for("cam0", 30):
            bridge.publish(topic_for("cam0"), frame, qos=1, timestamp=ts)
        assert bridge.duplicates > 0
        assert len(sys.cams["cam0"].log) == bridge.published


class TestCreditBackpressure:
    def test_qos0_shed_and_qos1_queued_when_credits_exhausted(self, table):
        sys = bridge_system(table, n_cams=1)
        bridge = MqttBridge(sys, ingress_credits=2)
        bridge.subscribe("mez/cam0/frames")
        stream = frames_for("cam0", 5)
        for ts, frame, _ in stream[:2]:
            assert bridge.publish(topic_for("cam0"), frame,
                                  timestamp=ts).is_published()
        assert bridge.credits("cam0") == 0
        ts2, f2, _ = stream[2]
        shed = bridge.publish(topic_for("cam0"), f2, qos=0, timestamp=ts2)
        assert shed.rc == MQTT_ERR_QUEUE_SIZE and not shed.is_published()
        ts3, f3, _ = stream[3]
        parked = bridge.publish(topic_for("cam0"), f3, qos=1, timestamp=ts3)
        assert parked.queued and not parked.is_published()
        # delivery returns credits, which unpark the QoS 1 publish -- and
        # the same drain keeps going, so the unparked frame flows too
        assert len(bridge.pump()) == 3
        assert parked.is_published()
        assert bridge.pump() == []
        assert bridge.stats()["queued_now"] == 0

    def test_crashed_camera_queues_qos1_until_recovery(self, table):
        """QoS 1 publishes against a crashed camera park; recovery flushes
        them in ORIGINAL publish order (the log's monotonic-timestamp rule
        silently rejects a reordered replay), each paying its ingress
        credit exactly once, with ``queued_total`` counting the park --
        not the requeue retries."""
        sys = bridge_system(table, n_cams=1)
        bridge = MqttBridge(sys)
        bridge.subscribe("mez/cam0/frames")
        stream = frames_for("cam0", 6)
        sys.cams["cam0"].crash()
        ts0, f0, _ = stream[0]
        drop = bridge.publish(topic_for("cam0"), f0, qos=0, timestamp=ts0)
        assert drop.rc == MQTT_ERR_NO_CONN
        parked = [(ts, bridge.publish(topic_for("cam0"), frame, qos=1,
                                      timestamp=ts))
                  for ts, frame, _ in stream[1:5]]
        assert all(info.queued for _, info in parked)
        assert len(sys.cams["cam0"].log) == 0
        assert bridge.stats()["queued_total"] == 4  # one count per park
        assert bridge.credits("cam0") == bridge.ingress_credits
        # a flush attempt while still down re-parks head-of-line: no
        # re-count, no credit burned, nothing reordered
        bridge.grant("cam0", 0)
        assert bridge.stats()["queued_total"] == 4
        assert bridge.stats()["queued_now"] == 4
        assert len(sys.cams["cam0"].log) == 0
        sys.cams["cam0"].recover()
        bridge.grant("cam0", 0)                # kick the flush path
        assert all(info.is_published() for _, info in parked)
        assert bridge.stats()["queued_now"] == 0
        # flushed in original publish order: the log kept every frame
        assert [t for t, _ in sys.cams["cam0"].log.tail(8)] == \
            [t for t, _ in parked]
        # each flushed frame consumed exactly one credit...
        assert bridge.credits("cam0") == bridge.ingress_credits - 4
        # ...returned once on delivery, closing the window exactly
        assert len(bridge.pump()) == 4
        assert bridge.credits("cam0") == bridge.ingress_credits
        assert bridge.stats()["queued_total"] == 4


class TestGauntletHarness:
    def test_smoke_phase_is_seeded_deterministic(self):
        """Two fresh runs of one phase agree bit-for-bit (minus wall
        clock): the whole harness is driven by seeded generators."""
        runs = []
        for _ in range(2):
            m = run_phase("qos_storm", PHASES["qos_storm"](7))
            m.pop("wall_s")
            runs.append(m)
        assert runs[0] == runs[1]
        assert runs[0]["frames_delivered"] > 0
        assert runs[0]["credits"]["leaked"] == 0

    def test_gate_catches_credit_leak_and_tail_regression(self):
        baseline = {"seed": 7, "phases": {
            "crash_wave": {"max_p999_ms": 100.0}}}
        good = {"seed": 7, "phases": {"crash_wave": {
            "p999_ms": 90.0, "frames_delivered": 10,
            "credits": {"leaked": 0, "in_flight": 0, "dropped": 0},
            "cache": {"hit_rate": 0.9}}}}
        assert check_gauntlet(good, baseline) == []
        leaky = {"seed": 7, "phases": {"crash_wave": {
            "p999_ms": 150.0, "frames_delivered": 10,
            "credits": {"leaked": 3, "in_flight": 2, "dropped": 1},
            "cache": {"hit_rate": 0.9}}}}
        failures = check_gauntlet(leaky, baseline)
        assert any("leaked" in f for f in failures)
        assert any("in_flight" in f for f in failures)
        assert any("dropped" in f for f in failures)
        assert any("p999_ms" in f for f in failures)
        assert check_gauntlet({"seed": 8, "phases": {}}, baseline)

    @pytest.mark.slow
    def test_full_soak_conserves_credits_and_degrades(self):
        """The long-phase soak (CI: race-guarded slow job): every phase's
        ledger conserves and admission control still reacts."""
        payload = run_gauntlet(seed=7, full=True)
        for name, m in payload["phases"].items():
            cr = m["credits"]
            assert cr["leaked"] == 0, (name, cr)
            assert cr["in_flight"] == 0, (name, cr)
            assert cr["dropped"] == 0, (name, cr)
            assert m["frames_delivered"] > 0
        assert payload["phases"]["oversub"]["admission_rejected"] >= 1
        assert payload["phases"]["oversub"]["tenant_degraded"] >= 1
        assert payload["phases"]["churn64"]["cache"]["hit_rate"] >= 0.85
