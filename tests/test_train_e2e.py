"""End-to-end training: loss decreases, faults recover, serving works."""

import jax
import numpy as np
import pytest

from repro.launch.serve import serve
from repro.launch.train import train


class TestTraining:
    def test_loss_decreases(self, tmp_path):
        out = train("qwen3-1.7b", steps=25, batch=4, seq=64,
                    checkpoint_dir=str(tmp_path), checkpoint_every=10,
                    log_every=1000)
        assert out["final_loss"] < out["first_loss"]

    def test_failure_injection_recovers_from_checkpoint(self, tmp_path):
        out = train("llama3-8b", steps=30, batch=4, seq=64,
                    checkpoint_dir=str(tmp_path), checkpoint_every=10,
                    inject_failure_at=22, log_every=1000)
        # recovery rewound to step 20's checkpoint and completed the run
        assert out["final_loss"] is not None
        assert len(out["losses"]) > 30 - 20   # replayed steps after restore

    def test_compressed_grads_train(self):
        out = train("internlm2-1.8b", steps=15, batch=4, seq=64,
                    grad_bits=8, log_every=1000)
        assert out["final_loss"] < out["first_loss"]


class TestServing:
    @pytest.mark.parametrize("arch", ["qwen3-1.7b", "rwkv6-1.6b",
                                      "zamba2-7b", "seamless-m4t-large-v2"])
    def test_serve_generates(self, arch):
        out = serve(arch, batch=2, prompt_len=32, gen=8)
        assert out["tokens"].shape == (2, 9)
        assert out["tokens_per_s"] > 0
        # enc-dec archs prefill a short decoder prompt (prompt_len // 8)
        # against the full-length encoder output; decoder-only archs prefill
        # the whole prompt
        from repro.configs import get_config
        dec_prompt = (max(1, 32 // 8)
                      if get_config(arch).is_encoder_decoder else 32)
        assert out["cache_len"] == dec_prompt + 8
