"""Beyond-paper: Mez-controlled approximate collectives on the cross-pod link.

The scenario (DESIGN.md §2): a 2-pod training job whose cross-pod gradient
reduction shares a DCN link with other tenants.  Link bandwidth varies 10x
(the paper's interference regime).  The SAME Algorithm-1 controller picks
the gradient compression level (bf16 / int8 / int4) each step:

  latency sensor   modeled collective time = payload_bytes / bw(t)
  regression       latency = bytes / bw_nominal (linear, zero intercept)
  size -> accuracy characterized offline: cosine fidelity of the
                   round-tripped gradient per level (real quantize kernels)
  floor            fidelity >= 0.98

Reports: step-latency series with/without control, SLO violations, fidelity
floor maintenance, and the end-to-end training-quality check (reduced model
trained with int8 grads reaches the bf16 loss within tolerance).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit
from repro.core.approx_comm import (LEVELS, CollectiveController,
                                    characterize_fidelity,
                                    collective_bytes_for, fidelity_table,
                                    make_grad_compressor)
from repro.core.characterization import LatencyRegression
from repro.core.controller import ControllerConfig, LatencyController


def _grad_sample(key=jax.random.PRNGKey(0)):
    return {"w1": jax.random.normal(key, (256, 512)) * 0.02,
            "w2": jax.random.normal(jax.random.fold_in(key, 1),
                                    (512, 256)) * 0.01}


def approx_collectives() -> dict:
    with Timer() as t:
        grads = _grad_sample()
        grad_bytes = sum(g.size * 2 for g in jax.tree_util.tree_leaves(grads))
        fidelity = characterize_fidelity(grads)

        bw_nominal = 25e9 / 8     # modeled per-host DCN share, bytes/s
        target = 1.5 * grad_bytes / bw_nominal     # SLO: 1.5x nominal xfer
        # the JITTED controller path (shared ControllerParams / one-lane
        # fleet_controller_step) picks the level each reduction...
        ctl = CollectiveController(
            grad_bytes, fidelity, latency_target=target,
            fidelity_floor=0.98, slope=1.0 / bw_nominal, intercept=1e-4)
        # ...and a shadow host LatencyController with the identical config
        # verifies the compiled decisions step for step
        reg = LatencyRegression(slope=1.0 / bw_nominal, intercept=1e-4)
        host = LatencyController(
            ControllerConfig(latency_target=target, accuracy_target=0.98,
                             error_threshold=0.05 * target),
            fidelity_table(grad_bytes, fidelity), reg)

        rng = np.random.default_rng(0)
        series_ctl, series_unc, levels, fids = [], [], [], []
        parity = True
        level_bits = 16
        for step in range(80):
            # contended link: bandwidth drops up to 10x mid-run
            contention = 10.0 if 25 <= step < 55 else 1.0
            bw = bw_nominal / contention * rng.lognormal(0, 0.1)
            lat_unc = grad_bytes / bw + 1e-4
            payload = collective_bytes_for(grad_bytes, level_bits)
            lat_ctl = payload / bw + 1e-4
            series_unc.append(lat_unc)
            series_ctl.append(lat_ctl)
            d = ctl.update(lat_ctl)
            dh = host.update(lat_ctl)
            parity &= d.setting_index == dh.setting_index
            level_bits = d.bits
            levels.append(level_bits)
            fids.append(fidelity[level_bits])

        series_ctl = np.asarray(series_ctl)
        series_unc = np.asarray(series_unc)
        out = {
            "fidelity_by_bits": fidelity,
            "slo_s": target,
            "ctl_p95_s": float(np.percentile(series_ctl[5:], 95)),
            "unc_p95_s": float(np.percentile(series_unc[5:], 95)),
            "ctl_violations": int((series_ctl[5:] > target * 1.2).sum()),
            "unc_violations": int((series_unc[5:] > target * 1.2).sum()),
            "min_fidelity": float(min(fids)),
            "levels_used": sorted(set(levels)),
            "latency_improvement": float(
                np.percentile(series_unc[25:55], 95)
                / np.percentile(series_ctl[25:55], 95)),
            "jit_host_parity": bool(parity),
            "controller_cache_size": ctl.cache_size(),
        }
    emit("approx_collectives", t.us,
         f"ctl_p95={out['ctl_p95_s']*1e3:.1f}ms "
         f"unc_p95={out['unc_p95_s']*1e3:.1f}ms "
         f"min_fid={out['min_fidelity']:.4f} "
         f"improve={out['latency_improvement']:.1f}x "
         f"parity={out['jit_host_parity']} "
         f"cache={out['controller_cache_size']}", out)
    return out


def compressed_training_quality() -> dict:
    """End-to-end: reduced qwen3 trained with int8 grad transport matches
    bf16 training loss within tolerance (the accuracy-floor claim)."""
    from repro.launch.train import train
    with Timer() as t:
        base = train("qwen3-1.7b", steps=25, batch=4, seq=64, grad_bits=16,
                     log_every=1000)
        comp = train("qwen3-1.7b", steps=25, batch=4, seq=64, grad_bits=8,
                     log_every=1000)
    out = {"bf16_final": base["final_loss"], "int8_final": comp["final_loss"],
           "bf16_first": base["first_loss"],
           "gap": abs(base["final_loss"] - comp["final_loss"])}
    emit("compressed_training_quality", t.us,
         f"bf16={out['bf16_final']:.4f};int8={out['int8_final']:.4f};"
         f"gap={out['gap']:.4f}", out)
    return out
