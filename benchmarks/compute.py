"""Compute-side benchmarks: Fig. 17 (compute latency vs resolution), Mez log
throughput (the design claim behind Section 4.3), and the Pallas frame-knobs
offload vs the host knob pipeline (the paper's Fig. 16 future-work item)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit
from repro.core import knobs as K
from repro.core.log import HostLog, frame_log_append, frame_log_init
from repro.data.camera import CameraConfig, SyntheticCamera


def fig17_compute_latency() -> dict:
    """Pedestrian-detection compute latency vs frame resolution.

    The paper measures OpenPose on a Titan V; here the subscriber model is
    the reduced qwen2-vl backbone consuming patch embeddings whose count
    scales with the resolution knob -- the same mechanism (resolution knob
    shrinks compute) on this testbed's hardware.
    """
    from repro.configs import get_config
    from repro.models.registry import build_model
    import dataclasses

    out = {"resolutions": {}}
    with Timer() as t:
        base_cfg = get_config("qwen2-vl-72b").reduced()
        for scale in K.RESOLUTION_SCALES:
            patches = max(4, int(64 * scale * scale))   # patch count ~ area
            cfg = dataclasses.replace(base_cfg, frontend_tokens=patches)
            m = build_model(cfg)
            params = m.init_params(jax.random.PRNGKey(0))
            batch = {
                "tokens": jnp.zeros((1, 8), jnp.int32),
                "patch_embeds": jnp.zeros((1, patches, cfg.d_model)),
            }
            fwd = jax.jit(lambda p, b: m.forward(p, b)[0])
            fwd(params, batch).block_until_ready()      # compile
            t0 = time.monotonic()
            for _ in range(5):
                fwd(params, batch).block_until_ready()
            ms = (time.monotonic() - t0) / 5 * 1e3
            out["resolutions"][f"{scale:.2f}"] = {
                "patches": patches, "forward_ms": ms}
    vals = [v["forward_ms"] for v in out["resolutions"].values()]
    emit("fig17_compute_latency", t.us,
         f"full={vals[0]:.1f}ms;quarter={vals[-1]:.1f}ms;"
         f"monotone={all(a >= b - 0.4 for a, b in zip(vals, vals[1:]))}",
         out)
    return out


def log_throughput() -> dict:
    """Mez storage-layer performance: append/query rates (host + device)."""
    out = {}
    with Timer() as t:
        frame = np.zeros((144, 256, 3), np.uint8)
        log = HostLog(4096, topic="bench")
        t0 = time.monotonic()
        for i in range(2000):
            log.append(float(i), frame)
        dt = time.monotonic() - t0
        out["host_append_us"] = dt / 2000 * 1e6
        t0 = time.monotonic()
        for i in range(500):
            log.point_query(float(i * 3))
        out["host_point_query_us"] = (time.monotonic() - t0) / 500 * 1e6
        t0 = time.monotonic()
        n = sum(1 for _ in log.range_query(100.0, 400.0))
        out["host_range_query_us"] = (time.monotonic() - t0) * 1e6
        out["host_range_n"] = n

        # device ring buffer, jitted append
        dlog = frame_log_init(256, (144, 256, 3))
        append = jax.jit(frame_log_append, donate_argnums=(0,))
        dlog = append(dlog, 0.0, jnp.zeros((144, 256, 3), jnp.uint8))
        t0 = time.monotonic()
        for i in range(1, 200):
            dlog = append(dlog, float(i),
                          jnp.zeros((144, 256, 3), jnp.uint8))
        jax.block_until_ready(dlog.timestamps)
        out["device_append_us"] = (time.monotonic() - t0) / 199 * 1e6
    emit("log_throughput", t.us,
         f"host_append={out['host_append_us']:.0f}us;"
         f"point_q={out['host_point_query_us']:.0f}us", out)
    return out


def knob_pipeline_cost() -> dict:
    """Host OpenCV-style knob pipeline vs the fused Pallas kernel (interpret
    mode on CPU -- the TPU offload validates numerically; wall-clock wins
    need the real Mosaic backend, recorded as the design target)."""
    from repro.kernels.ops import frame_knobs as fused
    out = {}
    with Timer() as t:
        cam = SyntheticCamera(CameraConfig(dynamics="complex", seed=7))
        frames = [f for _, f, _ in cam.stream(8)]
        setting = K.KnobSetting(resolution=2, colorspace=1, blur=1)
        t0 = time.monotonic()
        for f in frames:
            K.apply_knobs(f, setting, background=cam.background)
        out["host_knobs_ms_per_frame"] = (
            (time.monotonic() - t0) / len(frames) * 1e3)
        out["modeled_overhead_ms"] = setting.overhead_ms
        # fused kernel path (gray planes)
        gray = jnp.asarray(np.stack(
            [f.astype(np.float32).mean(-1) for f in frames]))
        prev = jnp.roll(gray, 1, axis=0)
        y, ch = fused(gray, prev, blur_k=5)
        jax.block_until_ready(y)
        t0 = time.monotonic()
        y, ch = fused(gray, prev, blur_k=5)
        jax.block_until_ready(y)
        out["fused_kernel_ms_per_frame_interpret"] = (
            (time.monotonic() - t0) / len(frames) * 1e3)
        out["note"] = ("interpret mode executes the kernel body in Python; "
                       "TPU wall-clock is the deployment target")
    emit("knob_pipeline_cost", t.us,
         f"host={out['host_knobs_ms_per_frame']:.1f}ms/frame", out)
    return out
