"""CI benchmark regression gate for the characterization sweep.

Diffs a freshly produced ``BENCH_characterize.json`` against the committed
baseline (``benchmarks/baseline_characterize.json``) and FAILS the job when
the batched engine's perf or fidelity rots:

  * speedup (with and without knob4) dropped more than ``--max-speedup-drop``
    (default 20%) below the baseline,
  * the wire-size proxy's median relative error exceeds ``--max-proxy-err``
    (default 5%),
  * the batched engine stopped agreeing with the reference oracle (kept
    sets diverge, or shared-setting accuracies drift past 0.1%).

Speedups are RATIOS of two runs on the same machine, so they transfer
across runner generations where absolute seconds would not -- but they
still jitter with runner contention, so the committed baseline pins its
speedup fields at the LOW end of the observed spread (not a lucky best
run): the 20% floor then absorbs ordinary noise while a genuine rot of
the batched path still trips it.  Update the baseline deliberately (fresh
measurements, conservative speedup floors, in the same PR that changes
the engine) -- never by loosening the thresholds.

  PYTHONPATH=src python -m benchmarks.check_regression \
      [--fresh BENCH_characterize.json] \
      [--baseline benchmarks/baseline_characterize.json]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_FRESH = os.path.join(os.path.dirname(_HERE),
                             "BENCH_characterize.json")
DEFAULT_BASELINE = os.path.join(_HERE, "baseline_characterize.json")


def check(fresh: dict, baseline: dict, *, max_speedup_drop: float,
          max_proxy_err: float) -> list[str]:
    """Returns the list of violated gate conditions (empty = pass)."""
    failures: list[str] = []

    def gate_speedup(key: str) -> None:
        base = baseline.get(key)
        got = fresh.get(key)
        if base is None:
            return                       # baseline predates this metric
        if got is None:
            failures.append(f"{key}: missing from fresh results "
                            f"(baseline {base})")
            return
        floor = base * (1.0 - max_speedup_drop)
        if got < floor:
            failures.append(
                f"{key}: {got:.2f}x dropped more than "
                f"{max_speedup_drop:.0%} below baseline {base:.2f}x "
                f"(floor {floor:.2f}x)")

    gate_speedup("speedup_vs_seed_path")
    gate_speedup("speedup_with_artifact")

    err = fresh.get("proxy_median_rel_err")
    if err is None:
        failures.append("proxy_median_rel_err: missing from fresh results")
    elif err > max_proxy_err:
        failures.append(f"proxy_median_rel_err: {err:.4f} exceeds the "
                        f"{max_proxy_err:.0%} bound")

    for suffix in ("", "_art"):
        kb = fresh.get(f"kept_settings_batched{suffix}")
        kr = fresh.get(f"kept_settings_reference{suffix}")
        ov = fresh.get(f"kept_overlap{suffix}")
        if kb is None or kr is None or ov is None:
            continue
        if not (kb == kr == ov):
            failures.append(
                f"kept set{suffix or ''} diverged: batched={kb} "
                f"reference={kr} overlap={ov} (must be identical)")
        acc = fresh.get(f"acc_max_diff_on_shared{suffix}", 0.0)
        if acc > 1e-3:
            failures.append(
                f"acc_max_diff_on_shared{suffix}: {acc} exceeds 1e-3 -- "
                f"batched detector scoring drifted from the oracle")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default=DEFAULT_FRESH,
                    help="benchmark json produced by this CI run")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline json")
    ap.add_argument("--max-speedup-drop", type=float, default=0.20,
                    help="allowed fractional speedup regression (0.20=20%%)")
    ap.add_argument("--max-proxy-err", type=float, default=0.05,
                    help="allowed wire-size proxy median relative error")
    args = ap.parse_args()

    with open(args.fresh) as fh:
        fresh = json.load(fh)
    with open(args.baseline) as fh:
        baseline = json.load(fh)

    failures = check(fresh, baseline,
                     max_speedup_drop=args.max_speedup_drop,
                     max_proxy_err=args.max_proxy_err)
    print(f"fresh:    speedup={fresh.get('speedup_vs_seed_path')}x "
          f"art={fresh.get('speedup_with_artifact')}x "
          f"proxy_err={fresh.get('proxy_median_rel_err')}")
    print(f"baseline: speedup={baseline.get('speedup_vs_seed_path')}x "
          f"art={baseline.get('speedup_with_artifact')}x "
          f"proxy_err={baseline.get('proxy_median_rel_err')}")
    if failures:
        print(f"\nBENCHMARK REGRESSION GATE FAILED "
              f"({len(failures)} violation(s)):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("benchmark regression gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
