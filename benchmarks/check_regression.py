"""CI benchmark regression gate for the characterization sweep and the
fleet control plane.

Diffs a freshly produced ``BENCH_characterize.json`` against the committed
baseline (``benchmarks/baseline_characterize.json``) and FAILS the job when
the batched engine's perf or fidelity rots:

  * speedup (with and without knob4) dropped more than ``--max-speedup-drop``
    (default 20%) below the baseline,
  * the wire-size proxy's median relative error exceeds ``--max-proxy-err``
    (default 5%),
  * the batched engine stopped agreeing with the reference oracle (kept
    sets diverge, or shared-setting accuracies drift past 0.1%).

Speedups are RATIOS of two runs on the same machine, so they transfer
across runner generations where absolute seconds would not -- but they
still jitter with runner contention, so the committed baseline pins its
speedup fields at the LOW end of the observed spread (not a lucky best
run): the 20% floor then absorbs ordinary noise while a genuine rot of
the batched path still trips it.  Update the baseline deliberately (fresh
measurements, conservative speedup floors, in the same PR that changes
the engine) -- never by loosening the thresholds.

When ``BENCH_fleet.json`` exists (produced by ``benchmarks.fleet_sweep``),
the fleet gate also runs against ``benchmarks/baseline_fleet.json``: the
vmapped fleet step must stay sublinear in camera count
(``scaling_256_over_64`` under the committed ceiling -- linear would be
4.0), keep a healthy speedup over the per-camera jitted-dispatch loop, and
compile exactly once across the sweep.  The whole-poll gates additionally
bound the REAL ``poll_subscription`` cost (fetch + merge + one fused
sharded tick): per-camera cost at 64 lanes under a generous absolute
ceiling, and per-camera cost at 4096 lanes on the forced 8-device mesh
within the committed flatness ratio of the 64-lane figure.  The
multi-tenant gates bound per-tenant whole-poll cost at 64 tenants over
one 256-camera fleet relative to the single-tenant figure (the shared
degraded-frame cache must amortize transforms across tenants) and floor
that cache's hit rate.

When ``BENCH_fig12.json`` exists (produced by ``python -m benchmarks.paper
fig12``), the fig12 gate runs against ``benchmarks/baseline_fig12.json``:
under the scripted workload shift the drift-aware refresh arm must hold
measured F1 within the committed bound of the offline-characterized oracle
arm, detect the shift within the latency bound, refresh exactly the
shifted cameras, keep both the drift monitor and the fleet step at one
compiled variant -- and the no-refresh control arm must still degrade
(otherwise the scenario stopped exercising staleness at all).

When ``BENCH_gauntlet.json`` exists (produced by ``python -m
benchmarks.gauntlet``), the gauntlet gate runs against
``benchmarks/baseline_gauntlet.json``: every phase's credit ledger must
conserve (granted - returned - in_flight - dropped == 0, with in-flight
and dropped both zero after the phase drains -- the crash-wave phase is
the one that trips when ``reattach_camera`` leaks credits held by
in-flight fetches at crash time), per-phase p99.9 delivered latency must
stay under the committed ceiling, the 64-tenant churn phase must keep the
shared-frame-cache hit rate above its floor (LRU eviction holding the hot
set through subscribe/unsubscribe floods), and the oversubscription phase
must still degrade and reject (otherwise admission control went dark).
The gauntlet's latencies are simulated from a seeded channel, so unlike
the timing gates these thresholds are tight -- a trip means behavior
changed, not that the runner was busy.

  PYTHONPATH=src python -m benchmarks.check_regression \
      [--fresh BENCH_characterize.json] \
      [--baseline benchmarks/baseline_characterize.json] \
      [--fleet-fresh BENCH_fleet.json] \
      [--fleet-baseline benchmarks/baseline_fleet.json] \
      [--fig12-fresh BENCH_fig12.json] \
      [--fig12-baseline benchmarks/baseline_fig12.json] \
      [--gauntlet-fresh BENCH_gauntlet.json] \
      [--gauntlet-baseline benchmarks/baseline_gauntlet.json]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_FRESH = os.path.join(os.path.dirname(_HERE),
                             "BENCH_characterize.json")
DEFAULT_BASELINE = os.path.join(_HERE, "baseline_characterize.json")
DEFAULT_FLEET_FRESH = os.path.join(os.path.dirname(_HERE),
                                   "BENCH_fleet.json")
DEFAULT_FLEET_BASELINE = os.path.join(_HERE, "baseline_fleet.json")
DEFAULT_FIG12_FRESH = os.path.join(os.path.dirname(_HERE),
                                   "BENCH_fig12.json")
DEFAULT_FIG12_BASELINE = os.path.join(_HERE, "baseline_fig12.json")
DEFAULT_GAUNTLET_FRESH = os.path.join(os.path.dirname(_HERE),
                                      "BENCH_gauntlet.json")
DEFAULT_GAUNTLET_BASELINE = os.path.join(_HERE, "baseline_gauntlet.json")


def check(fresh: dict, baseline: dict, *, max_speedup_drop: float,
          max_proxy_err: float) -> list[str]:
    """Returns the list of violated gate conditions (empty = pass)."""
    failures: list[str] = []

    def gate_speedup(key: str) -> None:
        base = baseline.get(key)
        got = fresh.get(key)
        if base is None:
            return                       # baseline predates this metric
        if got is None:
            failures.append(f"{key}: missing from fresh results "
                            f"(baseline {base})")
            return
        floor = base * (1.0 - max_speedup_drop)
        if got < floor:
            failures.append(
                f"{key}: {got:.2f}x dropped more than "
                f"{max_speedup_drop:.0%} below baseline {base:.2f}x "
                f"(floor {floor:.2f}x)")

    gate_speedup("speedup_vs_seed_path")
    gate_speedup("speedup_with_artifact")

    err = fresh.get("proxy_median_rel_err")
    if err is None:
        failures.append("proxy_median_rel_err: missing from fresh results")
    elif err > max_proxy_err:
        failures.append(f"proxy_median_rel_err: {err:.4f} exceeds the "
                        f"{max_proxy_err:.0%} bound")

    for suffix in ("", "_art"):
        kb = fresh.get(f"kept_settings_batched{suffix}")
        kr = fresh.get(f"kept_settings_reference{suffix}")
        ov = fresh.get(f"kept_overlap{suffix}")
        if kb is None or kr is None or ov is None:
            continue
        if not (kb == kr == ov):
            failures.append(
                f"kept set{suffix or ''} diverged: batched={kb} "
                f"reference={kr} overlap={ov} (must be identical)")
        acc = fresh.get(f"acc_max_diff_on_shared{suffix}", 0.0)
        if acc > 1e-3:
            failures.append(
                f"acc_max_diff_on_shared{suffix}: {acc} exceeds 1e-3 -- "
                f"batched detector scoring drifted from the oracle")
    return failures


def check_fleet(fresh: dict, baseline: dict) -> list[str]:
    """Gate BENCH_fleet.json against the committed conservative thresholds.
    Returns the violated conditions (empty = pass)."""
    failures: list[str] = []
    scaling = fresh.get("scaling_256_over_64")
    ceiling = baseline.get("max_scaling_256_over_64")
    if scaling is None:
        failures.append("scaling_256_over_64: missing from fleet results")
    elif ceiling is not None and scaling > ceiling:
        failures.append(
            f"scaling_256_over_64: {scaling:.2f} exceeds the committed "
            f"ceiling {ceiling:.2f} (linear would be 4.0) -- the fleet "
            f"step stopped being ~flat in camera count")
    speedup = fresh.get("speedup_vs_python_loop_64")
    floor = baseline.get("min_speedup_vs_python_loop_64")
    if speedup is None:
        failures.append("speedup_vs_python_loop_64: missing from fleet "
                        "results")
    elif floor is not None and speedup < floor:
        failures.append(
            f"speedup_vs_python_loop_64: {speedup:.1f}x fell below the "
            f"committed floor {floor:.1f}x -- one compiled vmapped step "
            f"should beat 64 per-camera dispatches comfortably")
    cache = fresh.get("cache_size")
    max_cache = baseline.get("max_cache_size", 1)
    if cache is None:
        failures.append("cache_size: missing from fleet results")
    elif cache > max_cache:
        failures.append(f"cache_size: {cache} compiled variants (> "
                        f"{max_cache}) -- the fleet step retraced")

    # whole-poll gates (fused poll_subscription path); baselines that
    # predate the metrics skip them
    per_cam_ceiling = baseline.get("max_whole_poll_us_per_cam_64")
    if per_cam_ceiling is not None:
        got = (fresh.get("whole_poll_us_per_cam") or {}).get("64")
        if got is None:
            failures.append("whole_poll_us_per_cam[64]: missing from "
                            "fleet results")
        elif got > per_cam_ceiling:
            failures.append(
                f"whole_poll_us_per_cam[64]: {got:.1f} us exceeds the "
                f"committed ceiling {per_cam_ceiling:.1f} us -- the whole "
                f"poll (fetch + merge + fused tick) regressed")
    flat_ceiling = baseline.get("max_whole_poll_flatness_4096_over_64")
    if flat_ceiling is not None:
        sharded = fresh.get("sharded") or {}
        flat = sharded.get("flatness_4096_over_64")
        if flat is None:
            failures.append("sharded.flatness_4096_over_64: missing from "
                            "fleet results (run fleet_sweep without "
                            "--skip-sharded)")
        elif flat > flat_ceiling:
            failures.append(
                f"sharded.flatness_4096_over_64: {flat:.2f} exceeds "
                f"{flat_ceiling:.2f} -- per-camera whole-poll cost at 4096 "
                f"lanes on the {sharded.get('devices')}-device mesh is no "
                f"longer flat relative to 64 lanes (per-poll host work "
                f"crept back to O(N))")

    # multi-tenant serving gates (shared degraded-frame cache); baselines
    # that predate the metrics skip them
    ratio_ceiling = baseline.get("max_tenant_poll_ratio_64_over_1")
    if ratio_ceiling is not None:
        mt = fresh.get("multi_tenant") or {}
        ratio = mt.get("tenant_poll_ratio_64_over_1")
        if ratio is None:
            failures.append("multi_tenant.tenant_poll_ratio_64_over_1: "
                            "missing from fleet results (run fleet_sweep "
                            "without --skip-tenants)")
        elif ratio > ratio_ceiling:
            failures.append(
                f"multi_tenant.tenant_poll_ratio_64_over_1: {ratio:.2f} "
                f"exceeds {ratio_ceiling:.2f} -- per-tenant whole-poll "
                f"cost at 64 tenants over {mt.get('cameras')} cameras is "
                f"no longer amortized by the shared degraded-frame cache")
    hit_floor = baseline.get("min_shared_cache_hit_rate_64")
    if hit_floor is not None:
        mt = fresh.get("multi_tenant") or {}
        hit = (mt.get("cache_hit_rate") or {}).get("64")
        if hit is None:
            failures.append("multi_tenant.cache_hit_rate[64]: missing "
                            "from fleet results")
        elif hit < hit_floor:
            failures.append(
                f"multi_tenant.cache_hit_rate[64]: {hit:.3f} fell below "
                f"the committed floor {hit_floor:.2f} -- 64 tenants at "
                f"one operating point stopped sharing transforms")
    return failures


def check_fig12(fresh: dict, baseline: dict) -> list[str]:
    """Gate BENCH_fig12.json (drift-aware refresh under a workload shift)
    against the committed thresholds.  Returns the violated conditions
    (empty = pass)."""
    failures: list[str] = []

    drop = fresh.get("f1_drop_vs_oracle")
    bound = baseline.get("max_f1_drop_vs_oracle", 0.05)
    if drop is None:
        failures.append("f1_drop_vs_oracle: missing from fig12 results")
    elif drop > bound:
        failures.append(
            f"f1_drop_vs_oracle: {drop:.4f} exceeds {bound:.0%} -- the "
            f"auto-refreshed tables stopped matching offline "
            f"characterization of the shifted regime")

    ctl_drop = fresh.get("f1_drop_without_refresh_vs_oracle")
    floor = baseline.get("min_f1_drop_without_refresh_vs_oracle")
    if ctl_drop is None:
        failures.append("f1_drop_without_refresh_vs_oracle: missing from "
                        "fig12 results")
    elif floor is not None and ctl_drop < floor:
        failures.append(
            f"f1_drop_without_refresh_vs_oracle: {ctl_drop:.4f} fell below "
            f"{floor:.2f} -- the control arm no longer degrades, so the "
            f"scenario stopped exercising table staleness")

    lat = fresh.get("detection_latency_s")
    lat_bound = baseline.get("max_detection_latency_s")
    if lat is None:
        failures.append("detection_latency_s: null -- the drift monitor "
                        "never fired on the scripted shift")
    elif lat_bound is not None and lat > lat_bound:
        failures.append(f"detection_latency_s: {lat:.2f}s exceeds the "
                        f"{lat_bound:.1f}s bound")

    expect = baseline.get("expect_refreshed_cameras")
    got = fresh.get("refreshed_cameras")
    if expect is not None and got != expect:
        failures.append(
            f"refreshed_cameras: {got} != {expect} -- the refresh must "
            f"land on exactly the shifted lanes (no false positives on "
            f"stationary cameras, no misses)")

    for key in ("drift_cache_size", "fleet_cache_size"):
        cache = fresh.get(key)
        max_cache = baseline.get(f"max_{key}", 1)
        if cache is not None and cache > max_cache:
            failures.append(f"{key}: {cache} compiled variants (> "
                            f"{max_cache}) -- retraced mid-scenario")
    return failures


def check_gauntlet(fresh: dict, baseline: dict) -> list[str]:
    """Gate BENCH_gauntlet.json (heavy-traffic phase harness) against the
    committed thresholds.  Returns the violated conditions (empty = pass)."""
    failures: list[str] = []
    if fresh.get("seed") != baseline.get("seed"):
        failures.append(
            f"gauntlet seed {fresh.get('seed')} != baseline seed "
            f"{baseline.get('seed')} -- thresholds only hold for the "
            f"committed seed; regenerate the baseline deliberately")
        return failures
    for name, gates in (baseline.get("phases") or {}).items():
        m = (fresh.get("phases") or {}).get(name)
        if m is None:
            failures.append(f"gauntlet phase '{name}': missing from fresh "
                            f"results")
            continue

        # unconditional invariants: the credit ledger must conserve after
        # every phase drains (camera crash/recover cycles must hand back
        # the credits their in-flight fetches held)
        cr = m.get("credits") or {}
        for key in ("leaked", "in_flight"):
            if cr.get(key, -1) != 0:
                failures.append(
                    f"gauntlet[{name}].credits.{key}: {cr.get(key)} != 0 "
                    f"-- fetch credits are not conserved across the phase "
                    f"(ledger: {cr})")
        max_drop = gates.get("max_dropped_credits", 0)
        if cr.get("dropped", -1) > max_drop:
            failures.append(
                f"gauntlet[{name}].credits.dropped: {cr.get('dropped')} "
                f"exceeds {max_drop} -- crashed cameras' credits were "
                f"written off instead of returned on reattach")

        ceiling = gates.get("max_p999_ms")
        got = m.get("p999_ms")
        if ceiling is not None:
            if got is None or got != got:            # None or NaN
                failures.append(f"gauntlet[{name}].p999_ms: missing/NaN "
                                f"(no frames delivered?)")
            elif got > ceiling:
                failures.append(
                    f"gauntlet[{name}].p999_ms: {got:.1f} ms exceeds the "
                    f"committed ceiling {ceiling:.1f} ms -- the delivered "
                    f"latency tail regressed under load")
        hit_floor = gates.get("min_cache_hit_rate")
        if hit_floor is not None:
            hit = (m.get("cache") or {}).get("hit_rate")
            if hit is None:
                failures.append(f"gauntlet[{name}].cache.hit_rate: missing")
            elif hit < hit_floor:
                failures.append(
                    f"gauntlet[{name}].cache.hit_rate: {hit:.3f} fell "
                    f"below the committed floor {hit_floor:.2f} -- LRU "
                    f"eviction stopped keeping the hot working set "
                    f"resident under tenant churn")
        min_frames = gates.get("min_frames_delivered")
        if (min_frames is not None
                and m.get("frames_delivered", 0) < min_frames):
            failures.append(
                f"gauntlet[{name}].frames_delivered: "
                f"{m.get('frames_delivered')} fell below {min_frames} -- "
                f"the phase stopped exercising sustained load")
        for key in ("tenant_degraded", "admission_rejected"):
            floor = gates.get(f"min_{key}")
            if floor is not None and m.get(key, 0) < floor:
                failures.append(
                    f"gauntlet[{name}].{key}: {m.get(key, 0)} fell below "
                    f"{floor} -- admission control stopped reacting to "
                    f"oversubscription")
        for key in ("camera_migrated", "broker_overload"):
            floor = gates.get(f"min_{key}")
            if floor is not None and m.get(key, 0) < floor:
                failures.append(
                    f"gauntlet[{name}].{key}: {m.get(key, 0)} fell below "
                    f"{floor} -- the federated herd stopped migrating "
                    f"cameras / flagging overloaded brokers under the "
                    f"scripted events")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default=DEFAULT_FRESH,
                    help="benchmark json produced by this CI run")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline json")
    ap.add_argument("--max-speedup-drop", type=float, default=0.20,
                    help="allowed fractional speedup regression (0.20=20%%)")
    ap.add_argument("--max-proxy-err", type=float, default=0.05,
                    help="allowed wire-size proxy median relative error")
    ap.add_argument("--fleet-fresh", default=DEFAULT_FLEET_FRESH,
                    help="fleet-scaling benchmark json (gated when present)")
    ap.add_argument("--fleet-baseline", default=DEFAULT_FLEET_BASELINE,
                    help="committed fleet gate thresholds")
    ap.add_argument("--fig12-fresh", default=DEFAULT_FIG12_FRESH,
                    help="fig12 workload-shift json (gated when present)")
    ap.add_argument("--fig12-baseline", default=DEFAULT_FIG12_BASELINE,
                    help="committed fig12 gate thresholds")
    ap.add_argument("--gauntlet-fresh", default=DEFAULT_GAUNTLET_FRESH,
                    help="gauntlet phase-harness json (gated when present)")
    ap.add_argument("--gauntlet-baseline",
                    default=DEFAULT_GAUNTLET_BASELINE,
                    help="committed gauntlet gate thresholds")
    args = ap.parse_args()

    with open(args.fresh) as fh:
        fresh = json.load(fh)
    with open(args.baseline) as fh:
        baseline = json.load(fh)

    failures = check(fresh, baseline,
                     max_speedup_drop=args.max_speedup_drop,
                     max_proxy_err=args.max_proxy_err)
    print(f"fresh:    speedup={fresh.get('speedup_vs_seed_path')}x "
          f"art={fresh.get('speedup_with_artifact')}x "
          f"proxy_err={fresh.get('proxy_median_rel_err')}")
    print(f"baseline: speedup={baseline.get('speedup_vs_seed_path')}x "
          f"art={baseline.get('speedup_with_artifact')}x "
          f"proxy_err={baseline.get('proxy_median_rel_err')}")
    if os.path.exists(args.fleet_fresh):
        with open(args.fleet_fresh) as fh:
            fleet_fresh = json.load(fh)
        with open(args.fleet_baseline) as fh:
            fleet_baseline = json.load(fh)
        failures += check_fleet(fleet_fresh, fleet_baseline)

        def fmt(key: str, spec: str) -> str:
            v = fleet_fresh.get(key)
            return format(v, spec) if isinstance(v, (int, float)) else str(v)

        print(f"fleet:    scaling_256/64={fmt('scaling_256_over_64', '.2f')} "
              f"speedup_vs_loop={fmt('speedup_vs_python_loop_64', '.1f')}x "
              f"cache={fleet_fresh.get('cache_size')}")
        sharded = fleet_fresh.get("sharded") or {}
        print(f"fleet:    whole_poll_us_per_cam="
              f"{fleet_fresh.get('whole_poll_us_per_cam')} "
              f"sharded_flatness_4096/64="
              f"{sharded.get('flatness_4096_over_64')}")
        mt = fleet_fresh.get("multi_tenant") or {}
        print(f"fleet:    tenant_poll_ratio_64/1="
              f"{mt.get('tenant_poll_ratio_64_over_1')} "
              f"cache_hit_rate={mt.get('cache_hit_rate')}")
    else:
        print(f"fleet:    {args.fleet_fresh} absent -- fleet gate skipped")
    if os.path.exists(args.fig12_fresh):
        with open(args.fig12_fresh) as fh:
            fig12_fresh = json.load(fh)
        with open(args.fig12_baseline) as fh:
            fig12_baseline = json.load(fh)
        failures += check_fig12(fig12_fresh, fig12_baseline)
        print(f"fig12:    drop_vs_oracle="
              f"{fig12_fresh.get('f1_drop_vs_oracle')} "
              f"control_drop="
              f"{fig12_fresh.get('f1_drop_without_refresh_vs_oracle')} "
              f"detect_s={fig12_fresh.get('detection_latency_s')} "
              f"refreshed={fig12_fresh.get('refreshed_cameras')}")
    else:
        print(f"fig12:    {args.fig12_fresh} absent -- fig12 gate skipped")
    if os.path.exists(args.gauntlet_fresh):
        with open(args.gauntlet_fresh) as fh:
            g_fresh = json.load(fh)
        with open(args.gauntlet_baseline) as fh:
            g_baseline = json.load(fh)
        failures += check_gauntlet(g_fresh, g_baseline)
        for name, m in sorted((g_fresh.get("phases") or {}).items()):
            cr = m.get("credits") or {}
            print(f"gauntlet: {name:12s} "
                  f"p99.9={m.get('p999_ms'):.1f}ms "
                  f"hit_rate={(m.get('cache') or {}).get('hit_rate'):.3f} "
                  f"leaked={cr.get('leaked')} dropped={cr.get('dropped')} "
                  f"degraded={m.get('tenant_degraded')} "
                  f"rejected={m.get('admission_rejected')}")
    else:
        print(f"gauntlet: {args.gauntlet_fresh} absent -- gauntlet gate "
              f"skipped")
    if failures:
        print(f"\nBENCHMARK REGRESSION GATE FAILED "
              f"({len(failures)} violation(s)):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("benchmark regression gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
