"""Benchmark driver: one function per paper table/figure + beyond-paper.

Prints ``name,us_per_call,derived`` CSV per experiment and writes JSON
artifacts to results/bench/.  The roofline/dry-run sweeps are separate
(launch/dryrun.py, benchmarks/roofline.py) since they need the 512-device
XLA flag set before jax import.

Usage: PYTHONPATH=src:. python -m benchmarks.run [--only substr]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only experiments whose name contains this")
    args = ap.parse_args()

    from benchmarks import approx, compute, paper

    experiments = [
        paper.table1_node_scaling,
        paper.table2_fps_distance,
        paper.fig5_latency_vs_size,
        paper.fig6_accuracy_vs_size,
        paper.fig11_controller_response,
        paper.fig12_e2e_latency_accuracy,
        paper.table3_controller_summary,
        paper.fig13_14_mez_vs_nats,
        paper.fig15_subscriber_scaling,
        paper.fig16_latency_breakdown,
        compute.fig17_compute_latency,
        compute.log_throughput,
        compute.knob_pipeline_cost,
        approx.approx_collectives,
        approx.compressed_training_quality,
    ]
    failures = 0
    for fn in experiments:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            fn()
        except Exception:  # noqa: BLE001 - report and continue
            failures += 1
            print(f"{fn.__name__},nan,FAILED", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
