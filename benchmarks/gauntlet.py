"""Broker gauntlet: seeded, sustained heavy-traffic phases over one fleet.

The edge-broker benchmarking literature's lesson is that latency claims only
hold up under systematic stress -- throughput, p99.9 tail latency, and
behavior under connection churn are where bugs hide.  This harness drives
scores of concurrent tenant sessions over a shared fleet through sustained
load phases composed from the scenario DSL, and reports per phase:

  * delivered-latency p50 / p95 / p99.9 (milliseconds, pooled over the
    main subscription's trace AND every tenant's delivered frames),
  * the edge's credit ledger (``EdgeBroker.credit_report``): granted /
    returned / in-flight / dropped / leaked fetch credits -- the crash-wave
    phase must end with everything returned,
  * shared-frame-cache hit rate (the 64-tenant churn phase gates on it:
    LRU eviction must keep the hot working set resident through
    subscribe/unsubscribe floods),
  * admission/degradation event tallies (TENANT_DEGRADED,
    ADMISSION_REJECTED, RPC_TIMEOUT, EVENTS_DROPPED, ...).

Phases (each an independent seeded ``ScenarioSpec`` -- one fresh fleet per
phase, so a phase's damage can't leak into the next):

  churn64     64 tenant sessions join in waves and half of them churn
              (leave / rejoin) while the fleet keeps serving.
  qos_storm   a renegotiation storm: the main subscription's QoS bounds
              flip every few hundred milliseconds while tenants hold SLOs.
  crash_wave  camera crash -> recover cycles sweep the fleet (plus an edge
              crash in --full mode); the credit ledger must conserve.
  oversub     the wire budget is capped below aggregate demand while
              tenants of every SLO class pile on: admission control must
              degrade lower classes and reject the infeasible join.
  federated   a two-broker herd serves the fleet through live camera
              migrations, a broker-overload shed, and a rolling edge
              upgrade; the credit ledger is summed herd-wide and the
              migration blackout must stay inside the p99.9 ceiling.

Tables are the shared deterministic synthetic controller tables (no
characterization sweep, no detector, no disk cache), and every random
draw -- channel jitter, synthetic frames -- is seeded, so the emitted
``BENCH_gauntlet.json`` is bit-reproducible for a fixed ``--seed``:
``benchmarks/check_regression.py --gauntlet-fresh`` gates it against the
committed ``benchmarks/baseline_gauntlet.json``.

Run:  python -m benchmarks.gauntlet [--full] [--seed 7] [--phases a,b]
"""

from __future__ import annotations

import argparse
import json
import os
from collections import Counter

import numpy as np

from benchmarks.common import Timer, emit, synthetic_controller_table
from repro.core.channel import calibrated_channel
from repro.core.characterization import fit_latency_regression
from repro.core.scenario import (BrokerOverload, CameraCrash, CameraMigrate,
                                 CameraRecover, CameraSpec, EdgeCrash,
                                 EdgeRecover, QosChange, RollingUpgrade,
                                 ScenarioSpec, TenantJoin, TenantLeave,
                                 run_scenario)

ROOT_OUT = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_gauntlet.json")

N_CAMS = 4
FPS = 5.0
LATENCY = 0.100
ACCURACY = 0.92
WORKLOAD = "jaad"
SLO_CYCLE = ("best_effort", "silver", "gold")


def _cameras() -> tuple:
    return tuple(CameraSpec(f"cam{i}", dynamics="medium", fps=FPS)
                 for i in range(N_CAMS))


def _tables() -> dict:
    return {"medium": synthetic_controller_table()}


def _fleet_demand_bps(seed: int, latency: float = LATENCY) -> float:
    """The fleet's aggregate nominal wire demand at ``latency`` bounds,
    mirroring ``EdgeBroker._lane_load`` (nominal operating size from the
    inverted latency regression, workload-scaled, times fps) -- used to
    size the oversubscription phase's wire budget deterministically."""
    tbl = synthetic_controller_table()
    ch = calibrated_channel(seed=seed, workload=WORKLOAD)
    sizes = np.linspace(tbl.sizes_sorted[0], tbl.sizes_sorted[-1], 16)
    reg = fit_latency_regression(sizes,
                                 ch.regression_points(sizes, n=N_CAMS))
    nominal = float(np.clip(reg.invert(latency), tbl.sizes_sorted[0],
                            tbl.sizes_sorted[-1]))
    return ch.scaled_bytes(nominal) * FPS * N_CAMS


# =============================================================================
# Phase builders: (name, full) -> ScenarioSpec
# =============================================================================


def phase_churn64(seed: int, *, full: bool = False,
                  tenants: int = 64) -> ScenarioSpec:
    """Subscribe/unsubscribe churn flood: ``tenants`` sessions join in
    waves; every odd tenant leaves mid-run and every fourth rejoins --
    sustained connection churn while the shared cache serves the stable
    majority."""
    frames = 80 if full else 40
    t_end = frames / FPS
    events = []
    for i in range(tenants):
        at = round(0.2 + (i % 16) * 0.04 + (i // 16) * 0.25 * t_end, 3)
        events.append(TenantJoin(at=at, tenant=f"t{i:03d}",
                                 slo=SLO_CYCLE[i % 3]))
        if i % 2 == 1:
            events.append(TenantLeave(at=round(at + 0.25 * t_end, 3),
                                      tenant=f"t{i:03d}"))
        if i % 4 == 1:
            events.append(TenantJoin(at=round(at + 0.5 * t_end, 3),
                                     tenant=f"t{i:03d}",
                                     slo=SLO_CYCLE[i % 3]))
    return ScenarioSpec(
        name="gauntlet-churn64", cameras=_cameras(), frames=frames,
        seed=seed, workload=WORKLOAD, latency=LATENCY, accuracy=ACCURACY,
        events=tuple(sorted(events, key=lambda e: e.at)))


def phase_qos_storm(seed: int, *, full: bool = False) -> ScenarioSpec:
    """QoS-renegotiation storm: the main subscription's bounds flip every
    0.4 s of stream time while 8 SLO-classed tenants hold subscriptions
    (every renegotiation re-divides the wire budget across them)."""
    frames = 80 if full else 40
    t_end = frames / FPS
    events = [TenantJoin(at=round(0.2 + 0.1 * i, 3), tenant=f"q{i}",
                         slo=SLO_CYCLE[i % 3]) for i in range(8)]
    lo, hi = 0.060, 0.160
    t, flip = 1.0, 0
    while t < t_end - 0.5:
        events.append(QosChange(at=round(t, 3),
                                latency=(lo if flip % 2 == 0 else hi),
                                accuracy=(0.90 if flip % 4 < 2 else 0.94)))
        t += 0.4
        flip += 1
    return ScenarioSpec(
        name="gauntlet-qos-storm", cameras=_cameras(), frames=frames,
        seed=seed + 1, workload=WORKLOAD, latency=LATENCY,
        accuracy=ACCURACY, events=tuple(sorted(events, key=lambda e: e.at)))


def phase_crash_wave(seed: int, *, full: bool = False) -> ScenarioSpec:
    """Camera crash -> recover cycles sweep the fleet round-robin while 8
    tenants stream (every crash strands the credits of in-flight fetches;
    every recover must hand them back).  ``--full`` adds an edge-broker
    crash/recover cycle on top."""
    frames = 120 if full else 60
    t_end = frames / FPS
    events = [TenantJoin(at=round(0.2 + 0.1 * i, 3), tenant=f"c{i}",
                         slo=SLO_CYCLE[i % 3]) for i in range(8)]
    t, wave = 1.0, 0
    while t + 1.0 < t_end - 1.0:
        cam = f"cam{wave % N_CAMS}"
        events.append(CameraCrash(at=round(t, 3), camera_id=cam))
        events.append(CameraRecover(at=round(t + 1.0, 3), camera_id=cam))
        t += 1.5
        wave += 1
    if full:
        events.append(EdgeCrash(at=round(t_end * 0.55, 3)))
        events.append(EdgeRecover(at=round(t_end * 0.60, 3)))
    return ScenarioSpec(
        name="gauntlet-crash-wave", cameras=_cameras(), frames=frames,
        seed=seed + 2, workload=WORKLOAD, latency=LATENCY,
        accuracy=ACCURACY, events=tuple(sorted(events, key=lambda e: e.at)))


def phase_oversub(seed: int, *, full: bool = False) -> ScenarioSpec:
    """Oversubscription soak: the wire budget is pinned to the untenanted
    main stream's demand plus ~1.2 gold-tenant demands while 12 tenants of
    every class pile on -- lower classes must degrade toward their floors
    -- and one reject-policy join demanding near-perfect accuracy (its
    floor alone busts the budget) must bounce."""
    frames = 120 if full else 60
    demand = _fleet_demand_bps(seed + 3)
    events = [TenantJoin(at=round(0.3 + 0.2 * i, 3), tenant=f"o{i:02d}",
                         slo=SLO_CYCLE[i % 3]) for i in range(12)]
    events.append(TenantJoin(at=2.9, tenant="greedy", slo="gold",
                             accuracy=0.999, admission="reject"))
    events.append(TenantLeave(at=round(frames / FPS * 0.7, 3),
                              tenant="o00"))
    return ScenarioSpec(
        name="gauntlet-oversub", cameras=_cameras(), frames=frames,
        seed=seed + 3, workload=WORKLOAD, latency=LATENCY,
        accuracy=ACCURACY, wire_budget=demand * 2.2,
        events=tuple(sorted(events, key=lambda e: e.at)))


def phase_federated(seed: int, *, full: bool = False) -> ScenarioSpec:
    """Federated herd under churn: two brokers split the fleet while 8
    SLO-classed tenants stream; live ``CameraMigrate``s move cameras
    between brokers mid-poll (the migration blackout must stay inside the
    p99.9 ceiling -- no frame loss, no duplicate), a ``BrokerOverload``
    halves one broker's backhaul so the overload policy sheds the newest
    best-effort lanes, and a ``RollingUpgrade`` restarts every broker in
    turn with zero subscriber-visible downtime.  The credit ledger is
    summed HERD-wide, so conservation here proves the migration drain /
    re-grant handshake leaks nothing."""
    frames = 120 if full else 60
    t_end = frames / FPS
    events: list = [TenantJoin(at=round(0.2 + 0.1 * i, 3), tenant=f"f{i}",
                               slo=SLO_CYCLE[i % 3]) for i in range(8)]
    # live migrations against the default round-robin placement
    # (cam0,cam2 -> broker 0; cam1,cam3 -> broker 1)
    events.append(CameraMigrate(at=round(t_end * 0.25, 3),
                                camera_id="cam0", to_broker=1))
    events.append(CameraMigrate(at=round(t_end * 0.35, 3),
                                camera_id="cam3", to_broker=0))
    # degraded backhaul on broker 0: the overload policy must fire
    # BROKER_OVERLOAD and shed newest best-effort lanes to broker 1
    events.append(BrokerOverload(at=round(t_end * 0.5, 3), broker=0,
                                 factor=0.5))
    # rolling edge upgrade: migrate-then-restart each broker in turn
    events.append(RollingUpgrade(at=round(t_end * 0.7, 3)))
    return ScenarioSpec(
        name="gauntlet-federated", cameras=_cameras(), frames=frames,
        seed=seed + 4, workload=WORKLOAD, latency=LATENCY,
        accuracy=ACCURACY, n_brokers=2,
        events=tuple(sorted(events, key=lambda e: e.at)))


PHASES = {
    "churn64": phase_churn64,
    "qos_storm": phase_qos_storm,
    "crash_wave": phase_crash_wave,
    "oversub": phase_oversub,
    "federated": phase_federated,
}


# =============================================================================
# Phase runner + metric extraction
# =============================================================================


def _pct(lats_ms: np.ndarray, q: float) -> float:
    return float(np.percentile(lats_ms, q)) if lats_ms.size else float("nan")


def run_phase(name: str, spec: ScenarioSpec) -> dict:
    with Timer() as t:
        res = run_scenario(spec, tables=_tables())
    lats = [r.latency_s for r in res.rows if r.latency_s is not None]
    dropped = sum(1 for r in res.rows if r.dropped)
    for s in (res.tenant_stats or {}).values():
        dropped += s["dropped"]
    for samples in (res.tenant_latencies or {}).values():
        lats.extend(samples)
    lats_ms = np.asarray(lats, np.float64) * 1e3
    ev = Counter(e["kind"] for e in res.events_log)
    tenants = res.tenant_stats or {}
    return {
        "phase": name,
        "scenario": spec.name,
        "seed": spec.seed,
        "sessions": 1 + sum(1 for e in spec.events
                            if isinstance(e, TenantJoin)),
        "tenants_admitted": sum(1 for s in tenants.values()
                                if s["admitted"]),
        "frames_delivered": int(len(lats)),
        "frames_dropped": int(dropped),
        "p50_ms": _pct(lats_ms, 50),
        "p95_ms": _pct(lats_ms, 95),
        "p999_ms": _pct(lats_ms, 99.9),
        "credits": res.credit_stats,
        "cache": res.cache_stats,
        "events": {k: int(v) for k, v in sorted(ev.items())},
        "tenant_degraded": int(ev.get("tenant_degraded", 0)),
        "admission_rejected": int(ev.get("admission_rejected", 0)),
        "camera_migrated": int(ev.get("camera_migrated", 0)),
        "broker_overload": int(ev.get("broker_overload", 0)),
        "rpc_timeouts": int(ev.get("rpc_timeout", 0)),
        "wall_s": round(t.seconds, 3),
    }


def run_gauntlet(*, seed: int = 7, full: bool = False,
                 phases: list[str] | None = None) -> dict:
    names = phases if phases else list(PHASES)
    out: dict = {"bench": "gauntlet", "mode": "full" if full else "quick",
                 "seed": seed, "phases": {}}
    for name in names:
        spec = PHASES[name](seed, full=full)
        m = run_phase(name, spec)
        out["phases"][name] = m
        print(f"  {name:12s} sessions={m['sessions']:3d} "
              f"delivered={m['frames_delivered']:5d} "
              f"p50={m['p50_ms']:.1f}ms p95={m['p95_ms']:.1f}ms "
              f"p99.9={m['p999_ms']:.1f}ms "
              f"cache={m['cache']['hit_rate']:.3f} "
              f"credits(leaked={m['credits']['leaked']} "
              f"in_flight={m['credits']['in_flight']} "
              f"dropped={m['credits']['dropped']}) "
              f"degraded={m['tenant_degraded']} "
              f"rejected={m['admission_rejected']} [{m['wall_s']:.1f}s]")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--full", action="store_true",
                    help="long soak phases (slow; CI runs these in the "
                         "race-guarded slow job)")
    ap.add_argument("--phases", type=str, default=None,
                    help=f"comma-separated subset of {sorted(PHASES)}")
    ap.add_argument("--out", type=str, default=ROOT_OUT)
    args = ap.parse_args()
    phases = args.phases.split(",") if args.phases else None
    if phases:
        unknown = [p for p in phases if p not in PHASES]
        if unknown:
            ap.error(f"unknown phases {unknown}; pick from {sorted(PHASES)}")
    payload = run_gauntlet(seed=args.seed, full=args.full, phases=phases)
    total_us = sum(m["wall_s"] for m in payload["phases"].values()) * 1e6
    emit("gauntlet", total_us, "phases={}".format(len(payload["phases"])),
         payload)
    with open(args.out, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
    print("wrote", os.path.normpath(args.out))


if __name__ == "__main__":
    main()
