"""Fleet-scaling benchmark: per-step dispatch cost of the vmapped fleet
controller step at 64 / 128 / 256 cameras -> ``BENCH_fleet.json``.

The claim under test: driving N per-camera PI controllers as ONE compiled
``fleet_controller_step`` makes per-step cost ~FLAT in camera count (the
Python/dispatch overhead is paid once, not N times), where the pre-fleet
path -- one jitted ``controller_step`` call per camera -- scales linearly.
Measured numbers:

  * ``us_per_step``            compiled fleet step, per camera count
  * ``scaling_256_over_64``    flatness: ratio of step cost at 4x the fleet
  * ``python_loop_us_per_step_64``   64 per-camera jitted dispatches
  * ``speedup_vs_python_loop_64``    fleet step vs that loop
  * ``decide_us_per_step_64``  the full broker-facing ``FleetController.
                               decide`` tick (sync + dispatch + readback +
                               host decision objects)
  * ``whole_poll_us``          a REAL ``EdgeBroker.poll_subscription`` --
                               frame fetch + merge + the fused fleet tick --
                               per poll, per camera count
  * ``sharded``                the same whole-poll measurement with the
                               fused tick partitioned over an 8-device mesh
                               (``--xla_force_host_platform_device_count``)
                               at 64 / 512 / 1024 / 4096 lanes, plus the
                               per-camera flatness ratio 4096-vs-64
  * ``multi_tenant``           per-tenant whole-poll cost with 1 / 8 / 64
                               tenant sessions sharing ONE 256-camera fleet
                               (round-robin polls), plus the shared
                               degraded-frame cache hit rate: N tenants at
                               one operating point must pay ~one transform
                               + deflate, so per-tenant cost at 64 tenants
                               stays within 1.5x the single-tenant figure
  * ``cache_size``             compiled variants across the whole sweep of
                               one fleet (must stay 1 per fleet instance)

CI gates these via ``benchmarks/check_regression.py`` against the
conservative thresholds committed in ``benchmarks/baseline_fleet.json``.

  PYTHONPATH=src python -m benchmarks.fleet_sweep [--repeats 5]
      [--skip-sharded]
"""

from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (RESULTS_DIR, emit, ensure_dir,
                               synthetic_controller_table)
from repro.core.characterization import LatencyRegression
from repro.core.controller import (ControllerConfig, ControllerParams,
                                   FleetController, JaxControllerTables,
                                   LatencyController, _controller_step_core,
                                   controller_init, fleet_controller_init,
                                   fleet_controller_step, stack_params,
                                   stack_tables)
ROOT_OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fleet.json")

CAPACITY = 512          # broker TABLE_CAPACITY: the deployed padding
FLEET_SIZES = (64, 128, 256)
STEPS = 200
POLL_SIZES = (64, 256)              # host (1-device) whole-poll sizes
SHARDED_SIZES = (64, 512, 1024, 4096)
SHARDED_DEVICES = 8
POLLS = 25              # timed polls per whole-poll repeat
MAX_FRAMES = 16         # poll_subscription budget (broker default)

synthetic_table = synthetic_controller_table


def build_fleet_arrays(n: int):
    """Stacked tables/params/state for n cameras with varied live rows."""
    reg = LatencyRegression(slope=1.2e-6, intercept=0.008)
    rows, params = [], []
    for i in range(n):
        tbl = synthetic_table(12 + i % 29, smin=2e3 + 37.0 * i,
                              smax=9e4 - 101.0 * i)
        rows.append(JaxControllerTables.from_table(tbl, capacity=CAPACITY))
        params.append(ControllerParams.from_scalars(
            latency_target=0.040 + 0.001 * (i % 17),
            accuracy_target=0.90 + 0.002 * (i % 4),
            slope=reg.slope, intercept=reg.intercept))
    tables = stack_tables(rows)
    return tables, stack_params(params), fleet_controller_init(tables)


BURST = 25          # steps per timed burst


def time_fleet_steps(sizes, *, steps: int, repeats: int) -> dict[int, float]:
    """Per-step wall time of the compiled fleet step for every fleet size.

    Noise-robust on shared runners: many SHORT bursts (min over bursts --
    a deschedule spike poisons one burst, not a whole measurement) with the
    fleet sizes INTERLEAVED, so a noisy period degrades every size equally
    instead of landing on whichever size happened to run then.
    """
    fleets = {}
    for n in sizes:
        tables, params, state = build_fleet_arrays(n)
        step = jax.jit(lambda st, lat, tb, pr: fleet_controller_step(
            st, lat, tb, pr))
        rng = np.random.default_rng(n)
        lat_series = [jnp.asarray(
            rng.uniform(0.005, 0.5, n).astype(np.float32))
            for _ in range(8)]
        state, _ = step(state, lat_series[0], tables, params)   # compile
        jax.block_until_ready(state.integral)
        fleets[n] = [step, state, tables, params, lat_series]
    bursts = max(1, (steps * repeats) // BURST)
    best = {n: float("inf") for n in sizes}
    for b in range(bursts):
        for n in sizes:
            step, s, tables, params, lat_series = fleets[n]
            t0 = time.perf_counter()
            for k in range(BURST):
                s, _ = step(s, lat_series[k % len(lat_series)], tables,
                            params)
            jax.block_until_ready(s.integral)
            best[n] = min(best[n], (time.perf_counter() - t0) / BURST)
            fleets[n][1] = s
    for n in sizes:
        assert fleets[n][0]._cache_size() == 1
    return {n: best[n] * 1e6 for n in sizes}


def time_python_loop(n: int, *, steps: int, repeats: int) -> float:
    """The pre-fleet path: one jitted controller_step dispatch per camera."""
    reg = LatencyRegression(slope=1.2e-6, intercept=0.008)
    cams = []
    step = jax.jit(lambda st, lat, tb, pr: _controller_step_core(
        st, lat, tb, pr))
    for i in range(n):
        tbl = synthetic_table(12 + i % 29, smin=2e3 + 37.0 * i,
                              smax=9e4 - 101.0 * i)
        jt = JaxControllerTables.from_table(tbl, capacity=CAPACITY)
        pr = ControllerParams.from_scalars(
            latency_target=0.040 + 0.001 * (i % 17),
            accuracy_target=0.90 + 0.002 * (i % 4),
            slope=reg.slope, intercept=reg.intercept)
        cams.append((controller_init(jt), jt, pr))
    rng = np.random.default_rng(n)
    lats = rng.uniform(0.005, 0.5, size=(8, n)).astype(np.float32)
    # compile once (shared shapes across cameras)
    st0, aux = step(cams[0][0], jnp.float32(0.1), cams[0][1], cams[0][2])
    jax.block_until_ready(st0.integral)
    best = float("inf")
    for _ in range(repeats):
        states = [c[0] for c in cams]
        t0 = time.perf_counter()
        for k in range(steps):
            row = lats[k % len(lats)]
            for i, (_, jt, pr) in enumerate(cams):
                states[i], aux = step(states[i], row[i], jt, pr)
        jax.block_until_ready(states[-1].integral)
        best = min(best, (time.perf_counter() - t0) / steps)
    return best * 1e6


def time_decide(n: int, *, steps: int, repeats: int) -> float:
    """End-to-end broker tick: FleetController.decide (sync + compiled
    dispatch + device readback + ControlDecision construction)."""
    reg = LatencyRegression(slope=1.2e-6, intercept=0.008)

    class _Cam:
        def __init__(self, i):
            self.camera_id = f"cam{i:03d}"
            tbl = synthetic_table(12 + i % 29, smin=2e3 + 37.0 * i,
                                  smax=9e4 - 101.0 * i)
            self.controller = LatencyController(
                ControllerConfig(0.040 + 0.001 * (i % 17),
                                 0.90 + 0.002 * (i % 4)), tbl, reg)
            self.table_version = 0
            self.qos_version = 0

    cams = [_Cam(i) for i in range(n)]
    fleet = FleetController(cams, capacity=CAPACITY)
    rng = np.random.default_rng(n)
    fbs = [{c.camera_id: float(x) for c, x in
            zip(cams, rng.uniform(0.005, 0.5, n))} for _ in range(4)]
    fleet.decide(fbs[0])                     # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for k in range(steps):
            fleet.decide(fbs[k % len(fbs)])
        best = min(best, (time.perf_counter() - t0) / steps)
    assert fleet.cache_size() == 1
    return best * 1e6


def time_whole_poll(n: int, *, polls: int, repeats: int,
                    mesh=None) -> float:
    """Wall time of a REAL ``EdgeBroker.poll_subscription`` over an
    n-camera fleet subscription: frame fetch across the simulated channel,
    timestamp merge, and the single fused controller/drift dispatch.

    Tiny 32x32 frames keep the synthetic payload cost from drowning the
    control plane; each camera publishes just enough frames that the
    subscription never drains mid-measurement (a poll budget of
    ``MAX_FRAMES`` visits only ~16 cameras per round-robin rotation).
    """
    from repro.core.api import QosBounds, SubscriptionOptions
    from repro.core.broker import MezSystem
    from repro.core.channel import calibrated_channel
    from repro.core.session import MezClient
    from repro.data.camera import CameraConfig, SyntheticCamera

    reg = LatencyRegression(slope=1.2e-6, intercept=0.008)
    system = MezSystem(calibrated_channel(seed=11))
    total_polls = 3 + polls * repeats            # warmup + timed
    frames_per_cam = math.ceil(total_polls * MAX_FRAMES / n) + 2
    src = SyntheticCamera(CameraConfig(camera_id="clip", height=32,
                                       width=32, seed=5))
    clip = [(ts, f) for ts, f, _ in src.stream(frames_per_cam)]
    ids = []
    for i in range(n):
        cid = f"cam{i:04d}"
        ids.append(cid)
        cam = system.add_camera(cid)
        cam.background = src.background
        tbl = synthetic_table(12 + i % 29, smin=2e3 + 37.0 * (i % 64),
                              smax=9e4 - 101.0 * (i % 64))
        cam.set_target(0.040 + 0.001 * (i % 17), 0.90 + 0.002 * (i % 4),
                       tbl, reg)
        for ts, f in clip:
            cam.publish(ts, f)
    sess = MezClient(system).open_session("bench")
    sub = sess.subscribe(ids, 0.0, 1e9, qos=QosBounds(0.050, 0.90),
                         options=SubscriptionOptions(fleet=True, mesh=mesh))
    for _ in range(3):                           # warmup (compiles the tick)
        sub.poll(max_frames=MAX_FRAMES)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(polls):
            sub.poll(max_frames=MAX_FRAMES)
        best = min(best, (time.perf_counter() - t0) / polls)
    fleet = system.edge.subscription_fleet(sub.subscription_id)
    assert fleet is not None and fleet.cache_size() == 1
    sess.close()
    return best * 1e6


TENANT_CAMS = 256
TENANT_COUNTS = (1, 8, 64)
TENANT_POLLS = 5            # timed round-robin rounds per repeat


def time_tenant_serving(n: int, tenants: int, *, polls: int,
                        repeats: int) -> tuple[float, float]:
    """Per-tenant whole-poll cost with ``tenants`` sessions sharing ONE
    n-camera fleet, plus the shared degraded-frame cache hit rate.

    Each tenant session subscribes every camera at the same operating
    point (the common multi-viewer shape) and the host control path is
    polled round-robin, so tenant cursors stay aligned: the first tenant
    of a round pays the knob transform + deflate, the rest must hit the
    ``EdgeBroker``-owned shared cache.  Returns ``(us_per_tenant_poll,
    cache_hit_rate)``.
    """
    from repro.core.api import QosBounds
    from repro.core.broker import MezSystem
    from repro.core.channel import calibrated_channel
    from repro.core.session import MezClient
    from repro.data.camera import CameraConfig, SyntheticCamera

    reg = LatencyRegression(slope=1.2e-6, intercept=0.008)
    system = MezSystem(calibrated_channel(seed=11))
    rounds = 1 + polls * repeats                 # warmup + timed
    frames_per_cam = math.ceil(rounds * MAX_FRAMES / n) + 2
    src = SyntheticCamera(CameraConfig(camera_id="clip", height=32,
                                       width=32, seed=5))
    clip = [(ts, f) for ts, f, _ in src.stream(frames_per_cam)]
    ids = []
    for i in range(n):
        cid = f"cam{i:04d}"
        ids.append(cid)
        cam = system.add_camera(cid)
        cam.background = src.background
        tbl = synthetic_table(12 + i % 29, smin=2e3 + 37.0 * (i % 64),
                              smax=9e4 - 101.0 * (i % 64))
        cam.set_target(0.040 + 0.001 * (i % 17), 0.90 + 0.002 * (i % 4),
                       tbl, reg)
        for ts, f in clip:
            cam.publish(ts, f)
    client = MezClient(system)
    sessions = []
    for t in range(tenants):
        sess = client.open_session(f"bench-t{t:02d}", tenant=f"t{t:02d}")
        sub = sess.subscribe(ids, 0.0, 1e9, qos=QosBounds(0.050, 0.90))
        sessions.append((sess, sub))
    for _, sub in sessions:                      # warmup round
        sub.poll(max_frames=MAX_FRAMES)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(polls):
            for _, sub in sessions:
                sub.poll(max_frames=MAX_FRAMES)
        best = min(best, (time.perf_counter() - t0) / (polls * tenants))
    hit_rate = system.edge.frame_cache.hit_rate()
    for sess, _ in sessions:
        sess.close()
    return best * 1e6, hit_rate


CHILD_MARKER = "WHOLE_POLL_RESULT "


def run_sharded_child(n: int, *, devices: int, polls: int,
                      repeats: int) -> float:
    """Measure ``time_whole_poll`` on a forced ``devices``-device host mesh
    in a SUBPROCESS: ``--xla_force_host_platform_device_count`` only takes
    effect before jax initializes, which this (parent) process already did."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fleet_sweep",
         "--whole-poll-child", str(n), "--mesh-devices", str(devices),
         "--polls", str(polls), "--repeats", str(repeats)],
        env=env, capture_output=True, text=True, check=True)
    for line in proc.stdout.splitlines():
        if line.startswith(CHILD_MARKER):
            return float(json.loads(line[len(CHILD_MARKER):])["whole_poll_us"])
    raise RuntimeError(f"sharded child (n={n}) produced no result marker:\n"
                       f"{proc.stdout}\n{proc.stderr}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=5,
                    help="best-of-N timing repeats (CI runners are noisy)")
    ap.add_argument("--steps", type=int, default=STEPS)
    ap.add_argument("--polls", type=int, default=POLLS,
                    help="timed poll_subscription calls per repeat")
    ap.add_argument("--skip-sharded", action="store_true",
                    help="skip the 8-device mesh subprocess sweep")
    ap.add_argument("--skip-tenants", action="store_true",
                    help="skip the 256-camera multi-tenant serving sweep")
    ap.add_argument("--whole-poll-child", type=int, default=None,
                    metavar="N", help="internal: measure one whole-poll "
                    "size on a forced mesh and print the result marker")
    ap.add_argument("--mesh-devices", type=int, default=None)
    args = ap.parse_args()

    if args.whole_poll_child is not None:
        us = time_whole_poll(args.whole_poll_child, polls=args.polls,
                             repeats=max(args.repeats - 2, 2),
                             mesh=args.mesh_devices)
        print(CHILD_MARKER + json.dumps(
            {"n": args.whole_poll_child, "devices": args.mesh_devices,
             "whole_poll_us": us}))
        return

    out: dict = {"fleet_sizes": list(FLEET_SIZES), "capacity": CAPACITY,
                 "steps": args.steps, "us_per_step": {},
                 "us_per_camera": {}}
    measured = time_fleet_steps(FLEET_SIZES, steps=args.steps,
                                repeats=args.repeats)
    for n in FLEET_SIZES:
        us = measured[n]
        out["us_per_step"][str(n)] = us
        out["us_per_camera"][str(n)] = us / n
        print(f"fleet n={n:4d}: {us:9.1f} us/step  ({us / n:6.2f} us/cam)")
    lo, hi = str(FLEET_SIZES[0]), str(FLEET_SIZES[-1])
    out["scaling_256_over_64"] = (out["us_per_step"][hi]
                                  / out["us_per_step"][lo])
    loop_us = time_python_loop(FLEET_SIZES[0], steps=max(args.steps // 4, 25),
                               repeats=max(args.repeats - 2, 2))
    out["python_loop_us_per_step_64"] = loop_us
    out["speedup_vs_python_loop_64"] = loop_us / out["us_per_step"][lo]
    out["decide_us_per_step_64"] = time_decide(
        FLEET_SIZES[0], steps=max(args.steps // 4, 25),
        repeats=max(args.repeats - 2, 2))

    out["whole_poll_us"] = {}
    out["whole_poll_us_per_cam"] = {}
    for n in POLL_SIZES:
        us = time_whole_poll(n, polls=args.polls,
                             repeats=max(args.repeats - 2, 2))
        out["whole_poll_us"][str(n)] = us
        out["whole_poll_us_per_cam"][str(n)] = us / n
        print(f"poll  n={n:4d}: {us:9.1f} us/poll  ({us / n:6.2f} us/cam)")
    if not args.skip_sharded:
        sh: dict = {"devices": SHARDED_DEVICES, "whole_poll_us": {},
                    "whole_poll_us_per_cam": {}}
        for n in SHARDED_SIZES:
            us = run_sharded_child(n, devices=SHARDED_DEVICES,
                                   polls=args.polls, repeats=args.repeats)
            sh["whole_poll_us"][str(n)] = us
            sh["whole_poll_us_per_cam"][str(n)] = us / n
            print(f"poll  n={n:4d} mesh={SHARDED_DEVICES}: {us:9.1f} "
                  f"us/poll  ({us / n:6.2f} us/cam)")
        lo_n, hi_n = str(SHARDED_SIZES[0]), str(SHARDED_SIZES[-1])
        sh["flatness_4096_over_64"] = (sh["whole_poll_us_per_cam"][hi_n]
                                       / sh["whole_poll_us_per_cam"][lo_n])
        out["sharded"] = sh
        print(f"per-camera whole-poll flatness {hi_n}/{lo_n} on "
              f"{SHARDED_DEVICES}-device mesh: "
              f"{sh['flatness_4096_over_64']:.3f} (<= 1.5 required)")
    if not args.skip_tenants:
        mt: dict = {"cameras": TENANT_CAMS, "tenant_counts":
                    list(TENANT_COUNTS), "poll_us_per_tenant": {},
                    "cache_hit_rate": {}}
        for t in TENANT_COUNTS:
            us, hit = time_tenant_serving(
                TENANT_CAMS, t, polls=TENANT_POLLS,
                repeats=max(args.repeats - 2, 2))
            mt["poll_us_per_tenant"][str(t)] = us
            mt["cache_hit_rate"][str(t)] = hit
            print(f"tenants={t:3d} over n={TENANT_CAMS}: {us:9.1f} us per "
                  f"tenant-poll  (shared-cache hit rate {hit:.3f})")
        lo_t, hi_t = str(TENANT_COUNTS[0]), str(TENANT_COUNTS[-1])
        mt["tenant_poll_ratio_64_over_1"] = (
            mt["poll_us_per_tenant"][hi_t] / mt["poll_us_per_tenant"][lo_t])
        out["multi_tenant"] = mt
        print(f"per-tenant poll ratio {hi_t}/{lo_t} tenants: "
              f"{mt['tenant_poll_ratio_64_over_1']:.3f} (<= 1.5 required)")
    out["cache_size"] = 1                   # asserted inside the timers

    ensure_dir()
    emit("BENCH_fleet", out["us_per_step"][lo],
         f"scaling={out['scaling_256_over_64']:.2f};"
         f"speedup={out['speedup_vs_python_loop_64']:.1f}x", out)
    with open(ROOT_OUT, "w") as fh:
        json.dump(out, fh, indent=1)
        fh.write("\n")
    print(f"python-loop n=64: {loop_us:9.1f} us/step -> "
          f"{out['speedup_vs_python_loop_64']:.1f}x speedup; "
          f"decide n=64: {out['decide_us_per_step_64']:.1f} us/step")
    print(f"artifacts: {ROOT_OUT} + {RESULTS_DIR}/BENCH_fleet.json")


if __name__ == "__main__":
    main()
