"""Fleet-scaling benchmark: per-step dispatch cost of the vmapped fleet
controller step at 64 / 128 / 256 cameras -> ``BENCH_fleet.json``.

The claim under test: driving N per-camera PI controllers as ONE compiled
``fleet_controller_step`` makes per-step cost ~FLAT in camera count (the
Python/dispatch overhead is paid once, not N times), where the pre-fleet
path -- one jitted ``controller_step`` call per camera -- scales linearly.
Measured numbers:

  * ``us_per_step``            compiled fleet step, per camera count
  * ``scaling_256_over_64``    flatness: ratio of step cost at 4x the fleet
  * ``python_loop_us_per_step_64``   64 per-camera jitted dispatches
  * ``speedup_vs_python_loop_64``    fleet step vs that loop
  * ``decide_us_per_step_64``  the full broker-facing ``FleetController.
                               decide`` tick (sync + dispatch + readback +
                               host decision objects)
  * ``cache_size``             compiled variants across the whole sweep of
                               one fleet (must stay 1 per fleet instance)

CI gates these via ``benchmarks/check_regression.py`` against the
conservative thresholds committed in ``benchmarks/baseline_fleet.json``.

  PYTHONPATH=src python -m benchmarks.fleet_sweep [--repeats 5]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (RESULTS_DIR, emit, ensure_dir,
                               synthetic_controller_table)
from repro.core.characterization import LatencyRegression
from repro.core.controller import (ControllerConfig, ControllerParams,
                                   FleetController, JaxControllerTables,
                                   LatencyController, _controller_step_core,
                                   controller_init, fleet_controller_init,
                                   fleet_controller_step, stack_params,
                                   stack_tables)
ROOT_OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fleet.json")

CAPACITY = 512          # broker TABLE_CAPACITY: the deployed padding
FLEET_SIZES = (64, 128, 256)
STEPS = 200

synthetic_table = synthetic_controller_table


def build_fleet_arrays(n: int):
    """Stacked tables/params/state for n cameras with varied live rows."""
    reg = LatencyRegression(slope=1.2e-6, intercept=0.008)
    rows, params = [], []
    for i in range(n):
        tbl = synthetic_table(12 + i % 29, smin=2e3 + 37.0 * i,
                              smax=9e4 - 101.0 * i)
        rows.append(JaxControllerTables.from_table(tbl, capacity=CAPACITY))
        params.append(ControllerParams.from_scalars(
            latency_target=0.040 + 0.001 * (i % 17),
            accuracy_target=0.90 + 0.002 * (i % 4),
            slope=reg.slope, intercept=reg.intercept))
    tables = stack_tables(rows)
    return tables, stack_params(params), fleet_controller_init(tables)


BURST = 25          # steps per timed burst


def time_fleet_steps(sizes, *, steps: int, repeats: int) -> dict[int, float]:
    """Per-step wall time of the compiled fleet step for every fleet size.

    Noise-robust on shared runners: many SHORT bursts (min over bursts --
    a deschedule spike poisons one burst, not a whole measurement) with the
    fleet sizes INTERLEAVED, so a noisy period degrades every size equally
    instead of landing on whichever size happened to run then.
    """
    fleets = {}
    for n in sizes:
        tables, params, state = build_fleet_arrays(n)
        step = jax.jit(lambda st, lat, tb, pr: fleet_controller_step(
            st, lat, tb, pr))
        rng = np.random.default_rng(n)
        lat_series = [jnp.asarray(
            rng.uniform(0.005, 0.5, n).astype(np.float32))
            for _ in range(8)]
        state, _ = step(state, lat_series[0], tables, params)   # compile
        jax.block_until_ready(state.integral)
        fleets[n] = [step, state, tables, params, lat_series]
    bursts = max(1, (steps * repeats) // BURST)
    best = {n: float("inf") for n in sizes}
    for b in range(bursts):
        for n in sizes:
            step, s, tables, params, lat_series = fleets[n]
            t0 = time.perf_counter()
            for k in range(BURST):
                s, _ = step(s, lat_series[k % len(lat_series)], tables,
                            params)
            jax.block_until_ready(s.integral)
            best[n] = min(best[n], (time.perf_counter() - t0) / BURST)
            fleets[n][1] = s
    for n in sizes:
        assert fleets[n][0]._cache_size() == 1
    return {n: best[n] * 1e6 for n in sizes}


def time_python_loop(n: int, *, steps: int, repeats: int) -> float:
    """The pre-fleet path: one jitted controller_step dispatch per camera."""
    reg = LatencyRegression(slope=1.2e-6, intercept=0.008)
    cams = []
    step = jax.jit(lambda st, lat, tb, pr: _controller_step_core(
        st, lat, tb, pr))
    for i in range(n):
        tbl = synthetic_table(12 + i % 29, smin=2e3 + 37.0 * i,
                              smax=9e4 - 101.0 * i)
        jt = JaxControllerTables.from_table(tbl, capacity=CAPACITY)
        pr = ControllerParams.from_scalars(
            latency_target=0.040 + 0.001 * (i % 17),
            accuracy_target=0.90 + 0.002 * (i % 4),
            slope=reg.slope, intercept=reg.intercept)
        cams.append((controller_init(jt), jt, pr))
    rng = np.random.default_rng(n)
    lats = rng.uniform(0.005, 0.5, size=(8, n)).astype(np.float32)
    # compile once (shared shapes across cameras)
    st0, aux = step(cams[0][0], jnp.float32(0.1), cams[0][1], cams[0][2])
    jax.block_until_ready(st0.integral)
    best = float("inf")
    for _ in range(repeats):
        states = [c[0] for c in cams]
        t0 = time.perf_counter()
        for k in range(steps):
            row = lats[k % len(lats)]
            for i, (_, jt, pr) in enumerate(cams):
                states[i], aux = step(states[i], row[i], jt, pr)
        jax.block_until_ready(states[-1].integral)
        best = min(best, (time.perf_counter() - t0) / steps)
    return best * 1e6


def time_decide(n: int, *, steps: int, repeats: int) -> float:
    """End-to-end broker tick: FleetController.decide (sync + compiled
    dispatch + device readback + ControlDecision construction)."""
    reg = LatencyRegression(slope=1.2e-6, intercept=0.008)

    class _Cam:
        def __init__(self, i):
            self.camera_id = f"cam{i:03d}"
            tbl = synthetic_table(12 + i % 29, smin=2e3 + 37.0 * i,
                                  smax=9e4 - 101.0 * i)
            self.controller = LatencyController(
                ControllerConfig(0.040 + 0.001 * (i % 17),
                                 0.90 + 0.002 * (i % 4)), tbl, reg)
            self.table_version = 0
            self.qos_version = 0

    cams = [_Cam(i) for i in range(n)]
    fleet = FleetController(cams, capacity=CAPACITY)
    rng = np.random.default_rng(n)
    fbs = [{c.camera_id: float(x) for c, x in
            zip(cams, rng.uniform(0.005, 0.5, n))} for _ in range(4)]
    fleet.decide(fbs[0])                     # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for k in range(steps):
            fleet.decide(fbs[k % len(fbs)])
        best = min(best, (time.perf_counter() - t0) / steps)
    assert fleet.cache_size() == 1
    return best * 1e6


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=5,
                    help="best-of-N timing repeats (CI runners are noisy)")
    ap.add_argument("--steps", type=int, default=STEPS)
    args = ap.parse_args()

    out: dict = {"fleet_sizes": list(FLEET_SIZES), "capacity": CAPACITY,
                 "steps": args.steps, "us_per_step": {},
                 "us_per_camera": {}}
    measured = time_fleet_steps(FLEET_SIZES, steps=args.steps,
                                repeats=args.repeats)
    for n in FLEET_SIZES:
        us = measured[n]
        out["us_per_step"][str(n)] = us
        out["us_per_camera"][str(n)] = us / n
        print(f"fleet n={n:4d}: {us:9.1f} us/step  ({us / n:6.2f} us/cam)")
    lo, hi = str(FLEET_SIZES[0]), str(FLEET_SIZES[-1])
    out["scaling_256_over_64"] = (out["us_per_step"][hi]
                                  / out["us_per_step"][lo])
    loop_us = time_python_loop(FLEET_SIZES[0], steps=max(args.steps // 4, 25),
                               repeats=max(args.repeats - 2, 2))
    out["python_loop_us_per_step_64"] = loop_us
    out["speedup_vs_python_loop_64"] = loop_us / out["us_per_step"][lo]
    out["decide_us_per_step_64"] = time_decide(
        FLEET_SIZES[0], steps=max(args.steps // 4, 25),
        repeats=max(args.repeats - 2, 2))
    out["cache_size"] = 1                   # asserted inside the timers

    ensure_dir()
    emit("BENCH_fleet", out["us_per_step"][lo],
         f"scaling={out['scaling_256_over_64']:.2f};"
         f"speedup={out['speedup_vs_python_loop_64']:.1f}x", out)
    with open(ROOT_OUT, "w") as fh:
        json.dump(out, fh, indent=1)
        fh.write("\n")
    print(f"python-loop n=64: {loop_us:9.1f} us/step -> "
          f"{out['speedup_vs_python_loop_64']:.1f}x speedup; "
          f"decide n=64: {out['decide_us_per_step_64']:.1f} us/step")
    print(f"artifacts: {ROOT_OUT} + {RESULTS_DIR}/BENCH_fleet.json")


if __name__ == "__main__":
    main()
