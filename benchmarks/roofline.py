import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis per (arch x shape) on the single-pod production mesh.

For each cell: lower + compile, run the loop-aware HLO analyzer, and derive
the three roofline terms on TPU v5e constants (197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI):

  compute term    dot_flops / peak_flops                     [s/step/device]
  memory term     hbm_traffic_bytes / hbm_bw                 [s/step/device]
  collective term wire_bytes / ici_bw                        [s/step/device]

where hbm_traffic = surface elementwise bytes (fusion-boundary outputs,
x2 for operand reads) + dot operand/output bytes, all trip-corrected; wire
bytes apply per-kind factors (all-reduce ~2x its payload for ring AR).

Also reported per cell:
  MODEL_FLOPS = 6*N*D (train) or 2*N_active*tokens (serve), per device
  usefulness  = MODEL_FLOPS / dot_flops   (remat/redundancy waste detector)
  bottleneck  = argmax of the three terms + a one-line lever
  memory fit  = dry-run bytes with the CPU bf16->f32 normalization artifact
                subtracted (TPU-adjusted estimate)

Usage: PYTHONPATH=src:. python -m benchmarks.roofline [--arch A] [--shape S]
       [--out results/roofline]
"""

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.hlo_analysis import analyze_hlo
from repro.configs import ARCHS, cells_for, get_config
from repro.configs.base import SHAPE_CELLS
from repro.launch.mesh import V5E, make_production_mesh
from repro.launch.steps import (build_prefill_step, build_serve_step,
                                build_train_step)

WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def model_flops_per_device(cfg, cell, n_dev: int) -> float:
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        mult = 8 if cfg.remat == "full" else 6   # fwd+bwd(+remat fwd)
        return mult * n_active * cell.tokens / n_dev
    if cell.kind == "prefill":
        return 2 * n_active * cell.tokens / n_dev
    # decode: one token per sequence
    return 2 * n_active * cell.global_batch / n_dev


def lever_for(bottleneck: str, cfg, cell) -> str:
    if bottleneck == "compute":
        if cfg.remat == "full":
            return ("selective remat (save attention outputs instead of "
                    "recomputing everything) cuts the recompute share of "
                    "the dot FLOPs")
        return "larger per-step batch or fused kernels raise MXU utilization"
    if bottleneck == "memory":
        if cell.kind == "decode":
            return ("quantize the KV cache (bf16->int8 halves the per-step "
                    "cache read) or batch more decode streams per read")
        return "fuse elementwise chains / bf16 intermediates to cut traffic"
    return ("overlap the gradient reduction with the backward pass, or "
            "compress the cross-pod payload (core/approx_comm int8: ~2x "
            "fewer wire bytes)")


def analyze_cell(arch: str, shape: str) -> dict:
    cfg = get_config(arch)
    cell = SHAPE_CELLS[shape]
    mesh = make_production_mesh()
    n_dev = 256
    builder = {"train": build_train_step, "prefill": build_prefill_step,
               "decode": build_serve_step}[cell.kind]
    t0 = time.time()
    bundle = builder(cfg, cell, mesh)
    with mesh:
        compiled = jax.jit(
            bundle.fn, in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
            donate_argnums=bundle.donate_argnums
        ).lower(*bundle.arg_structs).compile()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    a = analyze_hlo(hlo)

    compute_t = a.dot_flops / V5E.peak_flops_bf16
    # operand reads ~ output writes for elementwise; dots add their IO via
    # elem (outputs recorded) -- conservative x2 on surface traffic.
    # TPU-adjusted: the CPU backend's bf16->f32 normalization converts are
    # pure artifacts (TPU consumes bf16 natively) -- subtract their traffic.
    hbm_bytes = 2.0 * max(0.0, a.elem_bytes - a.f32_of_bf16_surface)
    memory_t = hbm_bytes / V5E.hbm_bandwidth
    wire = sum(WIRE_FACTOR[k] * v for k, v in a.collective_bytes.items())
    coll_t = wire / V5E.ici_bandwidth

    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    bottleneck = max(terms, key=terms.get)
    step_t = max(terms.values())
    mf = model_flops_per_device(cfg, cell, n_dev)
    temp = mem.temp_size_in_bytes
    args = mem.argument_size_in_bytes
    tpu_temp = max(0.0, temp - 0.5 * a.f32_of_bf16_resident)

    result = {
        "arch": arch, "shape": shape, "mesh": "single(16x16)",
        "terms_s": {k: float(v) for k, v in terms.items()},
        "bottleneck": bottleneck,
        "roofline_step_s": float(step_t),
        "mfu_at_bound": float(compute_t / step_t) if step_t else 0.0,
        "dot_flops_per_dev": float(a.dot_flops),
        "hbm_bytes_per_dev": float(hbm_bytes),
        "wire_bytes_per_dev": float(wire),
        "collectives_by_kind": {k: float(v)
                                for k, v in a.collective_bytes.items()},
        "model_flops_per_dev": float(mf),
        "usefulness": float(mf / a.dot_flops) if a.dot_flops else None,
        "memory": {"argument_bytes": args, "temp_bytes": temp,
                   "cpu_f32_artifact_bytes": float(a.f32_of_bf16_resident),
                   "tpu_adjusted_total": float(args + tpu_temp),
                   "fits_16GB": bool(args + tpu_temp < 16e9)},
        "lever": lever_for(bottleneck, cfg, cell),
        "analysis_s": round(time.time() - t0, 1),
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCHS)
    rows = []
    for arch in archs:
        for shape in cells_for(arch):
            if args.shape and shape != args.shape:
                continue
            path = os.path.join(args.out, f"{arch}__{shape}.json")
            if os.path.exists(path) and not args.force:
                with open(path) as fh:
                    rows.append(json.load(fh))
                print(f"CACHED {arch} x {shape}")
                continue
            print(f"ANALYZE {arch} x {shape} ...", flush=True)
            r = analyze_cell(arch, shape)
            with open(path, "w") as fh:
                json.dump(r, fh, indent=1)
            rows.append(r)
            t = r["terms_s"]
            print(f"  compute={t['compute']*1e3:.2f}ms "
                  f"memory={t['memory']*1e3:.2f}ms "
                  f"collective={t['collective']*1e3:.2f}ms "
                  f"-> {r['bottleneck']} "
                  f"useful={r['usefulness']:.2f} "
                  f"fit={r['memory']['fits_16GB']}", flush=True)
    # consolidated markdown table
    md = ["| arch | shape | compute ms | memory ms | collective ms | "
          "bottleneck | useful | TPU-adj mem GB | fits |",
          "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        t = r["terms_s"]
        md.append(
            f"| {r['arch']} | {r['shape']} | {t['compute']*1e3:.2f} | "
            f"{t['memory']*1e3:.2f} | {t['collective']*1e3:.2f} | "
            f"{r['bottleneck']} | {r['usefulness']:.2f} | "
            f"{r['memory']['tpu_adjusted_total']/1e9:.1f} | "
            f"{'Y' if r['memory']['fits_16GB'] else 'N'} |")
    with open(os.path.join(args.out, "TABLE.md"), "w") as fh:
        fh.write("\n".join(md) + "\n")
    print("\n".join(md))


if __name__ == "__main__":
    main()
