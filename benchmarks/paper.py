"""Paper-table reproductions: one function per table/figure (Sections 2 & 5).

Every function returns a dict (also written to results/bench/<name>.json) and
prints the scaffold CSV line ``name,us_per_call,derived``.

``python -m benchmarks.paper fig12`` runs only the fig12 closed-loop
reproduction and writes the CI-gated ``BENCH_fig12.json`` artifact (see
``benchmarks/check_regression.py`` / ``benchmarks/baseline_fig12.json``).
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import Timer, camera_factory, emit, get_table
from repro.configs.mez_edge import CONFIG as EDGE
from repro.compat import subscribe_v1
from repro.core.api import QosBounds, SubscribeSpec, SubscriptionOptions
from repro.core.broker import MezSystem, NatsLikeSystem
from repro.core.channel import calibrated_channel
from repro.core.characterization import fit_latency_regression
from repro.core.controller import ControllerConfig, LatencyController
from repro.core import detector as det
from repro.core import knobs as K
from repro.core.session import MezClient
from repro.data.camera import CameraConfig, SyntheticCamera

PAPER_TABLE1 = {  # size_kB: (ONE_Lat_ms, FIVE_Lat_ms)
    610: (32.09, 150.28), 760: (35.16, 164.56), 970: (46.09, 262.43),
    1390: (59.71, 382.47), 1670: (68.73, 606.98), 1740: (72.72, 617.16)}


# -----------------------------------------------------------------------------
# Table 1 / Fig. 4 -- peer-interference node scaling
# -----------------------------------------------------------------------------


def table1_node_scaling() -> dict:
    out = {"paper": PAPER_TABLE1, "predicted": {}, "per_dynamics": {}}
    ch = calibrated_channel()
    with Timer() as t:
        for size_kb, (one, five) in PAPER_TABLE1.items():
            p1 = ch.p95_latency(size_kb * 1e3, n=1) * 1e3
            p5 = ch.p95_latency(size_kb * 1e3, n=5) * 1e3
            out["predicted"][size_kb] = {
                "one_ms": p1, "five_ms": p5, "ratio": p5 / p1,
                "one_err": abs(p1 - one) / one,
                "five_err": abs(p5 - five) / five}
        # per-dynamics sampled latencies for the synthetic workload (Fig. 4)
        for dyn, workload in (("simple", "jaad"), ("medium", "jaad"),
                              ("complex", "jaad"), ("complex", "dukemtmc")):
            cam = camera_factory(dyn)()
            sizes = [K.wire_size(f) for _, f, _ in cam.stream(12)]
            med = float(np.median(sizes))
            chw = calibrated_channel(seed=1, workload=workload)
            series = {}
            for n in range(1, 6):
                lat = [chw.transfer(med, n=n) for _ in range(40)]
                series[n] = float(np.percentile(lat, 95) * 1e3)
            out["per_dynamics"][f"{dyn}-{workload}"] = {
                "median_wire": med, "p95_ms": series,
                "ratio_5_over_1": series[5] / series[1]}
    max_err = max(max(v["one_err"], v["five_err"])
                  for v in out["predicted"].values())
    emit("table1_node_scaling", t.us,
         f"max_rel_err={max_err:.3f};ratios=4.3x-8.5x", out)
    return out


def table2_fps_distance() -> dict:
    """Latency vs frame rate (5/15 fps) and distance (6/12 m), Duke complex."""
    paper = {1: [72.72, 80.60, 96.35], 2: [128.97, 409.82, 162.15],
             3: [341.18, 438.01, 390.75], 4: [518.31, 585.58, 526.95],
             5: [617.16, 631.76, 657.88]}
    ch = calibrated_channel()
    out = {"paper": paper, "predicted": {}}
    with Timer() as t:
        for n in range(1, 6):
            out["predicted"][n] = {
                "5fps_6m": ch.p95_latency(1740e3, n=n, fps=5) * 1e3,
                "15fps_6m": ch.p95_latency(1740e3, n=n, fps=15) * 1e3,
                "5fps_12m": ch.p95_latency(1740e3, n=n, fps=5,
                                           distance_m=12) * 1e3}
    p = out["predicted"][5]
    emit("table2_fps_distance", t.us,
         f"fps_effect={p['15fps_6m']/p['5fps_6m']:.3f};"
         f"dist_effect={p['5fps_12m']/p['5fps_6m']:.3f}", out)
    return out


# -----------------------------------------------------------------------------
# Fig. 5 -- latency vs frame size over knob combinations
# -----------------------------------------------------------------------------


def fig5_latency_vs_size() -> dict:
    cam = camera_factory("complex")()
    bg = cam.background
    frames = [f for _, f, _ in cam.stream(6)]
    ch = calibrated_channel(seed=2, workload="jaad")
    sizes, lats = [], []
    with Timer() as t:
        for setting in K.enumerate_settings()[::6]:       # ~75 combos
            wires = []
            for f in frames:
                r = K.apply_knobs(f, setting, background=bg)
                if r.frame is not None:
                    wires.append(r.wire_bytes)
            if not wires:
                continue
            med = float(np.median(wires))
            sizes.append(med)
            lats.append(float(np.median([ch.transfer(med, n=5)
                                         for _ in range(7)])))
        a, b = np.polyfit(sizes, lats, 1)
        pred = np.asarray(sizes) * a + b
        lats_arr = np.asarray(lats)
        r2 = 1 - np.sum((lats_arr - pred) ** 2) / np.sum(
            (lats_arr - lats_arr.mean()) ** 2)
    out = {"sizes": sizes, "lat_ms": (lats_arr * 1e3).tolist(),
           "slope_s_per_byte": a, "intercept_s": b, "r2": float(r2),
           "n_combos": len(sizes)}
    emit("fig5_latency_vs_size", t.us,
         f"r2={r2:.3f};combos={len(sizes)}", out)
    return out


# -----------------------------------------------------------------------------
# Fig. 6 -- normalized F1 vs frame-size buckets
# -----------------------------------------------------------------------------


def fig6_accuracy_vs_size() -> dict:
    out = {}
    with Timer() as t:
        for dyn in ("simple", "medium", "complex"):
            tbl = get_table(dyn)
            buckets: dict[str, list] = {}
            for size, acc in zip(tbl.size_by_setting, tbl.acc_by_setting):
                b = int(size // 10e3)
                buckets.setdefault(f"{10*b}-{10*(b+1)}kB", []).append(acc)
            out[dyn] = {
                "kept_combos": len(tbl.settings),
                "buckets": {k: {"mean_f1": float(np.mean(v)), "n": len(v)}
                            for k, v in sorted(buckets.items())},
                "min_size_at_95": float(
                    tbl.sizes_sorted[tbl.best_acc >= 0.95][0])
                if (tbl.best_acc >= 0.95).any() else None,
                "size_range": [float(tbl.sizes_sorted[0]),
                               float(tbl.sizes_sorted[-1])],
            }
    kept = ";".join(f"{d}:{out[d]['kept_combos']}" for d in out)
    emit("fig6_accuracy_vs_size", t.us, f"kept[{kept}]", out)
    return out


# -----------------------------------------------------------------------------
# Fig. 11 / Table 3 -- controller step response
# -----------------------------------------------------------------------------


def _closed_loop(dynamics: str, workload: str, *, frames=60, n_cams=5,
                 seed=3, controlled=True):
    tbl = get_table(dynamics)
    ch = calibrated_channel(seed=seed, workload=workload)
    sys = MezSystem(ch)
    for i in range(n_cams):
        cam = sys.add_camera(f"cam{i}")
        src = SyntheticCamera(CameraConfig(camera_id=f"cam{i}",
                                           dynamics=dynamics, seed=7))
        cam.background = src.background
        sizes = np.linspace(tbl.sizes_sorted[0], tbl.sizes_sorted[-1], 16)
        reg = fit_latency_regression(sizes,
                                     ch.regression_points(sizes, n=n_cams))
        cam.set_target(EDGE.latency_target, EDGE.accuracy_target, tbl, reg)
        for ts, f, gt in src.stream(frames):
            cam.publish(ts, f)
    # v2 session API: poll FrameBatches at the controller's sampling interval
    client = MezClient(sys)
    out = []
    with client.open_session("app0") as sess:
        sub = sess.subscribe(
            "cam0", 0.0, frames / EDGE.fps,
            qos=QosBounds(EDGE.latency_target, EDGE.accuracy_target),
            options=SubscriptionOptions(controlled=controlled,
                                        feedback_window=EDGE.feedback_window,
                                        credit_limit=EDGE.fetch_window))
        while (fb := sub.poll(max_frames=EDGE.fetch_window)):
            out.extend(fb.frames)
    delivered = [d for d in out if d.frame is not None]
    lat = np.asarray([d.latency.total for d in delivered])
    acc = [float(get_table(dynamics).acc_by_setting[d.knob_index])
           for d in delivered if d.knob_index >= 0]
    wire = [d.wire_bytes for d in delivered]
    return {"lat_series_ms": (lat * 1e3).tolist(),
            "p95_ms": float(np.percentile(lat, 95) * 1e3),
            "settled_p95_ms": float(np.percentile(lat[10:], 95) * 1e3),
            "median_ms": float(np.median(lat) * 1e3),
            "accuracy_min": min(acc) if acc else None,
            "accuracy_mean": float(np.mean(acc)) if acc else None,
            "wire_median": float(np.median(wire)),
            "infeasible": sys.cams["cam0"].infeasible_reported}


def fig11_controller_response() -> dict:
    out = {}
    with Timer() as t:
        for workload in ("jaad", "dukemtmc"):
            ctl = _closed_loop("complex", workload)
            unc = _closed_loop("complex", workload, controlled=False)
            # settling: first index from which a 5-frame window stays <110ms
            lat = np.asarray(ctl["lat_series_ms"])
            settle = next((i for i in range(len(lat) - 5)
                           if (lat[i:i + 5] < 120).all()), None)
            out[workload] = {
                "controlled": ctl, "uncontrolled": unc,
                "settle_frames": settle,
                "settle_seconds": settle / EDGE.fps if settle is not None
                else None,
                "latency_reduction":
                    unc["settled_p95_ms"] / ctl["settled_p95_ms"]}
    d = out["dukemtmc"]
    emit("fig11_controller_response", t.us,
         f"duke_settled_p95={d['controlled']['settled_p95_ms']:.0f}ms;"
         f"lat_red={d['latency_reduction']:.1f}x", out)
    return out


def table3_controller_summary() -> dict:
    out = {}
    with Timer() as t:
        for dyn in ("simple", "medium", "complex"):
            for workload in ("jaad", "dukemtmc"):
                ctl = _closed_loop(dyn, workload, frames=40)
                unc = _closed_loop(dyn, workload, frames=40,
                                   controlled=False)
                out[f"{dyn}-{workload}"] = {
                    "size_med_kB": ctl["wire_median"] / 1e3,
                    "f1_pct": (ctl["accuracy_mean"] or 0) * 100,
                    "lat_red": unc["settled_p95_ms"] / ctl["settled_p95_ms"],
                    "controlled_p95_ms": ctl["settled_p95_ms"],
                }
    worst_f1 = min(v["f1_pct"] for v in out.values())
    best_red = max(v["lat_red"] for v in out.values())
    emit("table3_controller_summary", t.us,
         f"worst_f1={worst_f1:.1f}%;max_lat_red={best_red:.1f}x", out)
    return out


# -----------------------------------------------------------------------------
# Fig. 12 -- end-to-end latency AND accuracy under a workload shift
# -----------------------------------------------------------------------------

FIG12_OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_fig12.json")


def fig12_e2e_latency_accuracy() -> dict:
    """Fig. 12 reproduction, scenario-driven: the closed loop holds BOTH its
    latency bound and its accuracy floor end to end -- including across a
    mid-stream workload shift, which is where a static characterization
    table silently fails.

    Three arms of the SAME deterministic ``SceneShift`` scenario (3
    cameras, 2 of them shift simple -> complex movers at t=4s, measured
    detection F1 scored per frame against the full-quality stream):

      * ``refresh``  -- drift-aware auto-recharacterization armed: the
        staleness monitor spots the regime change, re-sweeps exactly the
        shifted cameras' tables from their own live frames, and the
        controller re-binds its accuracy floor against current conditions.
      * ``control``  -- the same scenario with the drift loop off: the
        stale tables keep claiming accuracies the scene no longer
        delivers, and measured F1 degrades for the rest of the stream.
      * ``oracle``   -- the shifted cameras run tables characterized
        OFFLINE on the post-shift regime: the best a correctly calibrated
        static table can measure on complex scenes, i.e. the reference the
        refresh arm is judged against (complex movers cap measured F1
        below 1.0 for ANY table; comparing against the pre-shift window
        would conflate that scene effect with staleness).

    Writes the CI-gated ``BENCH_fig12.json`` (thresholds committed in
    ``benchmarks/baseline_fig12.json``: post-shift F1 within 5% of the
    oracle arm with refresh, a detection-latency bound, refreshes land on
    exactly the shifted cameras, and the control arm must actually degrade
    -- otherwise the scenario stopped exercising anything).
    """
    from repro.core.scenario import CameraSpec, SceneShift, ScenarioSpec, \
        run_scenario

    t_shift = 4.0
    frames = 80                       # 16 s of 5 fps stream
    shifted = ("cam0", "cam2")

    def spec(auto: bool) -> ScenarioSpec:
        return ScenarioSpec(
            name=f"fig12-{'refresh' if auto else 'control'}",
            cameras=tuple(CameraSpec(f"cam{i}", dynamics="simple")
                          for i in range(3)),
            frames=frames, seed=3, workload="jaad",
            latency=0.100, accuracy=0.95, min_accuracy=0.90,
            fleet=True, auto_recharacterize=auto, score_frames=True,
            events=tuple(SceneShift(at=t_shift, camera_id=cid,
                                    dynamics="complex")
                         for cid in shifted),
        )

    # per-camera calibration (camera-id keys win over dynamics keys): a
    # table swept on another camera's background is already mildly stale,
    # which would trip the monitor before the scripted shift
    tables = {cid: get_table("simple", clip_len=16, camera_id=cid)
              for cid in ("cam0", "cam1", "cam2")}
    oracle_tables = dict(tables)
    oracle_tables.update({cid: get_table("complex", clip_len=16,
                                         camera_id=cid) for cid in shifted})
    with Timer() as t:
        ref = run_scenario(spec(True), tables=tables)
        ctl = run_scenario(spec(False), tables=tables)
        orc = run_scenario(spec(False), tables=oracle_tables)

    pre = (1.0, t_shift)
    post = (t_shift + 1.0, frames / 5.0)
    refresh_events = [e for e in ref.events_log
                      if e["kind"] == "table_refresh"
                      and "re-swept" in e.get("detail", "")]
    detection_latency = (min(e["t"] for e in refresh_events) - t_shift
                        if refresh_events else None)
    oracle_post = orc.measured_f1(*post)
    windows = ((1.0, 4.0), (4.0, 6.0), (6.0, 10.0), (10.0, 16.0))
    out = {
        "t_shift": t_shift,
        "shifted_cameras": list(shifted),
        "f1_pre_refresh_arm": ref.measured_f1(*pre),
        "f1_post_refresh_arm": ref.measured_f1(*post),
        "f1_pre_control_arm": ctl.measured_f1(*pre),
        "f1_post_control_arm": ctl.measured_f1(*post),
        "f1_post_oracle_arm": oracle_post,
        "f1_drop_vs_oracle":
            1.0 - ref.measured_f1(*post) / max(oracle_post, 1e-9),
        "f1_drop_without_refresh_vs_oracle":
            1.0 - ctl.measured_f1(*post) / max(oracle_post, 1e-9),
        "f1_drop_with_refresh":
            1.0 - ref.measured_f1(*post) / max(ref.measured_f1(*pre), 1e-9),
        "f1_drop_without_refresh":
            1.0 - ctl.measured_f1(*post) / max(ctl.measured_f1(*pre), 1e-9),
        "p95_post_refresh_arm_ms": ref.p95_latency_ms(*post),
        "p95_post_control_arm_ms": ctl.p95_latency_ms(*post),
        "detection_latency_s": detection_latency,
        "refreshed_cameras": sorted({e["camera_id"]
                                     for e in refresh_events}),
        "drift_fires": ref.drift_fire_counts,
        "drift_cache_size": ref.drift_cache_size,
        "fleet_cache_size": ref.fleet_cache_size,
        "per_window_f1_refresh": {f"{a}-{b}": ref.measured_f1(a, b)
                                  for a, b in windows},
        "per_window_f1_control": {f"{a}-{b}": ctl.measured_f1(a, b)
                                  for a, b in windows},
        "per_window_f1_oracle": {f"{a}-{b}": orc.measured_f1(a, b)
                                 for a, b in windows},
    }
    with open(FIG12_OUT, "w") as fh:
        json.dump(out, fh, indent=1)
        fh.write("\n")
    emit("fig12_e2e_latency_accuracy", t.us,
         f"drop_vs_oracle={out['f1_drop_vs_oracle']:.3f};"
         f"drop_control={out['f1_drop_without_refresh_vs_oracle']:.3f};"
         f"detect_s={out['detection_latency_s']}", out)
    return out


# -----------------------------------------------------------------------------
# Fig. 13/14 -- Mez vs NATS node scaling
# -----------------------------------------------------------------------------


def fig13_14_mez_vs_nats() -> dict:
    out = {}
    with Timer() as t:
        for workload, fig in (("jaad", "fig13"), ("dukemtmc", "fig14")):
            res = {"mez": {}, "nats": {}, "mez_acc": {}}
            for n in range(1, 6):
                ctl = _closed_loop("complex", workload, frames=30, n_cams=n)
                res["mez"][n] = ctl["settled_p95_ms"]
                res["mez_acc"][n] = ctl["accuracy_mean"]
                # NATS: unmodified frames, 1 MB limit
                ch = calibrated_channel(seed=3, workload=workload)
                nats = NatsLikeSystem(ch)
                for i in range(n):
                    nats.add_camera(f"cam{i}")
                src = SyntheticCamera(CameraConfig(camera_id="cam0",
                                                   dynamics="complex", seed=7))
                lats, rejected = [], 0
                for ts, f, gt in src.stream(30):
                    try:
                        lats.append(nats.deliver("cam0", ts, f).latency.total)
                    except ValueError:
                        rejected += 1
                res["nats"][n] = (float(np.percentile(lats, 95) * 1e3)
                                  if lats else None)
                res.setdefault("nats_rejected", {})[n] = rejected
            out[fig] = res
    j = out["fig13"]
    emit("fig13_14_mez_vs_nats", t.us,
         f"mez_n5={j['mez'][5]:.0f}ms;nats_n5={j['nats'][5]:.0f}ms;"
         f"duke_nats_rejected={out['fig14']['nats_rejected'][5]}", out)
    return out


# -----------------------------------------------------------------------------
# Fig. 15 -- subscriber scaling
# -----------------------------------------------------------------------------


def fig15_subscriber_scaling() -> dict:
    out = {"mez": {}, "nats": {}}
    with Timer() as t:
        for n_subs in (1, 2, 4, 8):
            tbl = get_table("medium")
            ch = calibrated_channel(seed=4, workload="jaad")
            sys = MezSystem(ch)
            cam = sys.add_camera("cam0")
            src = SyntheticCamera(CameraConfig(camera_id="cam0",
                                               dynamics="medium", seed=7))
            cam.background = src.background
            sizes = np.linspace(tbl.sizes_sorted[0], tbl.sizes_sorted[-1], 12)
            reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=1))
            cam.set_target(0.1, 0.9, tbl, reg)
            for ts, f, gt in src.stream(16):
                cam.publish(ts, f)
            # one wireless transfer; subscribers fan out from the edge replica
            lats = []
            first = list(subscribe_v1(
                sys.edge, SubscribeSpec("app0", "cam0", 0, 100, 0.1, 0.9)))
            base = [d.latency.total for d in first if d.frame is not None]
            for s in range(n_subs):
                # replica reads add broker processing + subscribe API costs
                per_sub = [b + 0.0009 + 0.0006 + 0.0002 * s for b in base]
                lats.extend(per_sub)
            out["mez"][n_subs] = float(np.percentile(lats, 95) * 1e3)
            # NATS fan-out: no controller overhead, marginally lower
            nch = calibrated_channel(seed=4, workload="jaad")
            nats = NatsLikeSystem(nch)
            nats.add_camera("cam0")
            src = SyntheticCamera(CameraConfig(camera_id="cam0",
                                               dynamics="medium", seed=7))
            nlat = []
            deliveries = [nats.deliver("cam0", ts, f)
                          for ts, f, _ in src.stream(16)]
            for s in range(n_subs):
                nlat.extend(d.latency.total + 0.0002 * s for d in deliveries)
            out["nats"][n_subs] = float(np.percentile(nlat, 95) * 1e3)
    emit("fig15_subscriber_scaling", t.us,
         f"mez_1={out['mez'][1]:.0f}ms;mez_8={out['mez'][8]:.0f}ms;"
         f"nats_8={out['nats'][8]:.0f}ms", out)
    return out


# -----------------------------------------------------------------------------
# Fig. 16 -- end-to-end latency breakdown
# -----------------------------------------------------------------------------


def fig16_latency_breakdown() -> dict:
    with Timer() as t:
        ctl = None
        tbl = get_table("complex")
        ch = calibrated_channel(seed=5, workload="jaad")
        sys = MezSystem(ch)
        for i in range(5):
            cam = sys.add_camera(f"cam{i}")
            src = SyntheticCamera(CameraConfig(camera_id=f"cam{i}",
                                               dynamics="complex", seed=7))
            cam.background = src.background
            sizes = np.linspace(tbl.sizes_sorted[0], tbl.sizes_sorted[-1], 12)
            reg = fit_latency_regression(sizes, ch.regression_points(sizes, n=5))
            cam.set_target(0.1, 0.95, tbl, reg)
            for ts, f, gt in src.stream(30):
                cam.publish(ts, f)
        client = MezClient(sys)
        with client.open_session("app0") as sess:
            sub = sess.subscribe("cam0", 0, 100,
                                 qos=QosBounds(0.1, 0.95))
            out_frames = [d for d in sub.frames(max_frames=EDGE.fetch_window)
                          if d.frame is not None]
        comps = {"publish_api": 0.0, "controller": 0.0, "log_copy": 0.0,
                 "network": 0.0, "broker_processing": 0.0,
                 "subscribe_api": 0.0}
        for d in out_frames:
            for k in comps:
                comps[k] += getattr(d.latency, k)
        total = sum(comps.values())
        mez_pct = {k: 100 * v / total for k, v in comps.items()}
        # NATS: network + thin broker only
        nch = calibrated_channel(seed=5, workload="jaad")
        nats = NatsLikeSystem(nch)
        for i in range(5):
            nats.add_camera(f"cam{i}")
        src = SyntheticCamera(CameraConfig(camera_id="cam0",
                                           dynamics="complex", seed=7))
        nats_comps = {"network": 0.0, "other": 0.0}
        for ts, f, gt in src.stream(30):
            d = nats.deliver("cam0", ts, f)
            nats_comps["network"] += d.latency.network
            nats_comps["other"] += d.latency.total - d.latency.network
        ntotal = sum(nats_comps.values())
        nats_pct = {k: 100 * v / ntotal for k, v in nats_comps.items()}
    out = {"mez_pct": mez_pct, "nats_pct": nats_pct,
           "paper": {"mez_network": 65.7, "mez_controller": 20.5,
                     "nats_network": 96.2}}
    emit("fig16_latency_breakdown", t.us,
         f"mez_net={mez_pct['network']:.0f}%;"
         f"mez_ctl={mez_pct['controller'] + mez_pct['log_copy']:.0f}%;"
         f"nats_net={nats_pct['network']:.0f}%", out)
    return out


if __name__ == "__main__":
    import sys
    if "fig12" in sys.argv[1:]:
        fig12_e2e_latency_accuracy()
    else:
        print("usage: python -m benchmarks.paper fig12   (full sweep: "
              "python -m benchmarks.run)", file=sys.stderr)
        sys.exit(2)
