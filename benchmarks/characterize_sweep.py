"""Characterization sweep benchmark: batched grid engine vs the per-setting
reference path, with and without knob4 (artifact removal).

Measures wall clock for a full knob-grid characterization on the standard
calibration clip with both engines, plus the wire-size proxy's calibration
error and the batched/reference kept-set agreement, and records the perf
trajectory in ``BENCH_characterize.json`` at the repo root (also mirrored
into the results dir).  Run by CI on every push; the committed
``benchmarks/baseline_characterize.json`` plus ``check_regression.py`` turn
it into a merge gate (speedup must not drop >20%, proxy error must stay
under 5%, engines must keep agreeing).

  PYTHONPATH=src python -m benchmarks.characterize_sweep [--clip-len 24]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, camera_factory, emit, ensure_dir
from repro.core import grid_engine
from repro.core import knobs as K
from repro.core.characterization import characterize

ROOT_OUT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_characterize.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clip-len", type=int, default=24,
                    help="standard calibration clip length (frames)")
    ap.add_argument("--dynamics", default="complex")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--repeats", type=int, default=3,
                    help="measured runs per engine; best-of-N is reported "
                         "(shared CI runners are noisy)")
    args = ap.parse_args()

    camf = camera_factory(args.dynamics, args.seed)
    n_settings = len(K.enumerate_settings())

    def best_of(engine: str, n: int, *, artifact: bool = False
                ) -> tuple[float, object]:
        times, table = [], None
        for _ in range(n):
            t0 = time.monotonic()
            table = characterize(camf, clip_len=args.clip_len, engine=engine,
                                 include_artifact=artifact)
            times.append(time.monotonic() - t0)
        return min(times), table

    t0 = time.monotonic()
    table_cold = characterize(camf, clip_len=args.clip_len, engine="batched")
    cold = time.monotonic() - t0

    batched, table_b = best_of("batched", args.repeats)
    reference, table_r = best_of("reference", max(1, args.repeats - 1))

    # knob4 on device: the batched engine now covers include_artifact=True
    # (3x the settings grid); the seed path for the same grid is the
    # per-frame reference sweep
    batched_art, table_ba = best_of("batched", max(1, args.repeats - 1),
                                    artifact=True)
    reference_art, table_ra = best_of("reference", 1, artifact=True)

    # proxy calibration quality on the same clip
    cam = camf()
    bg = cam.background
    clip = [cam.next_frame()[1] for _ in range(args.clip_len)]
    grid = grid_engine.run_grid(bg, clip)

    def agreement(tb, tr):
        kept_b, kept_r = set(tb.settings), set(tr.settings)
        shared = kept_b & kept_r
        acc_b = dict(zip(tb.settings, tb.acc_by_setting))
        acc_r = dict(zip(tr.settings, tr.acc_by_setting))
        acc_max_diff = max((abs(acc_b[s] - acc_r[s]) for s in shared),
                           default=0.0)
        return kept_b, kept_r, shared, acc_max_diff

    kept_b, kept_r, shared, acc_max_diff = agreement(table_b, table_r)
    kept_ba, kept_ra, shared_a, acc_max_diff_a = agreement(table_ba, table_ra)
    n_settings_art = len(K.enumerate_settings(include_artifact=True))

    payload = {
        "clip_len": args.clip_len,
        "dynamics": args.dynamics,
        "n_settings": n_settings,
        "batched_seconds_cold": round(cold, 3),
        "batched_seconds": round(batched, 3),
        "reference_seconds": round(reference, 3),
        "speedup_vs_seed_path": round(reference / batched, 2),
        "settings_per_second_batched": round(n_settings / batched, 1),
        "settings_per_second_reference": round(n_settings / reference, 1),
        "proxy_median_rel_err": round(grid.proxy.median_rel_err, 4),
        "proxy_max_rel_err": round(grid.proxy.max_rel_err, 4),
        "zlib_calls_batched": grid.zlib_calls,
        "zlib_calls_reference": n_settings // len(K.DIFF_THRESHOLDS)
        * args.clip_len,
        "kept_settings_batched": len(kept_b),
        "kept_settings_reference": len(kept_r),
        "kept_overlap": len(shared),
        "acc_max_diff_on_shared": round(float(acc_max_diff), 4),
        "settings_cold_equals_warm": table_cold.settings == table_b.settings,
        # knob4-included sweep (the PR 3 device-side coverage)
        "n_settings_art": n_settings_art,
        "batched_seconds_art": round(batched_art, 3),
        "reference_seconds_art": round(reference_art, 3),
        "speedup_with_artifact": round(reference_art / batched_art, 2),
        "kept_settings_batched_art": len(kept_ba),
        "kept_settings_reference_art": len(kept_ra),
        "kept_overlap_art": len(shared_a),
        "acc_max_diff_on_shared_art": round(float(acc_max_diff_a), 4),
    }
    emit("BENCH_characterize", batched * 1e6,
         f"speedup={payload['speedup_vs_seed_path']}x "
         f"speedup_art={payload['speedup_with_artifact']}x "
         f"proxy_err={payload['proxy_median_rel_err']}", payload)
    with open(ROOT_OUT, "w") as fh:
        json.dump(payload, fh, indent=1)
    ensure_dir()
    print(f"batched {batched:.2f}s (cold {cold:.2f}s) vs reference "
          f"{reference:.2f}s -> {reference / batched:.1f}x; with knob4 "
          f"{batched_art:.2f}s vs {reference_art:.2f}s -> "
          f"{reference_art / batched_art:.1f}x; "
          f"artifacts: {ROOT_OUT} + {RESULTS_DIR}/BENCH_characterize.json")


if __name__ == "__main__":
    main()
