"""Loop-aware analysis of compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop BODY once, so a
scan-over-layers model under-reports FLOPs by ~L x and collective bytes by
the trip count.  This module parses the HLO text into its computation graph,
reads while-loop trip counts from ``backend_config known_trip_count`` (with a
condition-constant fallback), and propagates execution multipliers from
ENTRY -- yielding trip-corrected:

  * dot FLOPs (2 x prod(output dims) x prod(contracting dims)), the MXU term
  * collective bytes by kind, the ICI/DCN term
  * elementwise byte-traffic estimate (output sizes of non-dot ops), a
    lower-bound HBM-traffic term
  * bf16->f32 "float normalization" convert volume (CPU-backend artifact,
    subtracted in the TPU-adjusted memory estimate)

Shapes in post-SPMD HLO are shard-local, so every number is PER DEVICE.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["analyze_hlo", "HLOAnalysis"]

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
                "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# "<type> <op>(" where type is a tuple "(...)" (no nested parens in HLO
# types) or a single token.
_OP_RE = re.compile(r"^(\([^()]*\)|\S+)\s+([\w\-]+)\(")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*([^,()]+(?:\([^)]*\))?)")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body|condition)=%([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

# Ops that are views / metadata / buffer plumbing: no HBM traffic of their
# own.  (parameter & get-tuple-element of a while-carried tuple would
# otherwise count the ENTIRE model state once per loop iteration.)
_FREE_OPS = frozenset({
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "reshape", "optimization-barrier", "partition-id",
    "replica-id", "domain", "token",
})


def _shape_bytes_elems(type_str: str) -> tuple[int, int]:
    """Total (elements, bytes) across every array shape in a type string."""
    elems = bytes_ = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dtype]
    return elems, bytes_


def _dims_of(type_str: str) -> list[int] | None:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(x) for x in m.group(2).split(",") if x]


@dataclasses.dataclass
class _Comp:
    name: str
    dot_flops: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    elem_bytes: float = 0.0
    f32_of_bf16_bytes: float = 0.0
    whiles: list = dataclasses.field(default_factory=list)   # (body, cond, trip)
    calls: list = dataclasses.field(default_factory=list)


def _parse(hlo: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    current: _Comp | None = None
    symbols: dict[str, str] = {}     # per-computation: %name -> type string
    for raw in hlo.splitlines():
        line = raw.strip()
        hm = _HEADER_RE.match(line)
        if hm:
            current = _Comp(name=hm.group(1))
            comps[current.name] = current
            symbols = {}
            for pname, ptype in _PARAM_RE.findall(hm.group(2)):
                symbols[pname] = ptype
            continue
        if current is None:
            continue
        if " = " not in line:
            continue
        lhs, rhs = line.split(" = ", 1)
        om = _OP_RE.match(rhs)
        if not om:
            continue
        out_type, op = om.groups()
        result_name = lhs.lstrip("%").rstrip()
        symbols[result_name] = out_type
        args_str = rhs[om.end():]

        if op == "dot":
            dims_out = _dims_of(out_type)
            # lhs operand name -> its recorded type
            am = re.match(r"%([\w\.\-]+)", args_str)
            csize = 1
            if am and dims_out is not None:
                lhs_type = symbols.get(am.group(1), "")
                lhs_dims = _dims_of(lhs_type)
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                if lhs_dims and cm:
                    for ci in (int(x) for x in cm.group(1).split(",") if x):
                        if ci < len(lhs_dims):
                            csize *= lhs_dims[ci]
            if dims_out is not None:
                out_n = 1
                for d in dims_out:
                    out_n *= d
                current.dot_flops += 2.0 * out_n * csize
            continue
        if op == "while":
            attrs = dict(re.findall(r"(body|condition)=%([\w\.\-]+)", line))
            tm = _TRIP_RE.search(line)
            trip = int(tm.group(1)) if tm else None
            if "body" in attrs:
                current.whiles.append((attrs["body"],
                                       attrs.get("condition"), trip))
            continue
        matched_coll = False
        for kind in _COLLECTIVES:
            if op == kind or op == kind + "-start":
                _, b = _shape_bytes_elems(out_type)
                current.coll_bytes[kind] = current.coll_bytes.get(kind, 0) + b
                matched_coll = True
                break
            if op == kind + "-done":
                matched_coll = True
                break
        if matched_coll:
            continue
        # calls into sub-computations (fusion / call / reduce / conditional...)
        is_fusion = op in ("fusion", "reduce", "map", "scatter", "sort",
                           "reduce-window", "select-and-scatter")
        for callee in _CALL_ATTR.findall(line):
            current.calls.append((callee, is_fusion))
        bm = _BRANCHES.search(line)
        if bm:
            current.calls.extend(
                (b.strip().lstrip("%"), False) for b in bm.group(1).split(","))
        if op in _FREE_OPS:
            continue
        if op in ("dynamic-update-slice", "dynamic_update_slice"):
            # in-place update: traffic = the written slice, not the buffer
            names = re.findall(r"%([\w\.\-]+)", args_str)
            upd_type = symbols.get(names[1], "") if len(names) > 1 else ""
            _, b = _shape_bytes_elems(upd_type)
            current.elem_bytes += b
            continue
        _, b = _shape_bytes_elems(out_type)
        current.elem_bytes += b
        if op == "convert" and out_type.startswith("f32"):
            am = re.match(r"%([\w\.\-]+)", args_str)
            if am and symbols.get(am.group(1), "").startswith("bf16"):
                current.f32_of_bf16_bytes += b
        elif op == "fusion" and "convert" in line and "bf16" in line \
                and out_type.startswith("f32"):
            # wrapped_convert fusions
            if re.search(r"wrapped_convert", line):
                current.f32_of_bf16_bytes += b
    return comps


def _fallback_trip(cond_name: str | None, comps: dict[str, _Comp],
                   texts: dict[str, str]) -> int:
    if cond_name is None:
        return 1
    best = 1
    for m in re.finditer(r"constant\((\d+)\)", texts.get(cond_name, "")):
        best = max(best, int(m.group(1)))
    return best


def _comp_texts(hlo: str) -> dict[str, str]:
    texts: dict[str, str] = {}
    current, buf = None, []
    for raw in hlo.splitlines():
        line = raw.strip()
        hm = _HEADER_RE.match(line)
        if hm:
            if current:
                texts[current] = "\n".join(buf)
            current, buf = hm.group(1), []
        elif current:
            buf.append(line)
    if current:
        texts[current] = "\n".join(buf)
    return texts


@dataclasses.dataclass
class HLOAnalysis:
    dot_flops: float
    collective_bytes: dict
    elem_bytes: float              # surface traffic (fusion boundaries), trip-corrected
    f32_of_bf16_bytes: float       # trip-corrected convert TRAFFIC (CPU artifact)
    f32_of_bf16_surface: float     # surface-multiplier convert traffic
    f32_of_bf16_resident: float    # once-counted convert RESIDENCY estimate
    trip_counts: dict

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze_hlo(hlo: str) -> HLOAnalysis:
    comps = _parse(hlo)
    texts = _comp_texts(hlo)
    em = re.search(r"^ENTRY\s+%([\w\.\-]+)", hlo, re.M)
    entry = em.group(1) if em else next(iter(comps))

    mult: dict[str, float] = defaultdict(float)          # full reachability
    surf: dict[str, float] = defaultdict(float)          # stops at fusions
    trip_counts: dict[str, int] = {}

    def visit(name: str, m: float, s: float, depth: int = 0) -> None:
        comp = comps.get(name)
        if comp is None or m <= 0 or depth > 64:
            return
        mult[name] += m
        surf[name] += s
        for callee, is_fusion in comp.calls:
            # fusion internals execute (dots count) but their elementwise
            # intermediates never touch HBM (surface multiplier 0)
            visit(callee, m, 0.0 if is_fusion else s, depth + 1)
        for body, cond, trip in comp.whiles:
            if trip is None:
                trip = _fallback_trip(cond, comps, texts)
            trip_counts[body] = trip
            visit(body, m * trip, s * trip, depth + 1)
            if cond:
                visit(cond, m * (trip + 1), 0.0, depth + 1)

    visit(entry, 1.0, 1.0)

    dot = sum(c.dot_flops * mult[c.name] for c in comps.values())
    coll: dict[str, float] = defaultdict(float)
    for c in comps.values():
        for kind, b in c.coll_bytes.items():
            coll[kind] += b * mult[c.name]
    elem = sum(c.elem_bytes * surf[c.name] for c in comps.values())
    f32bf16 = sum(c.f32_of_bf16_bytes * mult[c.name] for c in comps.values())
    f32surf = sum(c.f32_of_bf16_bytes * surf[c.name] for c in comps.values())
    f32res = sum(c.f32_of_bf16_bytes * (1.0 if mult[c.name] > 0 else 0.0)
                 for c in comps.values())
    return HLOAnalysis(dot_flops=dot, collective_bytes=dict(coll),
                       elem_bytes=elem, f32_of_bf16_bytes=f32bf16,
                       f32_of_bf16_surface=f32surf,
                       f32_of_bf16_resident=f32res,
                       trip_counts=trip_counts)
