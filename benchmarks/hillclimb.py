import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Hillclimb harness: measure a cell's roofline terms under config variants.

Each experiment is hypothesis -> change (a dataclasses.replace on the arch
config) -> re-lower -> re-analyze; results append to
results/perf_iterations.jsonl, which EXPERIMENTS.md §Perf is built from.

Usage: PYTHONPATH=src:. python -m benchmarks.hillclimb <experiment>
       (see EXPERIMENTS for the registry)
"""

import dataclasses
import json
import sys
import time

import jax

from benchmarks.hlo_analysis import analyze_hlo
from repro.configs import get_config
from repro.configs.base import SHAPE_CELLS
from repro.launch.mesh import V5E, make_production_mesh
from repro.launch.steps import (build_prefill_step, build_serve_step,
                                build_train_step)

WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}
LOG = "results/perf_iterations.jsonl"


def measure(cfg, shape: str, *, multi_pod=False, grad_compress=None) -> dict:
    cell = SHAPE_CELLS[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    builder = {"train": build_train_step, "prefill": build_prefill_step,
               "decode": build_serve_step}[cell.kind]
    kw = {"grad_compress": grad_compress} if (
        cell.kind == "train" and grad_compress is not None) else {}
    bundle = builder(cfg, cell, mesh, **kw)
    t0 = time.time()
    with mesh:
        compiled = jax.jit(
            bundle.fn, in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
            donate_argnums=bundle.donate_argnums
        ).lower(*bundle.arg_structs).compile()
    a = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    hbm = 2.0 * max(0.0, a.elem_bytes - a.f32_of_bf16_surface)
    wire = sum(WIRE_FACTOR[k] * v for k, v in a.collective_bytes.items())
    terms = {"compute": a.dot_flops / V5E.peak_flops_bf16,
             "memory": hbm / V5E.hbm_bandwidth,
             "collective": wire / V5E.ici_bandwidth}
    return {
        "terms_ms": {k: round(v * 1e3, 2) for k, v in terms.items()},
        "bottleneck": max(terms, key=terms.get),
        "step_bound_ms": round(max(terms.values()) * 1e3, 2),
        "dot_flops": a.dot_flops,
        "wire_gb": round(wire / 1e9, 2),
        "collectives_by_kind_gb": {k: round(v / 1e9, 2)
                                   for k, v in a.collective_bytes.items()},
        "hbm_gb": round(hbm / 1e9, 1),
        "mem_args_temp_gb": round((mem.argument_size_in_bytes
                                   + mem.temp_size_in_bytes) / 1e9, 2),
        "compile_s": round(time.time() - t0, 1),
    }


def record(experiment: str, arch: str, shape: str, hypothesis: str,
           change: str, before: dict, after: dict, verdict: str) -> None:
    os.makedirs("results", exist_ok=True)
    with open(LOG, "a") as fh:
        fh.write(json.dumps({
            "experiment": experiment, "arch": arch, "shape": shape,
            "hypothesis": hypothesis, "change": change,
            "before": before, "after": after, "verdict": verdict,
        }) + "\n")
    print(f"[{experiment}] {verdict}")


# -----------------------------------------------------------------------------
# experiments
# -----------------------------------------------------------------------------


def phi3_prefill_sp() -> None:
    """phi3-medium prefill: heads (40/10) don't divide model=16 -> GSPMD
    computes attention with ~8x redundancy.  Hypothesis: sequence-parallel
    attention (q S-sharded, attention weights fsdp-only) removes the
    redundancy: compute term should drop ~8x toward llama3-8b-like levels
    (napkin: phi3 prefill useful-flops ~ 2*14e9*1M/256 = 109 TF/dev ->
    ~0.56 s compute)."""
    cfg = get_config("phi3-medium-14b")
    before = measure(cfg, "prefill_32k")
    after = measure(dataclasses.replace(cfg, sequence_parallel=True),
                    "prefill_32k")
    ratio = before["terms_ms"]["compute"] / max(after["terms_ms"]["compute"], 1e-9)
    record("phi3_prefill_sp", "phi3-medium-14b", "prefill_32k",
           "indivisible heads (40H/10KV vs model=16) cause ~8x redundant "
           "attention compute; SP shards the sequence instead",
           "sequence_parallel=True (q S-sharded, attn weights fsdp-only)",
           before, after,
           f"{'CONFIRMED' if ratio > 2 else 'REFUTED'}: compute "
           f"{before['terms_ms']['compute']} -> {after['terms_ms']['compute']}"
           f" ms ({ratio:.1f}x)")


def moonshot_train_tp() -> None:
    """moonshot train: most collective-bound cell (EP dispatch + TP ARs).
    Hypothesis: expert-TP (shard d_ff inside experts, experts replicated)
    eliminates the EP dispatch resharding; with F=1408 -> 88/shard the MXU
    tiles get thin but wire bytes should drop >2x."""
    cfg = get_config("moonshot-v1-16b-a3b")
    before = measure(cfg, "train_4k")
    after = measure(dataclasses.replace(cfg, moe_parallel="tp"), "train_4k")
    ratio = before["terms_ms"]["collective"] / max(
        after["terms_ms"]["collective"], 1e-9)
    record("moonshot_train_tp", "moonshot-v1-16b-a3b", "train_4k",
           "EP dispatch reshards the token buffer across the model axis "
           "every layer; expert-TP keeps tokens local",
           'moe_parallel="ep" -> "tp"', before, after,
           f"{'CONFIRMED' if ratio > 1.5 else 'REFUTED'}: collective "
           f"{before['terms_ms']['collective']} -> "
           f"{after['terms_ms']['collective']} ms ({ratio:.1f}x)")


def llama3_train_sp() -> None:
    """llama3 train: collective-bound on Megatron-TP activation all-reduces
    (2 AR x [B,S,D] per layer fwd + bwd).  Hypothesis: sequence-parallel
    activations turn each AR into RS+AG (half the wire bytes) and drop
    activation memory by 16x between blocks."""
    cfg = get_config("llama3-8b")
    before = measure(cfg, "train_4k")
    after = measure(dataclasses.replace(cfg, sequence_parallel=True),
                    "train_4k")
    ratio = before["terms_ms"]["collective"] / max(
        after["terms_ms"]["collective"], 1e-9)
    record("llama3_train_sp", "llama3-8b", "train_4k",
           "TP activation all-reduces dominate; SP lowers them to RS+AG "
           "(half wire) with S-sharded activations",
           "sequence_parallel=True", before, after,
           f"{'CONFIRMED' if ratio > 1.3 else 'REFUTED'}: collective "
           f"{before['terms_ms']['collective']} -> "
           f"{after['terms_ms']['collective']} ms ({ratio:.1f}x)")


def llama3_train_zero3() -> None:
    """Iteration 2 after SP was refuted (GSPMD added boundary all-gathers
    without demoting the row-parallel ARs to reduce-scatters).  Hypothesis:
    drop tensor parallelism entirely -- ZeRO-3 over all 256 devices.  The
    per-activation ARs (254 x 0.5 GB) disappear; collectives become
    per-layer weight all-gathers (~16 GB bf16 x 3 passes = 48 GB/dev) +
    gradient reduce-scatter (~16 GB): napkin ~70-100 GB wire vs 634 GB."""
    cfg = get_config("llama3-8b")
    before = measure(cfg, "train_4k")
    after = measure(dataclasses.replace(cfg, zero3=True), "train_4k")
    ratio = before["terms_ms"]["collective"] / max(
        after["terms_ms"]["collective"], 1e-9)
    record("llama3_train_zero3", "llama3-8b", "train_4k",
           "TP activation ARs dominate; ZeRO-3 (no TP, weights sharded over "
           "all 256 devices) replaces them with per-layer weight AGs",
           "zero3=True", before, after,
           f"{'CONFIRMED' if ratio > 1.5 else 'REFUTED'}: collective "
           f"{before['terms_ms']['collective']} -> "
           f"{after['terms_ms']['collective']} ms ({ratio:.1f}x)")


def moonshot_train_zero3() -> None:
    """MoE variant of the same hypothesis for the most collective-bound
    cell: EP dispatch + TP ARs vs ZeRO-3 weight AGs (16B params bf16 =
    32 GB/dev-gather x ~3 passes ~ 96 GB; baseline measured 1.6 TB)."""
    cfg = get_config("moonshot-v1-16b-a3b")
    before = measure(cfg, "train_4k")
    after = measure(dataclasses.replace(cfg, zero3=True), "train_4k")
    ratio = before["terms_ms"]["collective"] / max(
        after["terms_ms"]["collective"], 1e-9)
    record("moonshot_train_zero3", "moonshot-v1-16b-a3b", "train_4k",
           "EP dispatch resharding + TP ARs dominate; ZeRO-3 keeps tokens "
           "device-local and gathers expert weights instead",
           "zero3=True", before, after,
           f"{'CONFIRMED' if ratio > 1.5 else 'REFUTED'}: collective "
           f"{before['terms_ms']['collective']} -> "
           f"{after['terms_ms']['collective']} ms ({ratio:.1f}x)")


EXPERIMENTS = {
    "phi3_prefill_sp": phi3_prefill_sp,
    "moonshot_train_tp": moonshot_train_tp,
    "llama3_train_sp": llama3_train_sp,
    "llama3_train_zero3": llama3_train_zero3,
    "moonshot_train_zero3": moonshot_train_zero3,
}


if __name__ == "__main__":
    for name in (sys.argv[1:] or EXPERIMENTS):
        EXPERIMENTS[name]()
