"""Shared benchmark infrastructure: cached characterization, CSV emission."""

from __future__ import annotations

import json
import os
import pickle
import time

import numpy as np

from repro.core.characterization import CharacterizationTable, characterize
from repro.core.knobs import KnobSetting
from repro.data.camera import CameraConfig, SyntheticCamera

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/bench")
# v3: tables carry the drift monitor's scene-activity statistic + source
# provenance; v2 pickles (no such fields) would break dataclasses.replace
# on live tables, so they must not be mixed in.
CACHE = os.path.join(RESULTS_DIR, "_tables_v4.pkl")  # v4: residual_spread


def ensure_dir() -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)


def camera_factory(dynamics: str, seed: int = 7, camera_id: str = "cam0"):
    return lambda: SyntheticCamera(CameraConfig(
        camera_id=camera_id, dynamics=dynamics, seed=seed))


def synthetic_controller_table(n: int = 24, *, smin: float = 2e3,
                               smax: float = 9e4) -> CharacterizationTable:
    """Deterministic monotone size->accuracy table built without running
    the detector or zlib -- shared scaffolding for the fleet benchmark and
    the scenario/fleet test suites (one definition, not three copies)."""
    sizes = np.linspace(smin, smax, n)
    accs = 0.90 + 0.10 * (sizes - smin) / (smax - smin)
    settings = tuple(KnobSetting(resolution=i % 5) for i in range(n))
    return CharacterizationTable(
        settings=settings, sizes_sorted=sizes, best_acc=accs,
        best_idx=np.arange(n), acc_by_setting=accs, size_by_setting=sizes)


_TABLES: dict | None = None


def get_table(dynamics: str, *, clip_len: int = 32, seed: int = 7,
              camera_id: str = "cam0") -> CharacterizationTable:
    """Characterization tables are expensive (~20 s each); cache on disk.

    ``camera_id`` selects WHICH camera's stream the calibration clip comes
    from -- per-camera tables matter to the drift monitor, which treats a
    table swept on another camera's background as (mildly) stale."""
    global _TABLES
    ensure_dir()
    if _TABLES is None:
        if os.path.exists(CACHE):
            with open(CACHE, "rb") as fh:
                _TABLES = pickle.load(fh)
        else:
            _TABLES = {}
    key = (dynamics, clip_len, seed, camera_id)
    if key not in _TABLES:
        _TABLES[key] = characterize(
            camera_factory(dynamics, seed, camera_id), clip_len=clip_len)
        with open(CACHE, "wb") as fh:
            pickle.dump(_TABLES, fh)
    return _TABLES[key]


def emit(name: str, us_per_call: float, derived: str, payload: dict) -> None:
    """CSV line (scaffold contract) + JSON artifact."""
    print(f"{name},{us_per_call:.1f},{derived}")
    ensure_dir()
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as fh:
        json.dump(payload, fh, indent=1, default=_tolist)


def _tolist(o):
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, (np.floating, np.integer)):
        return o.item()
    return str(o)


class Timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        self.seconds = time.monotonic() - self.t0

    @property
    def us(self) -> float:
        return self.seconds * 1e6
