"""mezlint wall-time + finding counts -> ``BENCH_mezlint.json``.

The lint gates every PR, so its cost is part of the CI budget: this
benchmark times a full ``src/`` run (index build + all rules) and
records per-rule finding counts before suppression/baseline filtering,
plus the post-filter count the gate actually sees.  Artifacts land at
the repo root (CI upload) and in ``RESULTS_DIR`` via ``common.emit``.

Run: ``PYTHONPATH=src python -m benchmarks.mezlint_bench``
"""

from __future__ import annotations

import collections
import json
import os

from benchmarks.common import Timer, emit
from repro.analysis import baseline as baseline_mod
from repro.analysis.astindex import Index
from repro.analysis.mezlint import DEFAULT_BASELINE
from repro.analysis.rules import ALL_RULES, apply_suppressions

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ROOT_OUT = os.path.join(REPO, "BENCH_mezlint.json")
REPEATS = 3


def main() -> None:
    src = os.path.join(REPO, "src")
    runs = []
    for _ in range(REPEATS):
        with Timer() as t_index:
            idx = Index.build([src])
        with Timer() as t_rules:
            # pre-suppression findings, so the per-rule counts include
            # what justification comments are hiding
            raw = [f for fn in ALL_RULES.values() for f in fn(idx)]
        runs.append((t_index.seconds, t_rules.seconds))
    t_index_s = min(r[0] for r in runs)
    t_rules_s = min(r[1] for r in runs)

    unsuppressed = apply_suppressions(idx, raw)
    accepted = baseline_mod.load(os.path.join(REPO, DEFAULT_BASELINE))
    new, old = baseline_mod.split(unsuppressed, accepted)

    by_rule = collections.Counter(f.rule for f in raw)
    payload = {
        "index_s": round(t_index_s, 4),
        "rules_s": round(t_rules_s, 4),
        "total_s": round(t_index_s + t_rules_s, 4),
        "modules": len(idx.modules),
        "functions": len(idx.functions),
        "raw_findings_by_rule": dict(sorted(by_rule.items())),
        "suppressed": len(raw) - len(unsuppressed),
        "baseline_accepted": len(old),
        "new_findings": len(new),
    }
    emit("BENCH_mezlint", (t_index_s + t_rules_s) * 1e6,
         f"{len(new)} new findings", payload)
    with open(ROOT_OUT, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"mezlint bench: {payload['total_s'] * 1e3:.0f} ms over "
          f"{payload['modules']} modules; artifacts: {ROOT_OUT}")


if __name__ == "__main__":
    main()
