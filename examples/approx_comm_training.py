"""Beyond-paper demo: Mez's latency controller driving gradient compression.

Simulates the cross-pod link contention scenario (DESIGN.md Section 2) and
shows the control loop end to end: under 10x link contention the controller
drops the gradient transport to int8/int4 (wire bytes -4x) and recovers to
bf16 when the link clears -- the same Algorithm-1 machinery that adapts
video frames in the paper, pointed at a TPU fabric.

Also trains the reduced model with int8 transport to show the accuracy
floor holds (loss matches bf16 within tolerance).

Run:  PYTHONPATH=src:. python examples/approx_comm_training.py
"""

from benchmarks.approx import approx_collectives, compressed_training_quality


def main() -> None:
    print("== controller vs contended cross-pod link ==")
    out = approx_collectives()
    print(f"  SLO: {out['slo_s']*1e3:.1f} ms per reduction")
    print(f"  controlled p95:   {out['ctl_p95_s']*1e3:.1f} ms "
          f"({out['ctl_violations']} violations)")
    print(f"  uncontrolled p95: {out['unc_p95_s']*1e3:.1f} ms "
          f"({out['unc_violations']} violations)")
    print(f"  levels used: {out['levels_used']}  "
          f"min gradient fidelity: {out['min_fidelity']:.4f}")
    print(f"  latency improvement under contention: "
          f"{out['latency_improvement']:.1f}x")
    # the level decisions run on the jitted controller path (a one-lane
    # fleet_controller_step): one compiled variant across the whole run,
    # bit-identical to the host PI controller
    print(f"  jit decisions == host decisions: {out['jit_host_parity']}  "
          f"compiled variants: {out['controller_cache_size']}")

    print("\n== training quality with compressed transport ==")
    q = compressed_training_quality()
    print(f"  bf16 final loss: {q['bf16_final']:.4f}")
    print(f"  int8 final loss: {q['int8_final']:.4f} "
          f"(gap {q['gap']:.4f})")


if __name__ == "__main__":
    main()
