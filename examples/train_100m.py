"""End-to-end training driver: a ~100M-parameter qwen3-family model trained
for a few hundred steps on the synthetic token stream, with checkpointing,
failure injection + recovery, and (optionally) compressed gradient transport.

This is the (b) deliverable's end-to-end driver.  On this CPU container a
~100M model at batch 8 x seq 256 runs a step in a few seconds; pass --steps
200 for the full run or keep the default quick profile.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""

import argparse
import dataclasses

import jax

from repro.configs import get_config


def build_100m_config():
    """qwen3 wiring scaled to ~100M params (12L x 512d x 8H, 32k vocab)."""
    base = get_config("qwen3-1.7b")
    return dataclasses.replace(
        base, name="qwen3-100m", num_layers=12, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=32000,
        qk_norm=True, tie_embeddings=True, param_dtype="float32",
        compute_dtype="float32", remat="none", train_microbatches=1,
        attention_chunk=128)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)   # ~45 s/step on 1 CPU core;
                                                   # use --steps 200+ on real HW
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt_100m")
    ap.add_argument("--grad-bits", type=int, default=16)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    args = ap.parse_args()

    import repro.configs as C
    cfg = build_100m_config()
    C.ARCHS[cfg.name] = cfg    # register for the launcher
    n_params = cfg.param_count()
    print(f"model: {cfg.name}  ~{n_params/1e6:.0f}M params  "
          f"devices: {len(jax.devices())}")

    from repro.launch.train import train
    out = train(cfg.name, steps=args.steps, batch=args.batch, seq=args.seq,
                reduced=False, checkpoint_dir=args.ckpt, checkpoint_every=20,
                grad_bits=args.grad_bits,
                inject_failure_at=args.inject_failure_at, log_every=10)
    print(f"loss: {out['first_loss']:.3f} -> {out['final_loss']:.3f} over "
          f"{out['steps']} steps ({out['wall_s']:.0f}s, "
          f"{out['wall_s']/max(out['steps'],1):.2f}s/step)")
    assert out["final_loss"] < out["first_loss"], "loss must decrease"


if __name__ == "__main__":
    main()
