"""End-to-end IoT-Edge machine vision: cameras -> Mez -> detector -> F1.

The paper's headline experiment (Section 5.1) on the v2 session API: five
cameras stream complex scenes under interference into ONE multi-camera
``Subscription``; the subscriber drains timestamp-merged ``FrameBatch``
units, feeds the pedestrian detector through ``detect_batch``, and halfway
through renegotiates the latency bound with
``update_qos(recharacterize=True)`` -- live, without tearing the
subscription down, with each camera re-sweeping its knob tables over its
own recent frames (online re-characterization) before the tightened bound
binds.  We measure the application-level normalized F1 against ground
truth, demonstrating the latency/accuracy trade the controller actually
made.

Run:  PYTHONPATH=src python examples/multi_camera_pedestrian.py
"""

import numpy as np

from repro.configs.mez_edge import CONFIG as EDGE
from repro.core.api import QosBounds
from repro.core.broker import MezSystem
from repro.core.channel import calibrated_channel
from repro.core.characterization import characterize, fit_latency_regression
from repro.core import detector as det
from repro.core import knobs as K
from repro.core.session import MezClient
from repro.data.camera import CameraConfig, SyntheticCamera

N_FRAMES = 40
TIGHTENED_LATENCY = 0.060           # mid-run renegotiation target, seconds


def main() -> None:
    table = characterize(
        lambda: SyntheticCamera(CameraConfig(dynamics="complex",
                                             seed=EDGE.seed)),
        clip_len=16)
    channel = calibrated_channel(seed=3, workload="dukemtmc")
    system = MezSystem(channel)
    truth: dict[str, dict[float, np.ndarray]] = {}
    backgrounds: dict[str, np.ndarray] = {}
    cam_ids = [f"cam{i}" for i in range(EDGE.num_cameras)]
    for cid in cam_ids:
        cam = system.add_camera(cid)
        src = SyntheticCamera(CameraConfig(camera_id=cid,
                                           dynamics="complex", seed=EDGE.seed))
        backgrounds[cid] = src.background
        cam.background = src.background
        sizes = np.linspace(table.sizes_sorted[0], table.sizes_sorted[-1], 16)
        reg = fit_latency_regression(
            sizes, channel.regression_points(sizes, n=EDGE.num_cameras))
        cam.set_target(EDGE.latency_target, EDGE.accuracy_target, table, reg)
        truth[cid] = {}
        for ts, frame, gt in src.stream(N_FRAMES):
            cam.publish(ts, frame)
            truth[cid][round(ts, 6)] = gt

    h, w = backgrounds["cam0"].shape[:2]
    bg_memos = {cid: K.TransformMemo(bg) for cid, bg in backgrounds.items()}

    def bg_for(d):
        """Per-camera background, degraded the same way the knob degraded
        the delivered frame (the subscriber's model follows the stream).
        Memoized per knob setting -- the degradation is recomputed only
        when the controller actually moves the knobs, not per frame.
        Settings resolve against the camera's LIVE table: after the
        mid-run re-characterization the indices refer to the refreshed
        tables, not the startup calibration."""
        if d.knob_index >= 0:
            live = system.cams[d.camera_id].controller.table
            return bg_memos[d.camera_id].get(live.settings[d.knob_index])
        return backgrounds[d.camera_id]

    # one session, ONE subscription spanning all five cameras
    client = MezClient(system)
    results, lats_before, lats_after = [], [], []
    total = renegotiated = 0
    target_total = EDGE.num_cameras * N_FRAMES
    with client.open_session("app0") as session:
        sub = session.subscribe(cam_ids, 0.0, N_FRAMES / EDGE.fps,
                                qos=QosBounds(EDGE.latency_target,
                                              EDGE.accuracy_target))
        while (batch := sub.poll(max_frames=2 * EDGE.num_cameras)):
            if not total:
                # a jitted NN detector would consume this dense payload;
                # the classical detector below reads the frames directly
                payload, valid = batch.stack(batch_size=2 * EDGE.num_cameras)
                print(f"jit-ready payload {payload.shape} "
                      f"({int(valid.sum())} valid)")
            total += len(batch)
            for d, boxes in det.detect_batch(batch, bg_for, scale_to=(h, w)):
                gt = truth[d.camera_id].get(round(d.timestamp, 6))
                if gt is None:
                    continue
                results.append((gt, boxes))
                (lats_after if renegotiated else
                 lats_before).append(d.latency.total)
            for d in batch.dropped:                 # knob5: gt becomes FN
                gt = truth[d.camera_id].get(round(d.timestamp, 6))
                if gt is not None:
                    results.append((gt, np.zeros((0, 4), np.float32)))
            if not renegotiated and total >= target_total // 2:
                # live renegotiation: tighten the bound mid-stream -- the
                # per-camera controllers retarget in place, no resubscribe.
                # recharacterize=True first re-sweeps each camera's knob
                # tables over its own recent frames (batched grid engine,
                # seconds) and hot-swaps them into the live controller, so
                # the tightened bound binds against CURRENT conditions
                q = sub.update_qos(latency=TIGHTENED_LATENCY,
                                   recharacterize=True)
                renegotiated = total
                print(f"renegotiated at frame {total}: latency bound "
                      f"{EDGE.latency_target*1e3:.0f} -> "
                      f"{TIGHTENED_LATENCY*1e3:.0f} ms on "
                      f"{len(q.applied_cameras)} cameras ({q.status.value}), "
                      f"tables re-characterized online on "
                      f"{len(q.recharacterized)} cameras, "
                      f"subscription still {sub.state.value}")
        events = sub.events()

    # baseline F1: detector on the ORIGINAL frames of every camera
    base = []
    for cid in cam_ids:
        src = SyntheticCamera(CameraConfig(camera_id=cid, dynamics="complex",
                                           seed=EDGE.seed))
        for ts, frame, gt in src.stream(N_FRAMES):
            base.append((gt, det.detect(frame, backgrounds[cid],
                                        scale_to=(h, w))))

    f1 = det.normalized_f1(results, base)
    lb, la = np.asarray(lats_before), np.asarray(lats_after)
    print(f"delivered {total} frames from {EDGE.num_cameras} cameras "
          f"under DukeMTMC-scale interference (one subscription)")
    print(f"  p95 latency before renegotiation: {np.percentile(lb, 95)*1e3:.0f} ms "
          f"(bound {EDGE.latency_target*1e3:.0f} ms)")
    print(f"  p95 latency after  renegotiation: {np.percentile(la, 95)*1e3:.0f} ms "
          f"(bound {TIGHTENED_LATENCY*1e3:.0f} ms)")
    print(f"  infeasibility events surfaced: "
          f"{sum(e.kind.value == 'infeasible' for e in events)}")
    print(f"  application normalized F1: {f1*100:.1f}% "
          f"(bound {EDGE.accuracy_target*100:.0f}%)")
    print(f"  accuracy loss: {(1-f1)*100:.1f}% "
          f"(paper reports <= 4.2% worst case)")


if __name__ == "__main__":
    main()
