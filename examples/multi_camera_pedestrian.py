"""End-to-end IoT-Edge machine vision: cameras -> Mez -> detector -> F1.

The paper's headline experiment (Section 5.1) as a runnable script: five
cameras stream complex scenes under interference; the subscriber runs the
pedestrian detector on DELIVERED (quality-adapted) frames and we measure the
application-level normalized F1 against ground truth -- demonstrating the
latency/accuracy trade the controller actually made.

Run:  PYTHONPATH=src python examples/multi_camera_pedestrian.py
"""

import numpy as np

from repro.configs.mez_edge import CONFIG as EDGE
from repro.core.api import SubscribeSpec
from repro.core.broker import MezSystem
from repro.core.channel import calibrated_channel
from repro.core.characterization import characterize, fit_latency_regression
from repro.core import detector as det
from repro.core import knobs as K
from repro.data.camera import CameraConfig, SyntheticCamera


def main() -> None:
    table = characterize(
        lambda: SyntheticCamera(CameraConfig(dynamics="complex",
                                             seed=EDGE.seed)),
        clip_len=16)
    channel = calibrated_channel(seed=3, workload="dukemtmc")
    system = MezSystem(channel)
    truth: dict[float, np.ndarray] = {}
    sources = {}
    for i in range(EDGE.num_cameras):
        cam = system.add_camera(f"cam{i}")
        src = SyntheticCamera(CameraConfig(camera_id=f"cam{i}",
                                           dynamics="complex", seed=EDGE.seed))
        sources[f"cam{i}"] = src
        cam.background = src.background
        sizes = np.linspace(table.sizes_sorted[0], table.sizes_sorted[-1], 16)
        reg = fit_latency_regression(
            sizes, channel.regression_points(sizes, n=EDGE.num_cameras))
        cam.set_target(EDGE.latency_target, EDGE.accuracy_target, table, reg)
        for ts, frame, gt in src.stream(40):
            cam.publish(ts, frame)
            if i == 0:
                truth[round(ts, 6)] = gt

    # subscriber: detect pedestrians on delivered frames
    bg = sources["cam0"].background
    h, w = bg.shape[:2]
    results, baseline = [], []
    lats = []
    for d in system.edge.subscribe(SubscribeSpec(
            "app0", "cam0", 0.0, 8.0, EDGE.latency_target,
            EDGE.accuracy_target)):
        gt = truth.get(round(d.timestamp, 6))
        if gt is None:
            continue
        if d.frame is None:
            results.append((gt, np.zeros((0, 4), np.float32)))
            continue
        lats.append(d.latency.total)
        # the subscriber's background model follows the degraded stream
        if d.knob_index >= 0:
            bg_t = K.transform_frame(bg, table.settings[d.knob_index])
        else:
            bg_t = bg
        boxes = det.detect(np.asarray(d.frame), bg_t, scale_to=(h, w))
        results.append((gt, boxes))
        baseline.append((gt, det.detect(
            sources["cam0"].background * 0 + 0, bg, scale_to=(h, w))))

    # baseline F1: detector on the ORIGINAL frames
    src = SyntheticCamera(CameraConfig(camera_id="cam0", dynamics="complex",
                                       seed=EDGE.seed))
    base = []
    for ts, frame, gt in src.stream(40):
        base.append((gt, det.detect(frame, bg, scale_to=(h, w))))

    f1 = det.normalized_f1(results, base)
    lat = np.asarray(lats)
    print(f"delivered {len(lats)} frames under DukeMTMC-scale interference")
    print(f"  settled p95 latency: {np.percentile(lat[10:], 95)*1e3:.0f} ms "
          f"(bound {EDGE.latency_target*1e3:.0f} ms)")
    print(f"  application normalized F1: {f1*100:.1f}% "
          f"(bound {EDGE.accuracy_target*100:.0f}%)")
    print(f"  accuracy loss: {(1-f1)*100:.1f}% "
          f"(paper reports <= 4.2% worst case)")


if __name__ == "__main__":
    main()
