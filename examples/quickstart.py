"""Quickstart: the Mez loop in ~60 lines, on the v2 session API.

Five cameras publish to Mez under 4-peer interference; one subscriber opens
a session, asks for (100 ms, 95%) bounds, and drains timestamp-merged
``FrameBatch`` units; the latency controller holds the SLO by adapting frame
quality.  Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.configs.mez_edge import CONFIG as EDGE
from repro.core.api import QosBounds
from repro.core.broker import MezSystem
from repro.core.channel import calibrated_channel
from repro.core.characterization import characterize, fit_latency_regression
from repro.core.session import MezClient
from repro.data.camera import CameraConfig, SyntheticCamera


def main() -> None:
    # 1. offline characterization (paper Section 2): knob grid -> (size, F1)
    print("characterizing knob grid on a calibration clip ...")
    table = characterize(
        lambda: SyntheticCamera(CameraConfig(dynamics="complex",
                                             seed=EDGE.seed)),
        clip_len=16)
    print(f"  kept {len(table.settings)} knob settings, "
          f"sizes {table.sizes_sorted[0]/1e3:.1f}..".rstrip("."))

    # 2. deployment: 5 cameras on one contended 802.11ac channel
    channel = calibrated_channel(seed=3, workload="jaad")
    system = MezSystem(channel)
    sizes = np.linspace(table.sizes_sorted[0], table.sizes_sorted[-1], 16)
    regression = fit_latency_regression(
        sizes, channel.regression_points(sizes, n=EDGE.num_cameras))
    for i in range(EDGE.num_cameras):
        cam = system.add_camera(f"cam{i}")
        src = SyntheticCamera(CameraConfig(camera_id=f"cam{i}",
                                           dynamics="complex", seed=EDGE.seed))
        cam.background = src.background
        cam.set_target(EDGE.latency_target, EDGE.accuracy_target,
                       table, regression)
        for ts, frame, _ in src.stream(40):
            cam.publish(ts, frame)                       # Publish API

    # 3. open a session, subscribe with latency + accuracy bounds
    client = MezClient(system)
    print(f"cameras: {client.get_camera_info()}")        # GetCameraInfo API
    latencies, wires = [], []
    with client.open_session("app0") as session:
        sub = session.subscribe("cam0", 0.0, 8.0,
                                qos=QosBounds(EDGE.latency_target,
                                              EDGE.accuracy_target))
        while (batch := sub.poll(max_frames=EDGE.fetch_window)):
            for d in batch.delivered:                    # knob5 drops excluded
                latencies.append(d.latency.total)
                wires.append(d.wire_bytes)
        for ev in sub.events():                          # out-of-band failures
            print(f"  event: {ev.kind.value} on {ev.camera_id}")
        print(f"  subscription state: {sub.state.value}")
        sub.close()                                      # idempotent
    lat = np.asarray(latencies)
    print(f"delivered {len(lat)} frames")
    print(f"  p95 latency {np.percentile(lat, 95)*1e3:.0f} ms "
          f"(target {EDGE.latency_target*1e3:.0f} ms)")
    print(f"  settled p95 {np.percentile(lat[10:], 95)*1e3:.0f} ms")
    print(f"  median wire size {np.median(wires)/1e3:.0f} kB "
          f"(raw ~90 kB)")


if __name__ == "__main__":
    main()
