"""mezlint -- repo-specific static analysis for the Mez reproduction.

Usage::

    python -m repro.analysis.mezlint [paths ...]
        [--baseline mezlint.baseline.json] [--no-baseline]
        [--write-baseline] [--rules MZ01,MZ03] [--json]
        [--check-shrink OLD_BASELINE]

Exit status: 0 = no findings outside the baseline, 1 = new findings (or a
baseline growth with ``--check-shrink``), 2 = usage error.

Rules (details in ``repro.analysis.rules`` and README "Static analysis"):

  MZ01 host-sync calls / Python branches on traced values in jit-reachable
       code; MZ02 retrace smells (per-call jit wrappers, loop-varying
       static args, shape-unstable ``from_table``); MZ03 ``# guarded-by:``
       lock discipline; MZ04 f64 leaking into traced f32 lanes; MZ05
       Pallas kernel hygiene (closures, ``interpret=`` path, declared
       ``ref.py`` parity); MZ06 per-camera decision application inside
       poll-path loops; MZ07 deprecated per-kwarg (or ``**kwargs``)
       ``create_subscription`` call sites instead of one frozen
       ``options=SubscriptionOptions(...)``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.analysis import baseline as baseline_mod
from repro.analysis.astindex import Index
from repro.analysis.rules import Finding, run_rules

DEFAULT_BASELINE = "mezlint.baseline.json"


def run_paths(paths: list[str], rules: set[str] | None = None
              ) -> list[Finding]:
    """Lint ``paths`` (files or directories); returns unsuppressed findings.

    This is the programmatic entry point used by ``tests/test_mezlint.py``
    and ``benchmarks/mezlint_bench.py``.
    """
    idx = Index.build(paths)
    return run_rules(idx, rules=rules)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="mezlint", description=__doc__)
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="accepted-findings file (keys, shrink-only in CI)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept the current findings into --baseline")
    ap.add_argument("--rules", default="",
                    help="comma-separated rule subset (e.g. MZ01,MZ03)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--check-shrink", metavar="OLD",
                    help="compare --baseline against OLD: any added key "
                         "fails (no lint run happens)")
    args = ap.parse_args(argv)

    if args.check_shrink:
        grown = baseline_mod.check_shrink(args.check_shrink, args.baseline)
        if grown:
            print("mezlint: baseline grew (suppressions are shrink-only):")
            for k in grown:
                print(f"  + {k}")
            return 1
        print("mezlint: baseline ok (no new suppressions)")
        return 0

    rules = {r.strip() for r in args.rules.split(",") if r.strip()} or None
    t0 = time.monotonic()
    findings = run_paths(list(args.paths) or ["src"], rules=rules)
    elapsed = time.monotonic() - t0

    if args.write_baseline:
        baseline_mod.write(args.baseline, findings)
        print(f"mezlint: wrote {len(findings)} finding(s) to "
              f"{args.baseline}")
        return 0

    accepted: set[str] = set()
    if not args.no_baseline:
        accepted = baseline_mod.load(args.baseline)
    new, old = baseline_mod.split(findings, accepted)

    if args.as_json:
        print(json.dumps({
            "elapsed_s": round(elapsed, 3),
            "new": [vars(f) for f in new],
            "accepted": [f.key for f in old],
        }, indent=1))
    else:
        for f in new:
            print(f.render())
        print(f"mezlint: {len(new)} new finding(s), {len(old)} accepted by "
              f"baseline, {elapsed * 1e3:.0f} ms")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
