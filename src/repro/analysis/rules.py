"""mezlint rules MZ01-MZ08 (plus MZ00 for malformed suppressions).

=====  ========================================================================
MZ00   ``# mezlint: disable=`` without a ``-- justification``.
MZ01   Host-sync in traced code: ``.item()`` / ``.tolist()`` /
       ``.block_until_ready()`` / ``np.*`` / ``time.*`` / ``jax.device_get``
       calls, ``float()/int()/bool()`` of a traced parameter, or a Python
       branch (``if`` / ``while`` / ``assert`` / ternary / comprehension
       filter) whose test is not trace-time static, inside any function
       reachable from a ``jax.jit`` / ``pl.pallas_call`` entry point.
MZ02   Retrace smells: a ``jax.jit`` wrapper created inside a function body
       (every call builds a fresh cache -- module scope or a once-per-object
       ``__init__`` are the blessed spots); a jitted callsite in a loop whose
       static argument depends on the loop variable (one compile per
       iteration); ``JaxControllerTables.from_table`` without ``capacity=``
       (shape-unstable tables defeat the no-recompile ``swap_tables``
       contract).
MZ03   Lock discipline: a field annotated ``# guarded-by: <lock>`` may only
       be touched while ``<lock>`` is held -- lexically, via ``with`` blocks
       or ``acquire_*``/``release_*`` pairs; ``# holds-lock:`` on a ``def``
       shifts the obligation to its callers.  ``__init__`` is exempt (the
       object is not shared yet).
MZ04   dtype discipline: explicit float64 (``np.float64`` / ``jnp.float64``
       / ``dtype="float64"`` / ``.astype(float)``) inside traced code.  The
       f64 *pre*-compute in ``ControllerParams`` is blessed: gains are
       derived host-side in f64 and enter the trace as f32 leaves.
MZ05   Pallas kernel hygiene: kernels must be named module-level functions
       (optionally ``functools.partial``-bound with static kwargs), must not
       close over enclosing-scope values, every ``pallas_call`` must thread
       an ``interpret=`` flag, and each kernel module must declare its
       oracle twin with ``# mezlint: ref-parity: <symbol>``.
MZ06   Poll-path loop discipline: inside a function marked
       ``# mezlint: poll-path`` (the per-poll hot path), a Python loop or
       comprehension must not apply control decisions per camera --
       ``.setting_for(...)``, controller ``.update(...)``, or
       ``ControlDecision(...)`` construction inside the loop is O(N) host
       work per poll.  Fold the application into the fused fleet tick (one
       compiled dispatch) or materialize decisions lazily per fetched
       camera.
MZ07   Subscription config discipline: ``create_subscription(...)`` call
       sites must pass configuration as one frozen
       ``options=SubscriptionOptions(...)`` -- the per-kwarg spelling
       (``controlled=``, ``fleet=``, ``mesh=``, ...) is deprecated, and
       ``**kwargs`` forwarding hides which spelling is used.
MZ08   Broker construction discipline: direct ``EdgeBroker(...)``
       construction outside the broker/federation core bypasses the herd's
       routing table -- a camera registered on a hand-built broker can never
       be migrated, rebalanced, or carried through a rolling upgrade.  Build
       a ``MezSystem`` (single broker) or a ``BrokerHerd`` /
       ``FederatedMezSystem`` (federated) instead.
=====  ========================================================================
"""

from __future__ import annotations

import ast
import builtins
import dataclasses
import re

from repro.analysis.astindex import (GUARDED_BY_RE, FunctionInfo, Index,
                                     _params_of, body_of, inherited_static,
                                     iter_body_calls, scan_dynamic_tests)

_BUILTINS = frozenset(dir(builtins))

HOSTSYNC_ATTRS = {"item", "tolist", "block_until_ready",
                  "copy_to_host_async", "device_get"}
HOST_MODULES = {"numpy", "time"}
F64_ATTRS = {"float64", "double"}
MZ04_BLESSED = ("repro.core.controller.ControllerParams",)


@dataclasses.dataclass
class Finding:
    rule: str
    module: str         # dotted module name (stable across checkouts)
    path: str
    line: int
    scope: str          # enclosing function/class qualname suffix
    message: str
    detail: str         # short stable token used in the baseline key

    @property
    def key(self) -> str:
        return f"{self.rule}|{self.module}|{self.scope}|{self.detail}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} [{self.scope}] "
                f"{self.message}")


def _scope_of(fi: FunctionInfo | None) -> str:
    if fi is None:
        return "<module>"
    return fi.qualname[len(fi.module.name) + 1:]


def _root_name(node: ast.AST) -> str | None:
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    return node.id if isinstance(node, ast.Name) else None


def _mk(rule, fi_or_mod, line, scope, message, detail) -> Finding:
    mod = fi_or_mod.module if isinstance(fi_or_mod, FunctionInfo) else \
        fi_or_mod
    return Finding(rule=rule, module=mod.name, path=mod.path, line=line,
                   scope=scope, message=message, detail=detail)


# =============================================================================
# MZ00 -- malformed suppressions
# =============================================================================


def check_mz00(idx: Index) -> list[Finding]:
    out = []
    for mod in idx.modules.values():
        for line in mod.bare_disables:
            out.append(_mk("MZ00", mod, line, "<module>",
                           "suppression without a justification "
                           "(use `# mezlint: disable=MZxx -- why`)",
                           f"bare-disable@{line}"))
    return out


# =============================================================================
# MZ01 -- host sync inside traced code
# =============================================================================


def check_mz01(idx: Index) -> list[Finding]:
    out = []
    reach = idx.reachable()
    for qn in sorted(reach):
        fi = idx.functions.get(qn)
        if fi is None:
            continue
        root = reach[qn]
        scope = _scope_of(fi)
        host_aliases = {local for local, tgt in fi.module.aliases.items()
                        if tgt.split(".")[0] in HOST_MODULES}
        dyn_params = set(fi.params) - fi.static_params
        for call in iter_body_calls(fi):
            func = call.func
            if isinstance(func, ast.Attribute):
                if func.attr in HOSTSYNC_ATTRS:
                    out.append(_mk(
                        "MZ01", fi, call.lineno, scope,
                        f"`.{func.attr}()` forces a host sync in code "
                        f"reachable from jit entry `{root}`",
                        f"sync:{func.attr}"))
                    continue
                rn = _root_name(func.value)
                if rn in host_aliases:
                    out.append(_mk(
                        "MZ01", fi, call.lineno, scope,
                        f"host-library call `{rn}.{func.attr}(...)` in code "
                        f"reachable from jit entry `{root}` (use jnp/lax)",
                        f"host-call:{rn}.{func.attr}"))
            elif isinstance(func, ast.Name) and \
                    func.id in ("float", "int", "bool", "complex"):
                names = {n.id for a in call.args for n in ast.walk(a)
                         if isinstance(n, ast.Name)}
                if names & dyn_params:
                    out.append(_mk(
                        "MZ01", fi, call.lineno, scope,
                        f"`{func.id}()` of a traced value forces a host "
                        f"sync (reachable from `{root}`)",
                        f"cast:{func.id}@{call.lineno}"))
        for ev in scan_dynamic_tests(fi, inherited_static(idx, fi)):
            out.append(_mk(
                "MZ01", fi, getattr(ev.node, "lineno", fi.lineno), scope,
                f"Python `{ev.kind}` on a value that is not trace-time "
                f"static (reachable from `{root}`) -- use lax.cond/select "
                f"or mark the parameter static",
                f"branch:{ev.kind}@{getattr(ev.node, 'lineno', 0)}"))
    return out


# =============================================================================
# MZ02 -- retrace smells
# =============================================================================


def check_mz02(idx: Index) -> list[Finding]:
    out = []
    for site in idx.jit_wraps:
        if site.encl is None or site.self_assign_in_init:
            continue        # module scope / once-per-object are blessed
        scope = _scope_of(site.encl)
        out.append(_mk(
            "MZ02", site.module, site.node.lineno, scope,
            "`jax.jit(...)` created inside a function body: every call "
            "builds a fresh wrapper and retraces -- hoist to module scope "
            "or a long-lived object's `__init__`",
            f"jit-wrap@{scope}"))
    for call in idx.entry_calls:
        if not call.loop_names:
            continue
        scope = _scope_of(call.encl)
        argmap: list[tuple[str, ast.AST]] = []
        for i, a in enumerate(call.node.args):
            if i < len(call.target.params):
                argmap.append((call.target.params[i], a))
        for kw in call.node.keywords:
            if kw.arg:
                argmap.append((kw.arg, kw.value))
        for pname, expr in argmap:
            if pname not in call.target.static_params:
                continue
            names = {n.id for n in ast.walk(expr)
                     if isinstance(n, ast.Name)}
            hit = names & call.loop_names
            if hit:
                out.append(_mk(
                    "MZ02", call.module, call.node.lineno, scope,
                    f"static argument `{pname}` of jitted "
                    f"`{call.target.name}` varies with loop variable "
                    f"{sorted(hit)} -- one compile per iteration",
                    f"loop-static:{call.target.name}.{pname}"))
    for mod in idx.modules.values():
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "from_table":
                if not any(kw.arg == "capacity" for kw in node.keywords):
                    out.append(_mk(
                        "MZ02", mod, node.lineno, "<module>",
                        "`from_table(...)` without `capacity=`: table shape "
                        "follows the kept-set size, so every refresh "
                        "retraces -- pad to a fixed capacity (the "
                        "`swap_tables` no-recompile contract)",
                        f"from_table@{node.lineno}"))
    return out


# =============================================================================
# MZ03 -- lock discipline (guarded-by)
# =============================================================================


def _guard_map(idx: Index, fqcn: str) -> dict[str, str]:
    """field -> lock name, from `# guarded-by:` trailing comments."""
    guards: dict[str, str] = {}
    for m in idx.classes.get(fqcn, ()):
        fi = idx.functions.get(f"{fqcn}.{m}")
        if fi is None:
            continue
        for st in ast.walk(fi.node):
            targets = []
            if isinstance(st, ast.Assign):
                targets = st.targets
            elif isinstance(st, ast.AnnAssign):
                targets = [st.target]
            for t in targets:
                if isinstance(t, ast.Attribute) and \
                        isinstance(t.value, ast.Name) and t.value.id == "self":
                    g = GUARDED_BY_RE.search(fi.module.line(st.lineno))
                    if g:
                        guards[t.attr] = g.group(1)
    return guards


def _lock_base(expr: ast.AST) -> str | None:
    """`self._meta_lock` / `self._seg_locks[i]` -> the attribute name."""
    if isinstance(expr, ast.Subscript):
        expr = expr.value
    if isinstance(expr, ast.Attribute) and \
            isinstance(expr.value, ast.Name) and expr.value.id == "self":
        return expr.attr
    return None


class _LockWalker:
    def __init__(self, idx: Index, fi: FunctionInfo, guards: dict[str, str],
                 findings: list[Finding]):
        self.idx = idx
        self.fi = fi
        self.guards = guards
        self.findings = findings
        self.held: set[str] = set(fi.holds_locks)
        self.aliases: dict[str, str] = {}
        self.scope = _scope_of(fi)

    def run(self) -> None:
        self._stmts(body_of(self.fi.node))

    # -- helpers -------------------------------------------------------------
    def _lockname(self, expr: ast.AST) -> str | None:
        if isinstance(expr, ast.Name):
            return self.aliases.get(expr.id)
        return _lock_base(expr)

    def _check_exprs(self, roots) -> None:
        for root in roots:
            if root is None:
                continue
            for node in ast.walk(root):
                if isinstance(node, ast.Attribute) and \
                        isinstance(node.value, ast.Name) and \
                        node.value.id == "self" and node.attr in self.guards:
                    lock = self.guards[node.attr]
                    if lock not in self.held:
                        self.findings.append(_mk(
                            "MZ03", self.fi, node.lineno, self.scope,
                            f"`self.{node.attr}` is guarded by "
                            f"`{lock}` but accessed without it "
                            f"(held: {sorted(self.held) or 'none'})",
                            f"unlocked:{node.attr}@{self.scope}"))
                elif isinstance(node, ast.Call):
                    self._call(node)

    def _call(self, call: ast.Call) -> None:
        func = call.func
        if not isinstance(func, ast.Attribute):
            return
        # caller-side obligation for `# holds-lock:` methods
        if isinstance(func.value, ast.Name) and \
                func.value.id in ("self", "cls") and self.fi.cls:
            callee = self.idx.functions.get(
                f"{self.fi.module.name}.{self.fi.cls}.{func.attr}")
            if callee is not None:
                missing = set(callee.holds_locks) - self.held
                if missing:
                    self.findings.append(_mk(
                        "MZ03", self.fi, call.lineno, self.scope,
                        f"`self.{func.attr}()` requires holding "
                        f"{sorted(missing)} (declared `# holds-lock:`) "
                        f"but none of them are held here",
                        f"call-unlocked:{func.attr}@{self.scope}"))

    def _acquire_release(self, st: ast.stmt) -> bool:
        if not (isinstance(st, ast.Expr) and isinstance(st.value, ast.Call)):
            return False
        func = st.value.func
        if not isinstance(func, ast.Attribute):
            return False
        lock = self._lockname(func.value)
        if lock is None:
            return False
        if func.attr.startswith("acquire"):
            self.held.add(lock)
            return True
        if func.attr.startswith("release"):
            self.held.discard(lock)
            return True
        return False

    # -- statement walk ------------------------------------------------------
    def _stmts(self, stmts) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue
            if self._acquire_release(st):
                continue
            if isinstance(st, ast.With):
                added = []
                for item in st.items:
                    self._check_exprs([item.context_expr])
                    lock = self._lockname(item.context_expr)
                    if lock is not None and lock not in self.held:
                        self.held.add(lock)
                        added.append(lock)
                self._stmts(st.body)
                for lock in added:
                    self.held.discard(lock)
                continue
            if isinstance(st, ast.Assign) and len(st.targets) == 1 and \
                    isinstance(st.targets[0], ast.Name):
                lock = _lock_base(st.value)
                if lock is not None:
                    self.aliases[st.targets[0].id] = lock
            inner = [n for n in ast.iter_child_nodes(st)
                     if isinstance(n, ast.stmt)]
            other = [n for n in ast.iter_child_nodes(st)
                     if not isinstance(n, ast.stmt)]
            self._check_exprs(other)
            if inner:
                self._stmts(inner)


def check_mz03(idx: Index) -> list[Finding]:
    out: list[Finding] = []
    for fqcn in sorted(idx.classes):
        guards = _guard_map(idx, fqcn)
        if not guards:
            continue
        for m in sorted(idx.classes[fqcn]):
            if m == "__init__":
                continue        # not shared yet
            fi = idx.functions.get(f"{fqcn}.{m}")
            if fi is not None:
                _LockWalker(idx, fi, guards, out).run()
    return out


# =============================================================================
# MZ04 -- f64 leaking into traced f32 lanes
# =============================================================================


def check_mz04(idx: Index) -> list[Finding]:
    out = []
    reach = idx.reachable()
    for qn in sorted(reach):
        fi = idx.functions.get(qn)
        if fi is None or qn.startswith(MZ04_BLESSED):
            continue
        scope = _scope_of(fi)
        stack: list[ast.AST] = list(body_of(fi.node))
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda, ast.ClassDef)):
                continue
            if isinstance(node, ast.Attribute) and node.attr in F64_ATTRS:
                out.append(_mk(
                    "MZ04", fi, node.lineno, scope,
                    f"`{node.attr}` in traced code: f64 silently widens the "
                    "f32 lanes (precompute host-side in `ControllerParams` "
                    "and cast to f32 instead)",
                    f"f64:{node.attr}@{node.lineno}"))
            elif isinstance(node, ast.Constant) and node.value in F64_ATTRS:
                out.append(_mk(
                    "MZ04", fi, node.lineno, scope,
                    f"dtype string \"{node.value}\" in traced code",
                    f"f64-str@{node.lineno}"))
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "astype" and node.args and \
                    isinstance(node.args[0], ast.Name) and \
                    node.args[0].id == "float":
                out.append(_mk(
                    "MZ04", fi, node.lineno, scope,
                    "`.astype(float)` is float64 on the host path",
                    f"astype-float@{node.lineno}"))
            stack.extend(ast.iter_child_nodes(node))
    return out


# =============================================================================
# MZ05 -- Pallas kernel hygiene
# =============================================================================


def check_mz05(idx: Index) -> list[Finding]:
    out = []
    for site in idx.pallas_sites:
        scope = _scope_of(site.encl)
        line = site.node.lineno
        if "interpret" not in site.keywords:
            out.append(_mk(
                "MZ05", site.module, line, scope,
                "`pallas_call` without an `interpret=` flag: the kernel "
                "cannot run its CPU oracle path (ref.py parity)",
                f"no-interpret@{scope}"))
        if not site.kernels:
            out.append(_mk(
                "MZ05", site.module, line, scope,
                "kernel is not a resolvable named function (pass a "
                "module-level kernel, optionally functools.partial-bound "
                "with static kwargs)",
                f"anon-kernel@{scope}"))
        for kernel in site.kernels:
            for name, lineno in _free_vars(kernel):
                out.append(_mk(
                    "MZ05", site.module, lineno, scope,
                    f"kernel `{kernel.name}` closes over "
                    f"enclosing-scope name `{name}` -- pass it as a ref or "
                    "a functools.partial static kwarg",
                    f"closure:{kernel.name}.{name}"))
    # every kernel module must declare its ref.py oracle
    mods_with_kernels = {s.module.name: s.module for s in idx.pallas_sites}
    for name, mod in sorted(mods_with_kernels.items()):
        if not mod.ref_parity:
            out.append(_mk(
                "MZ05", mod, 1, "<module>",
                "module uses pallas_call but declares no "
                "`# mezlint: ref-parity: <symbol>` oracle twin",
                "no-ref-parity"))
            continue
        for sym in mod.ref_parity:
            target_mod, _, target_name = sym.rpartition(".")
            known = idx.modules.get(target_mod)
            if known is not None and target_name not in known.globals:
                out.append(_mk(
                    "MZ05", mod, 1, "<module>",
                    f"declared ref-parity symbol `{sym}` does not exist",
                    f"bad-ref-parity:{sym}"))
    return out


def _free_vars(fi: FunctionInfo) -> list[tuple[str, int]]:
    bound = set(fi.params) | fi.module.globals | _BUILTINS
    for node in ast.walk(fi.node):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            bound.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            bound.add(node.name)
            bound.update(_params_of(node))
        elif isinstance(node, ast.Lambda):
            bound.update(_params_of(node))
        elif isinstance(node, (ast.comprehension,)):
            bound.update(n.id for n in ast.walk(node.target)
                         if isinstance(n, ast.Name))
    out = []
    seen = set()
    for node in ast.walk(fi.node):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) and \
                node.id not in bound and node.id not in seen:
            seen.add(node.id)
            out.append((node.id, node.lineno))
    return sorted(out)


# =============================================================================
# MZ06 -- per-camera decision application on the poll path
# =============================================================================

POLL_PATH_RE = re.compile(r"#\s*mezlint:\s*poll-path\b")
MZ06_CALLS = ("setting_for", "update")


def _poll_marked(fi: FunctionInfo) -> bool:
    for ln in (fi.lineno, fi.lineno - 1):
        if ln >= 1 and POLL_PATH_RE.search(fi.module.line(ln)):
            return True
    return False


def check_mz06(idx: Index) -> list[Finding]:
    out = []
    loops = (ast.For, ast.While, ast.ListComp, ast.SetComp, ast.DictComp,
             ast.GeneratorExp)
    for qn in sorted(idx.functions):
        fi = idx.functions[qn]
        if not _poll_marked(fi):
            continue
        scope = _scope_of(fi)
        seen: set[int] = set()
        for loop in ast.walk(fi.node):
            if not isinstance(loop, loops):
                continue
            for node in ast.walk(loop):
                if not isinstance(node, ast.Call) or id(node) in seen:
                    continue
                func = node.func
                name = None
                if isinstance(func, ast.Attribute) and \
                        func.attr in MZ06_CALLS:
                    name = func.attr
                elif isinstance(func, ast.Name) and \
                        func.id == "ControlDecision":
                    name = "ControlDecision"
                if name is None:
                    continue
                seen.add(id(node))
                out.append(_mk(
                    "MZ06", fi, node.lineno, scope,
                    f"per-camera decision application `{name}(...)` inside "
                    "a Python loop on the poll path: O(N) host work per "
                    "poll -- fold it into the fused fleet tick or "
                    "materialize lazily per fetched camera",
                    f"poll-loop:{name}@{node.lineno}"))
    return out


# =============================================================================
# MZ07 -- deprecated per-kwarg create_subscription call sites
# =============================================================================

MZ07_LEGACY_KWARGS = frozenset({
    "controlled", "feedback_window", "credit_limit", "fleet", "mesh",
    "auto_recharacterize", "drift_config", "tenant", "slo",
})


def _walk_scoped(node: ast.AST, scope: str):
    """Yield ``(node, innermost function/class scope)`` over a subtree."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
            inner = child.name if scope == "<module>" else \
                f"{scope}.{child.name}"
            yield from _walk_scoped(child, inner)
        else:
            yield child, scope
            yield from _walk_scoped(child, scope)


def check_mz07(idx: Index) -> list[Finding]:
    out = []
    for name in sorted(idx.modules):
        mod = idx.modules[name]
        for node, scope in _walk_scoped(mod.tree, "<module>"):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            callee = func.attr if isinstance(func, ast.Attribute) else \
                func.id if isinstance(func, ast.Name) else None
            if callee != "create_subscription":
                continue
            legacy = sorted(kw.arg for kw in node.keywords
                            if kw.arg in MZ07_LEGACY_KWARGS)
            starred = any(kw.arg is None for kw in node.keywords)
            if legacy:
                out.append(_mk(
                    "MZ07", mod, node.lineno, scope,
                    "deprecated per-kwarg create_subscription call "
                    f"({', '.join(legacy)}): pass one frozen "
                    "options=SubscriptionOptions(...) instead",
                    f"legacy-kwargs:{','.join(legacy)}@{node.lineno}"))
            if starred:
                out.append(_mk(
                    "MZ07", mod, node.lineno, scope,
                    "create_subscription(**kwargs) hides whether the "
                    "deprecated per-kwarg config spelling is used: build "
                    "a SubscriptionOptions and pass options= explicitly",
                    f"star-kwargs@{node.lineno}"))
    return out


# =============================================================================
# MZ08 -- direct EdgeBroker construction outside the broker/federation core
# =============================================================================

# the broker module itself (MezSystem wires its single EdgeBroker) and the
# federation tier (BrokerHerd owns its N EdgeBrokers) are the only blessed
# construction sites
MZ08_ALLOWED_MODULES = frozenset({
    "repro.core.broker", "repro.core.federation",
})


def check_mz08(idx: Index) -> list[Finding]:
    out = []
    for name in sorted(idx.modules):
        if name in MZ08_ALLOWED_MODULES:
            continue
        mod = idx.modules[name]
        for node, scope in _walk_scoped(mod.tree, "<module>"):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            callee = func.attr if isinstance(func, ast.Attribute) else \
                func.id if isinstance(func, ast.Name) else None
            if callee != "EdgeBroker":
                continue
            out.append(_mk(
                "MZ08", mod, node.lineno, scope,
                "direct EdgeBroker(...) construction bypasses herd "
                "routing: cameras on a hand-built broker cannot be "
                "migrated, rebalanced, or rolled through an upgrade -- "
                "build MezSystem or BrokerHerd/FederatedMezSystem "
                "instead",
                f"edge-broker@{node.lineno}"))
    return out


ALL_RULES = {
    "MZ00": check_mz00,
    "MZ01": check_mz01,
    "MZ02": check_mz02,
    "MZ03": check_mz03,
    "MZ04": check_mz04,
    "MZ05": check_mz05,
    "MZ06": check_mz06,
    "MZ07": check_mz07,
    "MZ08": check_mz08,
}


def run_rules(idx: Index, rules=None) -> list[Finding]:
    findings: list[Finding] = []
    for code, fn in ALL_RULES.items():
        if rules and code not in rules:
            continue
        findings.extend(fn(idx))
    return apply_suppressions(idx, findings)


def apply_suppressions(idx: Index, findings: list[Finding]) -> list[Finding]:
    kept = []
    for f in findings:
        mod = idx.modules.get(f.module)
        suppressed = False
        if mod is not None:
            for ln in (f.line, f.line - 1):
                entry = mod.suppressions.get(ln)
                if entry and f.rule in entry[0]:
                    suppressed = True
                    break
        if not suppressed:
            kept.append(f)
    return sorted(kept, key=lambda f: (f.path, f.line, f.rule, f.detail))
