"""Runtime retrace guard -- the dynamic counterpart of mezlint MZ02.

``trace_guard`` snapshots jit cache sizes on entry and fails on exit if
any guarded target compiled more variants than expected.  It replaces the
ad-hoc ``cache_size() == 1`` assertions that used to be copy-pasted
through ``test_fleet.py`` / ``test_drift.py``:

    with trace_guard(fleet, monitor):
        for latencies in timeline:
            fleet.decide(fleet.sync(), latencies)
    # exiting asserts: each target compiled at most once inside the block
    # (a warm target may not recompile at all)

Targets are anything with a ``cache_size()`` method (``FleetController``,
``DriftMonitor``, ``CollectiveController``) or a jitted callable exposing
``_cache_size()``.  ``expect`` raises the per-target allowance when a
block legitimately compiles N variants (e.g. one per static config).

``assert_compiled_once`` is the post-hoc form for cache sizes *recorded*
by the scenario harness (``ScenarioResult.fleet_cache_size``), where the
live object is gone by the time the test can look.

No JAX import here: the guard only calls methods the targets provide, so
``repro.analysis`` stays importable in a bare lint job.
"""

from __future__ import annotations

import contextlib


class TraceGuardError(AssertionError):
    """A guarded target recompiled unexpectedly."""


def _size(target) -> int:
    for attr in ("cache_size", "_cache_size"):
        fn = getattr(target, attr, None)
        if callable(fn):
            return int(fn())
    raise TypeError(
        f"trace_guard target {target!r} exposes neither cache_size() nor "
        f"_cache_size()")


def _label(target, i: int) -> str:
    name = getattr(target, "__name__", None) or type(target).__name__
    return f"{name}#{i}"


@contextlib.contextmanager
def trace_guard(*targets, expect: int = 1):
    """Fail if any target's jit cache grows past ``max(initial, expect)``.

    A cold target is allowed its first ``expect`` compiles (the warm-up);
    a warm target is allowed none.  Raises ``TraceGuardError`` naming every
    offender with before/after sizes.
    """
    if not targets:
        raise TypeError("trace_guard needs at least one target")
    before = [_size(t) for t in targets]
    yield
    offenders = []
    for i, (t, b) in enumerate(zip(targets, before)):
        after = _size(t)
        allowed = max(b, expect)
        if after > allowed:
            offenders.append(
                f"{_label(t, i)}: cache {b} -> {after} (allowed {allowed})")
    if offenders:
        raise TraceGuardError(
            "unexpected recompile(s) inside trace_guard block:\n  "
            + "\n  ".join(offenders))


def assert_compiled_once(recorded, label: str = "recorded cache size") -> None:
    """Check a cache size *recorded* by a harness (an int, not a live
    object): exactly one compiled variant means the hot loop stayed on its
    fast path end to end."""
    if recorded != 1:
        raise TraceGuardError(
            f"{label}: expected exactly 1 compiled variant, got {recorded!r}"
            " -- something retraced (or never compiled) in the hot loop")
