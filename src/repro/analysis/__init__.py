"""Static + runtime discipline checks for the Mez reproduction.

``python -m repro.analysis.mezlint src/`` runs the AST lint (rules
MZ01-MZ05: trace discipline, retrace smells, lock discipline, dtype
contracts, Pallas kernel hygiene).  The runtime counterparts live here
too: ``trace_guard`` (fails a test on unexpected jit recompiles) and
``race_guard`` (lockset-instrumented locks for the threaded soak tests).

Import surface is kept lazy-friendly: importing ``repro.analysis`` pulls
no JAX, so the linter can run in a bare CI job.
"""

from repro.analysis.trace_guard import (TraceGuardError, assert_compiled_once,
                                        trace_guard)

__all__ = ["trace_guard", "assert_compiled_once", "TraceGuardError"]
