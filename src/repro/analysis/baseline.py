"""Committed-baseline handling for mezlint.

The baseline is a JSON file of finding *keys* (``rule|module|scope|detail``
-- deliberately line-number-free so ordinary edits don't churn it).  The
gate is: a run may produce no finding whose key is outside the baseline.
The baseline itself is shrink-only in CI: a PR may remove entries (by
fixing the underlying finding) but never add them -- new code must either
be clean or carry an inline ``# mezlint: disable=... -- why`` with a
justification the reviewer can see.
"""

from __future__ import annotations

import json

from repro.analysis.rules import Finding


def load(path: str) -> set[str]:
    try:
        with open(path) as fh:
            data = json.load(fh)
    except FileNotFoundError:
        return set()
    return set(data.get("findings", []))


def write(path: str, findings: list[Finding]) -> None:
    keys = sorted({f.key for f in findings})
    with open(path, "w") as fh:
        json.dump({"comment": "mezlint accepted findings -- shrink-only; "
                              "see README 'Static analysis'",
                   "findings": keys}, fh, indent=1)
        fh.write("\n")


def split(findings: list[Finding], baseline: set[str]
          ) -> tuple[list[Finding], list[Finding]]:
    """(new, accepted) relative to the baseline."""
    new = [f for f in findings if f.key not in baseline]
    old = [f for f in findings if f.key in baseline]
    return new, old


def check_shrink(old_path: str, new_path: str) -> list[str]:
    """Keys present in the new baseline but not the old one (violations)."""
    return sorted(load(new_path) - load(old_path))
