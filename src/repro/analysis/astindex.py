"""AST index over the repro source tree.

This is the substrate the ``mezlint`` rules (MZ01-MZ05) query: modules,
functions, jit / Pallas entry points, a name-resolution call graph, and a
trace-time *staticness* dataflow.  Resolution is by name, not by type --
deliberately heuristic, tuned to this repo's idioms:

  * decorator jit (``@jax.jit`` / ``@functools.partial(jax.jit, ...)``),
  * per-instance jit wrappers (``self._step = jax.jit(lambda ...)``),
  * ``functools.partial``-bound Pallas kernels with static-only kwargs,
  * higher-order combinators (``vmap`` / ``scan`` / ``shard_map`` / ...)
    that carry traced execution into their function arguments.

Inline markers (all plain comments, so they cost nothing at runtime):

  ``# mezlint: jit-entry``          on/above a ``def``: treat as a jit
                                    entry point even though the ``jax.jit``
                                    call lives elsewhere (e.g. in tests).
  ``# mezlint: ref-parity: <sym>``  module-level declaration that this
                                    Pallas module's kernels are oracle-
                                    checked against ``<sym>`` in
                                    ``repro.kernels.ref`` (rule MZ05).
  ``# guarded-by: <lock>``          trailing a field assignment: the field
                                    may only be touched while ``<lock>``
                                    is held (rule MZ03).
  ``# holds-lock: <lock>[, ...]``   on/above a ``def``: the method is only
                                    ever called with these locks already
                                    held (callers are checked instead).
  ``# mezlint: disable=MZxx -- why``  suppress findings on this (or the
                                    next) line; the justification is
                                    mandatory -- a bare disable is itself
                                    reported.
"""

from __future__ import annotations

import ast
import builtins
import dataclasses
import re
from pathlib import Path

_BUILTIN_NAMES = frozenset(dir(builtins))

SUPPRESS_RE = re.compile(
    r"#\s*mezlint:\s*disable=([A-Z]{2}\d{2}(?:\s*,\s*[A-Z]{2}\d{2})*)"
    r"(?:\s*--\s*(.*\S))?\s*$")
JIT_ENTRY_RE = re.compile(r"#\s*mezlint:\s*jit-entry\b")
REF_PARITY_RE = re.compile(r"#\s*mezlint:\s*ref-parity:\s*([\w.]+)")
GUARDED_BY_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")
HOLDS_LOCK_RE = re.compile(
    r"#\s*holds-lock:\s*([A-Za-z_]\w*(?:\s*,\s*[A-Za-z_]\w*)*)")

# Higher-order combinators whose function arguments execute in the caller's
# trace context: an edge caller -> f is added for ``vmap(f)`` etc.
HOF_NAMES = {"vmap", "pmap", "scan", "while_loop", "cond", "switch",
             "fori_loop", "map", "associative_scan", "shard_map",
             "checkpoint", "remat", "custom_vjp", "custom_jvp", "partial",
             "grad", "value_and_grad"}

# Attribute reads that are static at trace time regardless of the base.
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}

# Builtins whose result is static when every argument is static.
STATIC_CALLS = {"len", "range", "int", "float", "bool", "str", "min", "max",
                "abs", "round", "tuple", "list", "set", "dict", "sorted",
                "sum", "isinstance", "enumerate", "zip", "divmod", "getattr",
                "hasattr", "repr"}

# A parameter annotated with one of these is host-static by convention
# (matches how jit static_argnames are typed throughout the repo).
_STATIC_ANN = re.compile(
    r"^(?:int|bool|float|str|bytes|tuple\[[^]]*\]"
    r"|(?:int|bool|float|str)\s*\|\s*None"
    r"|None\s*\|\s*(?:int|bool|float|str))$")


def _is_jit_expr(node: ast.AST) -> bool:
    return (isinstance(node, ast.Name) and node.id == "jit") or (
        isinstance(node, ast.Attribute) and node.attr == "jit")


def _is_partial(node: ast.AST) -> bool:
    return (isinstance(node, ast.Name) and node.id == "partial") or (
        isinstance(node, ast.Attribute) and node.attr == "partial")


def _callee_tail(func: ast.AST) -> str | None:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _const_names(node: ast.AST) -> list[str]:
    """Names in a ``static_argnames=``-style constant ("x" or ("x", "y"))."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


@dataclasses.dataclass
class ModuleInfo:
    name: str                                 # dotted import path
    path: str
    tree: ast.Module
    lines: list[str]
    suppressions: dict[int, tuple[frozenset, str]]  # line -> (rules, why)
    bare_disables: list[int]                  # disables missing -- why
    ref_parity: list[str]
    aliases: dict[str, str]                   # local name -> dotted module
    from_imports: dict[str, tuple[str, str]]  # local name -> (module, symbol)
    globals: set[str] = dataclasses.field(default_factory=set)

    def line(self, lineno: int) -> str:
        return self.lines[lineno - 1] if 0 < lineno <= len(self.lines) else ""


@dataclasses.dataclass
class FunctionInfo:
    qualname: str           # module[.Class].name  (lambdas: <lambda@LINE>)
    name: str
    module: ModuleInfo
    node: ast.AST           # FunctionDef | AsyncFunctionDef | Lambda
    cls: str | None
    params: list[str]
    static_params: set[str]
    lineno: int
    entry: str | None = None        # "jit" | "pallas" | "marker" | None
    holds_locks: frozenset = frozenset()
    locals_: dict[str, str] = dataclasses.field(default_factory=dict)
    # nested def name -> qualname


@dataclasses.dataclass
class PallasSite:
    module: ModuleInfo
    node: ast.Call
    encl: FunctionInfo | None
    kernels: list[FunctionInfo]   # resolved candidates (branchy callsites
    keywords: set[str]            # may select between several kernels)


@dataclasses.dataclass
class JitWrapSite:
    module: ModuleInfo
    node: ast.Call
    encl: FunctionInfo | None
    self_assign_in_init: bool


@dataclasses.dataclass
class EntryCallSite:
    """A call that resolves to a known jit entry (for MZ02 stability)."""
    module: ModuleInfo
    node: ast.Call
    encl: FunctionInfo
    target: FunctionInfo
    loop_names: frozenset   # loop-variable names in scope at the call


def _params_of(node: ast.AST) -> list[str]:
    a = node.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _static_params_of(node: ast.AST) -> set[str]:
    """Annotation / constant-default derived static parameters."""
    out: set[str] = set()
    a = node.args
    ordered = a.posonlyargs + a.args
    # defaults align with the tail of the positional params
    for p, d in zip(ordered[len(ordered) - len(a.defaults):], a.defaults):
        if _is_const_default(d):
            out.add(p.arg)
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None and _is_const_default(d):
            out.add(p.arg)
    for p in ordered + a.kwonlyargs:
        if p.annotation is not None:
            try:
                ann = ast.unparse(p.annotation)
            except Exception:  # pragma: no cover - malformed annotation
                continue
            if _STATIC_ANN.match(ann.strip()):
                out.add(p.arg)
    return out


def _is_const_default(d: ast.AST) -> bool:
    if isinstance(d, ast.Constant):
        return True
    if isinstance(d, (ast.Tuple, ast.List)):
        return all(_is_const_default(e) for e in d.elts)
    if isinstance(d, ast.UnaryOp):
        return _is_const_default(d.operand)
    return False


class Index:
    """Cross-module function index + call graph."""

    def __init__(self) -> None:
        self.modules: dict[str, ModuleInfo] = {}
        self.functions: dict[str, FunctionInfo] = {}
        self.classes: dict[str, set[str]] = {}      # fq class -> method names
        self.calls: dict[str, set[str]] = {}        # caller -> callees
        self.pallas_sites: list[PallasSite] = []
        self.jit_wraps: list[JitWrapSite] = []
        self.entry_calls: list[EntryCallSite] = []

    # -- construction --------------------------------------------------------
    @classmethod
    def build(cls, paths: list[str]) -> "Index":
        idx = cls()
        for path in _py_files(paths):
            idx._add_module(path)
        for mod in list(idx.modules.values()):
            idx._scan_module(mod)
        return idx

    def _add_module(self, path: Path) -> None:
        src = path.read_text()
        try:
            tree = ast.parse(src)
        except SyntaxError:
            return
        lines = src.splitlines()
        sup: dict[int, tuple[frozenset, str]] = {}
        bare: list[int] = []
        parity: list[str] = []
        for i, ln in enumerate(lines, start=1):
            m = SUPPRESS_RE.search(ln)
            if m:
                rules = frozenset(r.strip() for r in m.group(1).split(","))
                why = (m.group(2) or "").strip()
                if why:
                    sup[i] = (rules, why)
                else:
                    bare.append(i)
            m = REF_PARITY_RE.search(ln)
            if m:
                parity.append(m.group(1))
        mod = ModuleInfo(name=_module_name(path), path=str(path), tree=tree,
                         lines=lines, suppressions=sup, bare_disables=bare,
                         ref_parity=parity, aliases={}, from_imports={})
        for node in tree.body:
            if isinstance(node, ast.Import):
                for al in node.names:
                    mod.aliases[al.asname or al.name.split(".")[0]] = al.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for al in node.names:
                    local = al.asname or al.name
                    mod.from_imports[local] = (node.module, al.name)
                    # ``from repro.kernels import frame_knobs as FK`` imports
                    # a module, not a symbol -- keep it usable as an alias.
                    mod.aliases.setdefault(local,
                                           f"{node.module}.{al.name}")
            elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                for t in ast.walk(node):
                    if isinstance(t, ast.Name) and isinstance(t.ctx, ast.Store):
                        mod.globals.add(t.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                mod.globals.add(node.name)
        mod.globals.update(mod.aliases)
        mod.globals.update(mod.from_imports)
        self.modules[mod.name] = mod
        self._register_functions(mod, mod.tree.body, cls_name=None, prefix="")

    def _register_functions(self, mod: ModuleInfo, body, cls_name, prefix):
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = f"{mod.name}.{prefix}{node.name}"
                fi = FunctionInfo(
                    qualname=qn, name=node.name, module=mod, node=node,
                    cls=cls_name, params=_params_of(node),
                    static_params=_static_params_of(node), lineno=node.lineno)
                self._apply_decorators(fi)
                self._apply_def_markers(fi)
                self.functions[qn] = fi
                if cls_name:
                    self.classes.setdefault(f"{mod.name}.{cls_name}",
                                            set()).add(node.name)
                # nested defs (one level is all the repo uses)
                self._register_functions(mod, node.body, cls_name,
                                         prefix=f"{prefix}{node.name}.")
            elif isinstance(node, ast.ClassDef):
                self.classes.setdefault(f"{mod.name}.{node.name}", set())
                self._register_functions(mod, node.body, node.name,
                                         prefix=f"{node.name}.")

    def _apply_decorators(self, fi: FunctionInfo) -> None:
        for dec in getattr(fi.node, "decorator_list", []):
            if _is_jit_expr(dec):
                fi.entry = "jit"
            elif isinstance(dec, ast.Call):
                if _is_jit_expr(dec.func):
                    fi.entry = "jit"
                    self._bind_static_kwargs(fi, dec.keywords)
                elif (_is_partial(dec.func) and dec.args
                      and _is_jit_expr(dec.args[0])):
                    fi.entry = "jit"
                    self._bind_static_kwargs(fi, dec.keywords)

    def _bind_static_kwargs(self, fi: FunctionInfo, keywords) -> None:
        for kw in keywords:
            if kw.arg == "static_argnames":
                fi.static_params.update(_const_names(kw.value))
            elif kw.arg == "static_argnums":
                nums = []
                if isinstance(kw.value, ast.Constant):
                    nums = [kw.value.value]
                elif isinstance(kw.value, (ast.Tuple, ast.List)):
                    nums = [e.value for e in kw.value.elts
                            if isinstance(e, ast.Constant)]
                for n in nums:
                    if isinstance(n, int) and n < len(fi.params):
                        fi.static_params.add(fi.params[n])

    def _apply_def_markers(self, fi: FunctionInfo) -> None:
        mod = fi.module
        for ln in (fi.lineno, fi.lineno - 1):
            text = mod.line(ln)
            if JIT_ENTRY_RE.search(text) and fi.entry is None:
                fi.entry = "marker"
            m = HOLDS_LOCK_RE.search(text)
            if m:
                fi.holds_locks = frozenset(
                    x.strip() for x in m.group(1).split(","))

    # -- pass 2: wraps, kernels, call edges ----------------------------------
    def _scan_module(self, mod: ModuleInfo) -> None:
        scanned: set[str] = set()
        pending = [f for f in self.functions.values() if f.module is mod]
        while pending:
            fi = pending.pop()
            if fi.qualname in scanned:
                continue
            scanned.add(fi.qualname)
            self._scan_body(mod, fi, body_of(fi.node), frozenset())
            # jit-wrapped lambdas registered while scanning get their own pass
            pending.extend(f for f in self.functions.values()
                           if f.module is mod and f.qualname not in scanned)
        # module/class-level statements (outside any def)
        self._scan_body(mod, None, _toplevel_stmts(mod.tree), frozenset())

    def _scan_body(self, mod, encl, stmts, loop_names) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue            # scanned under its own FunctionInfo
            if isinstance(st, ast.ClassDef):
                self._scan_body(mod, encl, st.body, loop_names)
                continue
            if isinstance(st, (ast.For, ast.AsyncFor)):
                names = loop_names | frozenset(
                    n.id for n in ast.walk(st.target)
                    if isinstance(n, ast.Name))
                self._scan_exprs(mod, encl, [st.iter], loop_names)
                self._scan_body(mod, encl, st.body + st.orelse, names)
                continue
            if isinstance(st, ast.While):
                self._scan_exprs(mod, encl, [st.test], loop_names)
                self._scan_body(mod, encl, st.body + st.orelse,
                                loop_names | frozenset(["<while>"]))
                continue
            inner = [n for n in ast.iter_child_nodes(st)
                     if isinstance(n, ast.stmt)]
            if inner:
                other = [n for n in ast.iter_child_nodes(st)
                         if not isinstance(n, ast.stmt)]
                self._scan_exprs(mod, encl, other, loop_names)
                self._scan_body(mod, encl, inner, loop_names)
            else:
                self._scan_exprs(mod, encl, [st], loop_names)

    def _scan_exprs(self, mod, encl, roots, loop_names) -> None:
        # manual walk so nested lambda bodies are NOT attributed to the
        # enclosing function -- a jit-wrapped lambda is its own FunctionInfo
        # and gets its own scan pass
        stack = list(roots)
        while stack:
            node = stack.pop()
            if isinstance(node, ast.Lambda):
                continue
            if isinstance(node, ast.Call):
                self._handle_call(mod, encl, node, loop_names)
            stack.extend(ast.iter_child_nodes(node))

    def _handle_call(self, mod, encl, call: ast.Call, loop_names) -> None:
        func = call.func
        caller = encl.qualname if encl else f"{mod.name}.<module>"
        # jit wrap: jax.jit(f, ...) / jax.jit(lambda: ...)
        if _is_jit_expr(func) and call.args:
            in_init = bool(encl and encl.name == "__init__")
            self.jit_wraps.append(JitWrapSite(
                module=mod, node=call, encl=encl,
                self_assign_in_init=in_init))
            target = self._resolve_callable(mod, encl, call.args[0])
            if target is not None:
                target.entry = target.entry or "jit"
                self._bind_static_kwargs(target, call.keywords)
            return
        # pallas_call(kernel, ...)
        if isinstance(func, ast.Attribute) and func.attr == "pallas_call" \
                or (isinstance(func, ast.Name) and func.id == "pallas_call"):
            kernels = self._kernel_candidates(mod, encl, call.args[0]) \
                if call.args else []
            for k in kernels:
                k.entry = k.entry or "pallas"
            self.pallas_sites.append(PallasSite(
                module=mod, node=call, encl=encl, kernels=kernels,
                keywords={kw.arg for kw in call.keywords if kw.arg}))
            return
        # higher-order combinators carry trace context into their args
        tail = _callee_tail(func)
        if tail in HOF_NAMES:
            for arg in list(call.args) + [kw.value for kw in call.keywords]:
                t = self._resolve_callable(mod, encl, arg, register_edge=True,
                                           caller=caller)
                del t
            return
        # plain call edge
        target = self._resolve(mod, encl, func)
        if target is not None:
            self.calls.setdefault(caller, set()).add(target.qualname)
            if target.entry and target.static_params and encl is not None:
                self.entry_calls.append(EntryCallSite(
                    module=mod, node=call, encl=encl, target=target,
                    loop_names=loop_names))

    def _kernel_candidates(self, mod, encl, expr) -> list[FunctionInfo]:
        """Kernel expressions may be a local name assigned (possibly in
        several branches) from ``functools.partial(<kernel>, ...)``."""
        direct = self._resolve_callable(mod, encl, expr)
        if direct is not None:
            return [direct]
        out: list[FunctionInfo] = []
        if isinstance(expr, ast.Name) and encl is not None:
            for st in ast.walk(encl.node):
                if isinstance(st, ast.Assign) and any(
                        isinstance(t, ast.Name) and t.id == expr.id
                        for t in st.targets):
                    cand = self._resolve_callable(mod, encl, st.value)
                    if cand is not None:
                        out.append(cand)
        return out

    def _resolve_callable(self, mod, encl, expr, register_edge=False,
                          caller=None) -> FunctionInfo | None:
        """Resolve a callable expression: name, lambda, partial(name, ...)."""
        if isinstance(expr, ast.Lambda):
            fi = self._register_lambda(mod, encl, expr)
            if register_edge and caller:
                self.calls.setdefault(caller, set()).add(fi.qualname)
            return fi
        if isinstance(expr, ast.Call) and _is_partial(expr.func) and expr.args:
            inner = self._resolve_callable(mod, encl, expr.args[0],
                                           register_edge, caller)
            if inner is not None:
                # kwargs bound at partial time are host values -> static
                inner.static_params.update(
                    kw.arg for kw in expr.keywords if kw.arg)
            return inner
        target = self._resolve(mod, encl, expr)
        if target is not None and register_edge and caller:
            self.calls.setdefault(caller, set()).add(target.qualname)
        return target

    def _register_lambda(self, mod, encl, node: ast.Lambda) -> FunctionInfo:
        qn = f"{mod.name}.<lambda@{node.lineno}>"
        if qn not in self.functions:
            self.functions[qn] = FunctionInfo(
                qualname=qn, name=f"<lambda@{node.lineno}>", module=mod,
                node=node, cls=encl.cls if encl else None,
                params=_params_of(node), static_params=set(),
                lineno=node.lineno)
        return self.functions[qn]

    def _resolve(self, mod, encl, func) -> FunctionInfo | None:
        if isinstance(func, ast.Name):
            n = func.id
            if encl is not None:
                nested = f"{encl.qualname}.{n}"
                if nested in self.functions:
                    return self.functions[nested]
            if f"{mod.name}.{n}" in self.functions:
                return self.functions[f"{mod.name}.{n}"]
            if n in mod.from_imports:
                m, sym = mod.from_imports[n]
                return self.functions.get(f"{m}.{sym}")
            return None
        if isinstance(func, ast.Attribute):
            base, attr = func.value, func.attr
            if isinstance(base, ast.Name):
                if base.id in ("self", "cls") and encl is not None and encl.cls:
                    return self.functions.get(f"{mod.name}.{encl.cls}.{attr}")
                if base.id in mod.aliases:
                    return self.functions.get(f"{mod.aliases[base.id]}.{attr}")
                # ClassName.method -- local or imported class
                if f"{mod.name}.{base.id}" in self.classes:
                    return self.functions.get(f"{mod.name}.{base.id}.{attr}")
                if base.id in mod.from_imports:
                    m, sym = mod.from_imports[base.id]
                    if f"{m}.{sym}" in self.classes:
                        return self.functions.get(f"{m}.{sym}.{attr}")
        return None

    # -- reachability --------------------------------------------------------
    def entries(self) -> list[FunctionInfo]:
        return [f for f in self.functions.values() if f.entry]

    def reachable(self) -> dict[str, str]:
        """qualname -> the entry qualname it is reachable from (BFS)."""
        seen: dict[str, str] = {}
        frontier = [(f.qualname, f.qualname) for f in self.entries()]
        while frontier:
            qn, root = frontier.pop()
            if qn in seen:
                continue
            seen[qn] = root
            for callee in self.calls.get(qn, ()):
                if callee not in seen:
                    frontier.append((callee, root))
        return seen


def body_of(node: ast.AST) -> list[ast.stmt]:
    if isinstance(node, ast.Lambda):
        return [ast.Expr(value=node.body)]
    return list(node.body)


def _toplevel_stmts(tree: ast.Module) -> list[ast.stmt]:
    return [st for st in tree.body
            if not isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef))]


def _module_name(path: Path) -> str:
    parts = list(path.with_suffix("").parts)
    for anchor in ("src", "repro"):
        if anchor in parts:
            i = parts.index(anchor)
            parts = parts[i + 1 :] if anchor == "src" else parts[i:]
            break
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) or path.stem


def _py_files(paths: list[str]) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    return out


# =============================================================================
# Trace-time staticness dataflow (MZ01 / MZ04 substrate)
# =============================================================================


@dataclasses.dataclass
class TraceEvent:
    node: ast.AST
    kind: str           # "if" | "while" | "assert" | "ifexp" | "comp-if"


def scan_dynamic_tests(fi: FunctionInfo,
                       extra_static: frozenset = frozenset()
                       ) -> list[TraceEvent]:
    """Python branches whose test is not trace-time static.

    The dataflow is a single forward pass: a name is *static* if it is a
    static parameter, a module-level binding, or assigned from an
    expression built only of static parts (shape/ndim/dtype attributes are
    static regardless of their base).  ``x is None`` compares against the
    tracer object itself and is always static.  ``extra_static`` carries
    inherited static names for nested functions (the enclosing function's
    static parameters are static in the closure too).
    """
    static = (set(fi.static_params) | fi.module.globals | _BUILTIN_NAMES
              | set(extra_static))
    events: list[TraceEvent] = []
    _walk_stmts(body_of(fi.node), static, events)
    return events


def inherited_static(idx: "Index", fi: FunctionInfo) -> frozenset:
    """Static parameter names of every enclosing function of ``fi``."""
    out: set[str] = set()
    qn = fi.qualname
    while "." in qn:
        qn = qn.rsplit(".", 1)[0]
        parent = idx.functions.get(qn)
        if parent is not None:
            out.update(parent.static_params)
    return frozenset(out)


def _walk_stmts(stmts, static: set, events: list) -> None:
    for st in stmts:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            static.add(st.name)
            continue
        if isinstance(st, ast.Assign):
            _expr_events(st.value, static, events)
            s = _is_static(st.value, static)
            for t in st.targets:
                _bind(t, s, static)
        elif isinstance(st, ast.AnnAssign) and st.value is not None:
            _expr_events(st.value, static, events)
            _bind(st.target, _is_static(st.value, static), static)
        elif isinstance(st, ast.AugAssign):
            _expr_events(st.value, static, events)
            if isinstance(st.target, ast.Name):
                if not (st.target.id in static
                        and _is_static(st.value, static)):
                    static.discard(st.target.id)
        elif isinstance(st, (ast.If, ast.While)):
            _expr_events(st.test, static, events)
            if not _is_static(st.test, static):
                events.append(TraceEvent(
                    st, "while" if isinstance(st, ast.While) else "if"))
            _walk_stmts(st.body, static, events)
            _walk_stmts(st.orelse, static, events)
        elif isinstance(st, ast.Assert):
            _expr_events(st.test, static, events)
            if not _is_static(st.test, static):
                events.append(TraceEvent(st, "assert"))
        elif isinstance(st, (ast.For, ast.AsyncFor)):
            _expr_events(st.iter, static, events)
            _bind(st.target, _is_static(st.iter, static), static)
            _walk_stmts(st.body, static, events)
            _walk_stmts(st.orelse, static, events)
        elif isinstance(st, ast.With):
            for item in st.items:
                _expr_events(item.context_expr, static, events)
            _walk_stmts(st.body, static, events)
        elif isinstance(st, ast.Try):
            _walk_stmts(st.body, static, events)
            for h in st.handlers:
                _walk_stmts(h.body, static, events)
            _walk_stmts(st.orelse, static, events)
            _walk_stmts(st.finalbody, static, events)
        elif isinstance(st, (ast.Return, ast.Expr)) and st.value is not None:
            _expr_events(st.value, static, events)
        elif isinstance(st, ast.Raise):
            pass
        else:
            for sub in ast.iter_child_nodes(st):
                if isinstance(sub, ast.expr):
                    _expr_events(sub, static, events)


def _bind(target: ast.AST, is_static: bool, static: set) -> None:
    for n in ast.walk(target):
        if isinstance(n, ast.Name):
            (static.add if is_static else static.discard)(n.id)


def _expr_events(expr: ast.AST, static: set, events: list) -> None:
    """Collect dynamic-test events hiding inside expressions."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Lambda):
            continue
        if isinstance(node, ast.IfExp) and not _is_static(node.test, static):
            events.append(TraceEvent(node, "ifexp"))
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                for cond in gen.ifs:
                    if not _is_static(cond, static):
                        events.append(TraceEvent(cond, "comp-if"))


def _is_static(expr: ast.AST, static: set) -> bool:
    if isinstance(expr, ast.Constant):
        return True
    if isinstance(expr, ast.Name):
        return expr.id in static
    if isinstance(expr, ast.Attribute):
        if expr.attr in STATIC_ATTRS:
            return True
        return _is_static(expr.value, static)
    if isinstance(expr, ast.Compare):
        if any(isinstance(op, (ast.Is, ast.IsNot)) for op in expr.ops):
            return True     # identity check against a tracer is static
        return all(_is_static(e, static)
                   for e in [expr.left] + list(expr.comparators))
    if isinstance(expr, (ast.BinOp,)):
        return _is_static(expr.left, static) and _is_static(expr.right, static)
    if isinstance(expr, ast.UnaryOp):
        return _is_static(expr.operand, static)
    if isinstance(expr, ast.BoolOp):
        return all(_is_static(v, static) for v in expr.values)
    if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
        return all(_is_static(e, static) for e in expr.elts)
    if isinstance(expr, ast.Dict):
        return all(_is_static(e, static)
                   for e in list(expr.keys) + list(expr.values)
                   if e is not None)
    if isinstance(expr, ast.Subscript):
        return _is_static(expr.value, static) and _is_static(expr.slice,
                                                             static)
    if isinstance(expr, ast.Slice):
        return all(e is None or _is_static(e, static)
                   for e in (expr.lower, expr.upper, expr.step))
    if isinstance(expr, ast.IfExp):
        return all(_is_static(e, static)
                   for e in (expr.test, expr.body, expr.orelse))
    if isinstance(expr, ast.Call):
        fn = expr.func
        named_static = (isinstance(fn, ast.Name) and fn.id in STATIC_CALLS)
        if named_static:
            return all(_is_static(a, static) for a in expr.args)
        return False
    if isinstance(expr, ast.JoinedStr):
        return True
    if isinstance(expr, ast.Starred):
        return _is_static(expr.value, static)
    return False


def iter_body_calls(fi: FunctionInfo):
    """Every Call node in ``fi``'s own body, skipping nested defs/lambdas
    (they are separate FunctionInfos with their own scan)."""
    stack: list[ast.AST] = list(body_of(fi.node))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            continue
        if isinstance(node, ast.Call):
            yield node
        stack.extend(ast.iter_child_nodes(node))
