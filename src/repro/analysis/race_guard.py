"""Runtime lock instrumentation -- the dynamic counterpart of mezlint MZ03.

``race_guard`` wraps the locks of ``HostLog`` (segment ``_RWLock``s +
``_meta_lock``) and ``CamBroker`` (``_version_lock``) in bookkeeping
proxies while the context is active:

  * **Exclusion invariants**: a writer entering while readers (or another
    writer) are inside the same RW lock, or two threads inside one mutex,
    is recorded as a violation -- this is the check that would have caught
    the pre-PR-2 ``HostLog`` wrap-around race at runtime had the unlocked
    timestamp scan taken any lock at all (it took none, which the *static*
    MZ03 rule catches; the runtime guard covers the lock implementation
    itself and future refactors of it).
  * **Lock-order cycles**: acquiring B while holding A adds an A->B edge;
    a cycle in that graph is a latent deadlock even if the soak run never
    actually deadlocked.
  * **Leaks**: locks still held when the context exits.

Instances created *inside* the context are instrumented automatically
(``HostLog.__init__`` / ``CamBroker.__init__`` are patched for the
duration); pre-existing objects can be passed to ``instrument()``.

The slow soak job runs the whole suite under this shim: set
``MEZLINT_RACE_GUARD=1`` and the autouse fixture in ``tests/conftest.py``
activates one guard per test.

Violations raise ``RaceGuardError`` on exit (collected, not thrown
mid-flight, so the offending interleaving is reported in full).
"""

from __future__ import annotations

import os
import threading


class RaceGuardError(AssertionError):
    """Lock-discipline violation observed at runtime."""


class _Shared:
    """Bookkeeping shared by every proxy of one race_guard context."""

    def __init__(self) -> None:
        self.mu = threading.Lock()
        self.violations: list[str] = []
        self.held = threading.local()       # per-thread list of labels
        self.order: dict[str, set[str]] = {}  # label -> labels acquired after

    def stack(self) -> list[str]:
        if not hasattr(self.held, "v"):
            self.held.v = []
        return self.held.v

    def note_acquire(self, label: str) -> None:
        stack = self.stack()
        with self.mu:
            for outer in stack:
                if outer == label:
                    continue
                self.order.setdefault(outer, set()).add(label)
                if self._reaches(label, outer):
                    self.violations.append(
                        f"lock-order cycle: {outer} -> {label} while a "
                        f"{label} -> ... -> {outer} path exists")
        stack.append(label)

    def note_release(self, label: str) -> None:
        stack = self.stack()
        if label in stack:
            stack.remove(label)

    def _reaches(self, src: str, dst: str) -> bool:
        seen, frontier = set(), [src]
        while frontier:
            n = frontier.pop()
            if n == dst:
                return True
            if n in seen:
                continue
            seen.add(n)
            frontier.extend(self.order.get(n, ()))
        return False

    def violation(self, msg: str) -> None:
        with self.mu:
            self.violations.append(msg)


class _LockProxy:
    """Mutex wrapper: context manager + acquire/release, counted."""

    def __init__(self, inner, shared: _Shared, label: str):
        self._inner = inner
        self._shared = shared
        self._label = label
        self._owners = 0
        self._mu = threading.Lock()

    def acquire(self, *a, **kw):
        got = self._inner.acquire(*a, **kw)
        if got:
            with self._mu:
                self._owners += 1
                if self._owners > 1:
                    self._shared.violation(
                        f"{self._label}: {self._owners} threads inside a "
                        f"mutex at once")
            self._shared.note_acquire(self._label)
        return got

    def release(self):
        with self._mu:
            self._owners -= 1
        self._shared.note_release(self._label)
        return self._inner.release()

    def locked(self):
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()


class _RWLockProxy:
    """``_RWLock`` wrapper checking reader/writer exclusion."""

    def __init__(self, inner, shared: _Shared, label: str):
        self._inner = inner
        self._shared = shared
        self._label = label
        self._mu = threading.Lock()
        self._readers = 0
        self._writers = 0

    def acquire_read(self):
        self._inner.acquire_read()
        with self._mu:
            self._readers += 1
            if self._writers:
                self._shared.violation(
                    f"{self._label}: reader admitted while a writer is "
                    f"inside")
        self._shared.note_acquire(self._label)

    def release_read(self):
        with self._mu:
            self._readers -= 1
        self._shared.note_release(self._label)
        self._inner.release_read()

    def acquire_write(self):
        self._inner.acquire_write()
        with self._mu:
            self._writers += 1
            if self._writers > 1 or self._readers:
                self._shared.violation(
                    f"{self._label}: writer admitted with {self._readers} "
                    f"readers / {self._writers} writers inside")
        self._shared.note_acquire(self._label)

    def release_write(self):
        with self._mu:
            self._writers -= 1
        self._shared.note_release(self._label)
        self._inner.release_write()


class race_guard:
    """Context manager; see module docstring.

    ``strict=True`` (default) raises ``RaceGuardError`` on exit when any
    violation was recorded; ``strict=False`` only collects them in
    ``.violations`` (useful when a test wants to assert on the content).
    """

    def __init__(self, *, strict: bool = True):
        self.strict = strict
        self.shared = _Shared()
        self._patches: list[tuple[type, str, object]] = []

    # -- public --------------------------------------------------------------
    @property
    def violations(self) -> list[str]:
        return list(self.shared.violations)

    def instrument(self, obj) -> None:
        """Wrap the known lock attributes of ``obj`` in proxies."""
        name = type(obj).__name__
        if hasattr(obj, "_meta_lock") and not isinstance(
                obj._meta_lock, _LockProxy):
            obj._meta_lock = _LockProxy(
                obj._meta_lock, self.shared, f"{name}._meta_lock")
        if hasattr(obj, "_seg_locks"):
            obj._seg_locks = [
                lk if isinstance(lk, _RWLockProxy) else _RWLockProxy(
                    lk, self.shared, f"{name}._seg_locks[{i}]")
                for i, lk in enumerate(obj._seg_locks)]
        if hasattr(obj, "_version_lock") and not isinstance(
                obj._version_lock, _LockProxy):
            obj._version_lock = _LockProxy(
                obj._version_lock, self.shared, f"{name}._version_lock")

    # -- context -------------------------------------------------------------
    def __enter__(self) -> "race_guard":
        self._patch_init("repro.core.log", "HostLog")
        self._patch_init("repro.core.broker", "CamBroker")
        return self

    def _patch_init(self, module: str, clsname: str) -> None:
        try:
            import importlib
            cls = getattr(importlib.import_module(module), clsname)
        except Exception:       # broker pulls jax; fine to skip in lint jobs
            return
        orig = cls.__init__
        guard = self

        def wrapped(self_obj, *a, **kw):
            orig(self_obj, *a, **kw)
            guard.instrument(self_obj)

        wrapped.__wrapped__ = orig
        cls.__init__ = wrapped
        self._patches.append((cls, "__init__", orig))

    def __exit__(self, exc_type, exc, tb) -> None:
        for cls, attr, orig in reversed(self._patches):
            setattr(cls, attr, orig)
        self._patches.clear()
        if exc_type is None and self.strict and self.shared.violations:
            raise RaceGuardError(
                "race_guard recorded violation(s):\n  "
                + "\n  ".join(self.shared.violations))


def from_env() -> "race_guard | None":
    """One guard per test when ``MEZLINT_RACE_GUARD=1`` (CI soak job)."""
    if os.environ.get("MEZLINT_RACE_GUARD") == "1":
        return race_guard()
    return None
