"""Fault-tolerant checkpointing, Mez-log style (paper Section 4.4 applied to
training state).

Design mirrors the Mez persistence layer:
  * per-leaf files with CRC32 integrity records (torn/corrupted leaves are
    detected and the whole step is discarded, falling back to the previous
    valid step -- exactly the paper's "partially written segments ...
    discarded during the recovery process"),
  * atomic publication (write to a temp dir, fsync, rename),
  * background-friendly: save() can run in a worker thread off the training
    loop's critical path,
  * MESH-INDEPENDENT format: leaves are stored as full (unsharded) arrays
    plus the logical PartitionSpec they were trained under; restore() lays
    them out on WHATEVER mesh is passed (elastic scaling: restore a
    256-chip checkpoint onto 512 chips or onto 1 CPU device for debugging).

Layout:
  <root>/step_<n>/MANIFEST.json       {step, keys, specs, crcs, meta}
  <root>/step_<n>/<flatkey>.npy
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


class Checkpointer:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()

    # -- save -------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, specs: Any = None,
             meta: dict | None = None) -> str:
        """Write one checkpoint atomically; returns the final directory."""
        with self._lock:
            final = os.path.join(self.root, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            flat = _flatten(tree)
            crcs = {}
            for key, arr in flat.items():
                fname = key.replace("/", "__") + ".npy"
                path = os.path.join(tmp, fname)
                with open(path, "wb") as fh:
                    np.save(fh, arr)
                    fh.flush()
                    os.fsync(fh.fileno())
                with open(path, "rb") as fh:
                    crcs[key] = f"{zlib.crc32(fh.read()) & 0xFFFFFFFF:08x}"
            manifest = {
                "step": step,
                "keys": sorted(flat),
                "crcs": crcs,
                "treedef": jax.tree_util.tree_structure(tree).__repr__(),
                "specs": (jax.tree_util.tree_map(
                    lambda s: str(s), specs,
                    is_leaf=lambda x: hasattr(x, "spec") or
                    type(x).__name__ == "PartitionSpec").__repr__()
                    if specs is not None else None),
                "meta": meta or {},
            }
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as fh:
                json.dump(manifest, fh, indent=1)
                fh.flush()
                os.fsync(fh.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()
            return final

    def save_async(self, step: int, tree: Any, **kw) -> threading.Thread:
        """Background save (off the training critical path).  Host copies of
        the leaves are snapshotted eagerly so training can mutate buffers."""
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        t = threading.Thread(target=self.save, args=(step, host_tree),
                             kwargs=kw, daemon=True)
        t.start()
        return t

    # -- restore ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def _valid(self, step: int) -> bool:
        d = os.path.join(self.root, f"step_{step:08d}")
        mpath = os.path.join(d, "MANIFEST.json")
        if not os.path.exists(mpath):
            return False
        try:
            with open(mpath) as fh:
                manifest = json.load(fh)
        except json.JSONDecodeError:
            return False
        for key in manifest["keys"]:
            path = os.path.join(d, key.replace("/", "__") + ".npy")
            if not os.path.exists(path):
                return False
            with open(path, "rb") as fh:
                if f"{zlib.crc32(fh.read()) & 0xFFFFFFFF:08x}" != \
                        manifest["crcs"][key]:
                    return False
        return True

    def latest_valid_step(self) -> int | None:
        """Newest step whose every leaf passes CRC (torn steps skipped)."""
        for step in reversed(self.steps()):
            if self._valid(step):
                return step
        return None

    def restore(self, target_tree: Any, *, step: int | None = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Restore into the structure of ``target_tree``.

        ``shardings``: optional pytree of NamedSharding matching target_tree;
        leaves are device_put with it -- this is the elastic-rescale path
        (any mesh shape works, the stored arrays are unsharded).
        """
        step = step if step is not None else self.latest_valid_step()
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {self.root}")
        d = os.path.join(self.root, f"step_{step:08d}")
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        if shardings is not None:
            flat_s = treedef.flatten_up_to(shardings)
        leaves = []
        for i, (path, leaf) in enumerate(flat_t):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = np.load(os.path.join(d, key.replace("/", "__") + ".npy"))
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {leaf.shape}")
            if shardings is not None and flat_s[i] is not None:
                leaves.append(jax.device_put(arr, flat_s[i]))
            else:
                leaves.append(jax.device_put(arr))
        return treedef.unflatten(leaves), step

    # -- gc ------------------------------------------------------------------------
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- test helper -----------------------------------------------------------------
    def corrupt(self, step: int, *, leaf_index: int = 0) -> None:
        """Flip a byte in one leaf (emulates a torn write for tests)."""
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "MANIFEST.json")) as fh:
            manifest = json.load(fh)
        key = manifest["keys"][leaf_index]
        path = os.path.join(d, key.replace("/", "__") + ".npy")
        with open(path, "r+b") as fh:
            fh.seek(-1, 2)
            b = fh.read(1)
            fh.seek(-1, 2)
            fh.write(bytes([b[0] ^ 0xFF]))
