"""Deprecated v1 compatibility surface.

The v1 iterator API (paper Fig. 7: one blocking ``subscribe`` call per
camera) predates the v2 session machinery.  ``EdgeBroker.subscribe`` now
warns ``DeprecationWarning`` on every call; v1 callers that cannot migrate
yet should import :func:`subscribe_v1` from here instead -- same behavior,
no per-call warning, one explicit opt-in import.

Migration (see README "v1 -> v2 migration"):

    # v1                                     # v2
    for f in edge.subscribe(spec): ...       with client.open_session(app) as s:
                                                 sub = s.subscribe([cam], t0, t1,
                                                                   qos=QosBounds(l, a))
                                                 for f in sub.frames(): ...

This module is the LAST v1 surface and will be removed with it.
"""

from __future__ import annotations

from typing import Iterator

from repro.core.api import DeliveredFrame, SubscribeSpec

__all__ = ["subscribe_v1"]


def subscribe_v1(edge, spec: SubscribeSpec, *,
                 controlled: bool = True,
                 feedback_window: int = 8,
                 fetch_window: int = 2) -> Iterator[DeliveredFrame]:
    """v1 streaming subscription over the v2 session machinery, without the
    per-call ``DeprecationWarning`` (importing this module IS the opt-in).

    ``edge`` is an ``EdgeBroker`` (or anything with ``_subscribe_v1``, e.g.
    pass ``system.edge`` for a ``MezSystem``)."""
    edge = getattr(edge, "edge", edge)
    return edge._subscribe_v1(spec, controlled=controlled,
                              feedback_window=feedback_window,
                              fetch_window=fetch_window)
