"""Activation sharding constraints, mesh-agnostic.

Model code calls ``shard(x, "dp", None, "model")`` at key activation points
(post-embedding, per-scan-block, logits).  When a mesh has been activated
(launch/dry-run paths call ``activate(mesh)`` before tracing), this lowers to
``with_sharding_constraint`` pinning the batch dim to the DP axes and feature
dims to the model axis -- without it GSPMD is free to replicate the batch to
resolve FSDP contractions, which explodes activation memory (observed: 40 GB
unsharded logits per device on the 256-chip pod).  Without an active mesh
(single-device smoke tests) every call is a no-op.

Roles per dim: "dp" (pod+data), "model", None.  Divisibility is checked per
dim -- a role that doesn't divide falls back to replicated, so constraints
never change numerics or break lowering.
"""

from __future__ import annotations

import threading

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["activate", "deactivate", "shard", "active_axes"]

_ctx = threading.local()


def activate(mesh: Mesh, *, zero3: bool = False) -> None:
    names = ("pod", "data", "model") if zero3 else ("pod", "data")
    _ctx.dp = tuple(n for n in names if n in mesh.axis_names)
    # under zero3 the model axis is free for SEQUENCE sharding (it is last
    # in the dp prefix order, so batch dims claim (pod, data) first and a
    # sequence_parallel constraint can still land on "model")
    _ctx.model = "model" if "model" in mesh.axis_names else None
    _ctx.sizes = dict(mesh.shape)
    _ctx.mesh = mesh
    _ctx.on = True


def deactivate() -> None:
    _ctx.on = False


def active_axes() -> dict | None:
    if not getattr(_ctx, "on", False):
        return None
    return {"dp": _ctx.dp, "model": _ctx.model, "sizes": _ctx.sizes}


def _axis_size(axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([_ctx.sizes[a] for a in axes]))


def shard(x: jax.Array, *roles) -> jax.Array:
    """Constrain x's sharding.  roles: one of "dp" | "model" | None per dim."""
    if not getattr(_ctx, "on", False):
        return x
    assert len(roles) == x.ndim, (roles, x.shape)
    spec = []
    for dim, role in zip(x.shape, roles):
        if role == "dp":
            axes = _ctx.dp
        elif role == "model":
            axes = _ctx.model
        else:
            axes = None
        # longest divisible prefix (batch may not divide the full dp size)
        chosen = None
        if axes is not None:
            seq = (axes,) if isinstance(axes, str) else axes
            for k in range(len(seq), 0, -1):
                if dim % _axis_size(seq[:k]) == 0:
                    chosen = seq[:k]
                    break
        spec.append(chosen)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ctx.mesh, P(*spec)))
