"""Logical-axis sharding rules -> PartitionSpecs for params, batches, caches.

Mesh axes:
  pod    (multi-pod only)  composes with `data` into the DP/FSDP axis
  data   DP: batch dims; FSDP: the d_model-ish dim of every weight
  model  TP: heads / d_ff / vocab / experts; SP: decode KV sequence

Rules are name-based over the parameter pytree (tree_map_with_path); every
family's parameter names were chosen so the table below covers them:

  name                      layout                     spec (L = scan dim)
  embed                     [V, D]                     (model, fsdp)*
  lm_head                   [D, V]                     (fsdp, model)*
  wq|wk|wv|wg|wr|w_gate|w_up|cm_wk|cm_wr|in_proj|mix_down|w_down(lora)
                            [L, D, out]                (None, fsdp, model)
  wo|w_down|cm_wv|out_proj  [L, in, D]                 (None, model, fsdp)
  moe router                [L, D, E]                  (None, fsdp, None)
  moe w_gate|w_up           [L, E, D, F]   EP          (None, model, fsdp, None)
  moe w_down                [L, E, F, D]   EP          (None, model, None, fsdp)
  conv_w                    [L, K, C]                  (None, None, model)
  lora qa|ka|va             [I, D, r]                  (None, fsdp, None)
  lora qb|kb|vb             [I, r, out]                (None, None, model)
  norms / scalars           replicated

  (*) vocab falls back to replicated when V % model != 0 (seamless's 256206).

Every rule checks divisibility and drops the axis if it doesn't divide --
sharding must never change numerics or fail to lower.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell

__all__ = ["param_specs", "batch_specs", "cache_specs", "fsdp_axes",
           "shardings_for", "opt_state_specs", "logical_to_sharding",
           "fleet_mesh", "padded_lane_count", "shard_fleet_tick",
           "fleet_sharding"]


def fsdp_axes(mesh: Mesh, cfg: ModelConfig):
    """The DP/FSDP axis (composes pod+data on multi-pod meshes; zero3 mode
    folds the model axis in too)."""
    names = ("pod", "data", "model") if getattr(cfg, "zero3", False) \
        else ("pod", "data")
    return tuple(n for n in names if n in mesh.axis_names)


def _dp(mesh: Mesh, cfg: ModelConfig | None = None):
    names = ("pod", "data", "model") if (cfg is not None and
                                         getattr(cfg, "zero3", False)) \
        else ("pod", "data")
    return tuple(n for n in names if n in mesh.axis_names)


def _size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(mesh: Mesh, dim: int, axes):
    """axes if they divide dim, else None (replicate)."""
    if axes is None:
        return None
    sz = _size(mesh, axes)
    return axes if (sz > 0 and dim % sz == 0) else None


def _best_prefix(mesh: Mesh, dim: int, axes):
    """Longest prefix of ``axes`` whose size divides dim (zero3 multi-pod:
    batch 256 can't shard 512 ways -- fall back to (pod, data))."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    for k in range(len(axes), 0, -1):
        sub = axes[:k]
        if dim % _size(mesh, sub) == 0:
            return sub
    return None


# -----------------------------------------------------------------------------
# parameters
# -----------------------------------------------------------------------------

# leaf-name -> (in_axis_role, out_axis_role); roles: fsdp | model | none
_COL_PARALLEL = re.compile(
    r"^(wq|wk|wv|wg|wr|w_gate|w_up|cm_wk|cm_wr|in_proj|mix_down|w_down_lora)$")
_ROW_PARALLEL = re.compile(r"^(wo|w_down|cm_wv|out_proj)$")


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def _path_names(path) -> list[str]:
    return [str(e.key) for e in path if isinstance(e, jax.tree_util.DictKey)]


def param_specs(params_shape: Any, cfg: ModelConfig, mesh: Mesh):
    """PartitionSpec pytree matching ``jax.eval_shape`` of the params."""
    fsdp = fsdp_axes(mesh, cfg) if cfg.fsdp else None
    model = "model" if "model" in mesh.axis_names else None

    if getattr(cfg, "zero3", False):
        model = None                      # no tensor parallelism

    def spec(path, leaf) -> P:
        names = _path_names(path)
        name = _leaf_name(path)
        shape = leaf.shape
        nd = len(shape)
        in_moe = "moe" in names
        # --- embeddings ------------------------------------------------------
        if name == "embed":
            v, d = shape
            return P(_maybe(mesh, v, model), _maybe(mesh, d, fsdp))
        if name == "lm_head":
            d, v = shape
            return P(_maybe(mesh, d, fsdp), _maybe(mesh, v, model))
        # --- MoE expert weights [L, E, D, F] / [L, E, F, D] -------------------
        if in_moe and name in ("w_gate", "w_up", "w_down") and nd == 4:
            L, e, a, b = shape
            if cfg.moe_parallel == "ep":
                espec = _maybe(mesh, e, model)
                if name == "w_down":    # [L, E, F, D]
                    return P(None, espec, None, _maybe(mesh, b, fsdp))
                return P(None, espec, _maybe(mesh, a, fsdp), None)
            else:                        # TP inside experts
                if name == "w_down":    # [L, E, F, D]
                    return P(None, None, _maybe(mesh, a, model),
                             _maybe(mesh, b, fsdp))
                return P(None, None, _maybe(mesh, a, fsdp),
                         _maybe(mesh, b, model))
        if in_moe and name == "router":  # [L, D, E]
            return P(None, _maybe(mesh, shape[1], fsdp), None)
        # --- zamba LoRA stacks [I, D, r] / [I, r, out] ------------------------
        if name in ("qa", "ka", "va"):
            return P(None, _maybe(mesh, shape[1], fsdp), None)
        if name in ("qb", "kb", "vb"):
            return P(None, None, _maybe(mesh, shape[2], model))
        # --- mamba conv [L, K, C] ---------------------------------------------
        if name == "conv_w":
            return P(*([None] * (nd - 1)), _maybe(mesh, shape[-1], model))
        # --- generic col/row parallel (leading scan dims allowed) -------------
        # Under sequence parallelism, attention weights drop the model axis
        # ONLY when the head count doesn't divide it (phi3: 40H vs 16) --
        # that's the case where head-sharding computes redundantly.  Archs
        # with divisible heads (llama3: 32H) keep Megatron-TP weights and
        # get RS/AG'd boundary activations instead.
        attn_names = ("wq", "wk", "wv", "wo")
        msize = _size(mesh, model)
        sp_attn = (cfg.sequence_parallel and name in attn_names
                   and cfg.num_heads % max(msize, 1) != 0)
        if _COL_PARALLEL.match(name) and nd >= 2:
            lead = [None] * (nd - 2)
            return P(*lead, _maybe(mesh, shape[-2], fsdp),
                     None if sp_attn else _maybe(mesh, shape[-1], model))
        if _ROW_PARALLEL.match(name) and nd >= 2:
            lead = [None] * (nd - 2)
            return P(*lead, None if sp_attn else _maybe(mesh, shape[-2], model),
                     _maybe(mesh, shape[-1], fsdp))
        if name in ("w_down",) and nd >= 2:  # non-moe fallthrough safety
            lead = [None] * (nd - 2)
            return P(*lead, _maybe(mesh, shape[-2], model),
                     _maybe(mesh, shape[-1], fsdp))
        if name in ("w_up", "mix_up") and nd >= 2:
            lead = [None] * (nd - 2)
            return P(*lead, _maybe(mesh, shape[-2], None),
                     _maybe(mesh, shape[-1], model))
        # --- everything else (norms, scalars, biases, u, A_log, ...) ----------
        return P()

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def opt_state_specs(params_shape: Any, cfg: ModelConfig, mesh: Mesh):
    """Adam m/v mirror the parameter sharding; counters replicated."""
    pspecs = param_specs(params_shape, cfg, mesh)
    return {"m": pspecs, "v": pspecs, "count": P()}


# -----------------------------------------------------------------------------
# batches and caches
# -----------------------------------------------------------------------------


def batch_specs(batch_shape: dict, cfg: ModelConfig, mesh: Mesh,
                cell: ShapeCell):
    dp = _dp(mesh, cfg)

    def spec(path, leaf):
        b = leaf.shape[0]
        lead = _best_prefix(mesh, b, dp)
        # shard only the batch dim; seq/feature replicated for activations
        return P(lead, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def cache_specs(cache_shape: Any, cfg: ModelConfig, mesh: Mesh,
                cell: ShapeCell):
    """KV/state cache sharding for decode cells.

    Layouts handled:
      [L, B, S, KH, HD]  kv cache      -> B: dp, S: model  (flash-decode SP)
      [B, S, D]          enc_out       -> B: dp
      [L, B, H, K, V]    wkv/ssm state -> B: dp, H: model
      [L, B, K-1, C]     conv state    -> B: dp, C: model
      [L, B, D]          shift state   -> B: dp
      scalars            replicated

    When B < dp size (long_500k has B=1), B falls back to replicated and the
    big sequence dim picks up (data, model) combined.
    """
    dp = _dp(mesh, cfg)
    model = ("model" if "model" in mesh.axis_names
             and not getattr(cfg, "zero3", False) else None)

    def spec(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd == 0 or max(shape) == 1 and nd <= 1:
            return P()
        name = _leaf_name(path)
        if nd == 5:   # [L, B, S, KH, HD] kv cache or [L, B, H, K, V] state
            L, b, s, h, d = shape
            bspec = _maybe(mesh, b, dp)
            if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v",
                        "attn_k", "attn_v"):
                if bspec is None:
                    # Batch too small to shard (long_500k, B=1): shard the
                    # sequence over the model axis.  Spreading S over
                    # (data x model) doesn't help: the per-step cache write
                    # (dynamic_update_slice at `length`) makes GSPMD reshard
                    # to this same model-only layout internally anyway
                    # (measured: identical footprint), so pin it explicitly.
                    return P(None, None, _maybe(mesh, s, model), None, None)
                return P(None, bspec, _maybe(mesh, s, model), None, None)
            # recurrent state [L, B, H, K, V]
            return P(None, bspec, _maybe(mesh, s, model), None, None)
        if nd == 4:   # [L, B, H, P*N...] / [L, B, K-1, C] conv
            L, b, a, c = shape
            return P(None, _maybe(mesh, b, dp), None,
                     _maybe(mesh, c, model))
        if nd == 3:   # [B, S, D] enc_out / [L, B, D] shifts
            a, b, c = shape
            if name == "enc_out":
                return P(_maybe(mesh, a, dp), None, None)
            return P(None, _maybe(mesh, b, dp), None)
        if nd == 2:
            return P(_maybe(mesh, shape[0], dp), None)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


# -----------------------------------------------------------------------------
# fleet control plane (camera-axis data parallelism)
# -----------------------------------------------------------------------------


def fleet_mesh(devices=None) -> Mesh:
    """One-axis ``("cams",)`` mesh for the fleet control plane.

    ``devices`` is a ``Mesh`` (used as given -- must carry a ``cams`` axis),
    an int (first k host devices; on CPU CI, k > 1 needs
    ``XLA_FLAGS=--xla_force_host_platform_device_count=k`` set before jax
    import), an explicit device sequence, or None (all devices).
    """
    if isinstance(devices, Mesh):
        if "cams" not in devices.axis_names:
            raise ValueError("fleet mesh needs a 'cams' axis, got "
                             f"{devices.axis_names}")
        return devices
    if devices is None:
        devs = jax.devices()
    elif isinstance(devices, int):
        avail = jax.devices()
        if not 1 <= devices <= len(avail):
            raise ValueError(
                f"mesh wants {devices} devices but only {len(avail)} are "
                "available (set XLA_FLAGS=--xla_force_host_platform_"
                "device_count=N before importing jax)")
        devs = avail[:devices]
    else:
        devs = list(devices)
    return Mesh(np.asarray(devs), ("cams",))


def padded_lane_count(n: int, mesh: Mesh | None) -> int:
    """Smallest lane count >= n divisible by the mesh's device count."""
    if mesh is None:
        return n
    m = int(mesh.devices.size)
    return -(-n // m) * m


def fleet_sharding(mesh: Mesh) -> NamedSharding:
    """The lane-axis sharding of every fleet tick operand: dim 0 split over
    ``cams``, everything else replicated (prefix spec covers any rank).

    Pinning this as the jitted tick's in/out shardings keeps the compile
    cache at ONE variant: without it, the first dispatch sees host-committed
    arrays while later dispatches feed back the sharded outputs -- two
    distinct input layouts, two compiles.
    """
    return NamedSharding(mesh, P("cams"))


def shard_fleet_tick(fn, mesh: Mesh):
    """Partition a per-lane fleet tick over the ``cams`` axis.

    Every argument and output leaf carries the lane axis at dim 0 (the
    caller pads lanes to a device multiple with ``padded_lane_count``), so
    a prefix ``P("cams")`` spec covers the whole pytree of each.  Lanes are
    fully independent -- no collectives -- so sharding is pure data
    parallelism and cannot change numerics.
    """
    from jax.experimental.shard_map import shard_map
    spec = P("cams")
    return shard_map(fn, mesh=mesh, in_specs=(spec,) * 8, out_specs=spec,
                     check_rep=False)


def logical_to_sharding(specs: Any, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs, is_leaf=lambda x: isinstance(x, P))


def shardings_for(tree_shape: Any, specs: Any, mesh: Mesh):
    return logical_to_sharding(specs, mesh)
