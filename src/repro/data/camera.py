"""Synthetic multi-camera scene generator with ground truth.

Stand-in for the paper's JAAD / DukeMTMC footage (Section 2.1): each camera
produces a stream of uint8 frames containing moving rectangular "pedestrians"
over a textured static background, with per-frame ground-truth bounding boxes.

Scene dynamics follow the paper's clustering: simple / medium / complex map to
increasing object counts and texture energy, which mechanistically yields the
paper's size ordering (complex frames deflate-compress worse, i.e. are larger
on the wire) and its accuracy ordering (complex scenes lose more F1 under
quality degradation because small/overlapping objects blur together).

Deterministic given (camera_id, seed): every benchmark is reproducible.
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np

__all__ = ["SceneDynamics", "CameraConfig", "SyntheticCamera", "DYNAMICS"]

DYNAMICS = ("simple", "medium", "complex")


@dataclasses.dataclass(frozen=True)
class SceneDynamics:
    name: str
    num_objects: tuple[int, int]      # inclusive range
    obj_size: tuple[int, int]         # min/max box side, pixels
    texture_amp: float                # background texture energy
    speed: float                      # px/frame


_DYNAMICS = {
    "simple": SceneDynamics("simple", (1, 2), (14, 26), 6.0, 1.5),
    "medium": SceneDynamics("medium", (3, 5), (12, 22), 12.0, 2.5),
    "complex": SceneDynamics("complex", (5, 8), (10, 20), 18.0, 3.5),
}


@dataclasses.dataclass(frozen=True)
class CameraConfig:
    camera_id: str = "cam0"
    height: int = 144
    width: int = 256
    channels: int = 3
    dynamics: str = "complex"
    fps: float = 5.0
    noise_sigma: float = 2.0
    seed: int = 0


class SyntheticCamera:
    """Streaming frame source.  ``next_frame()`` -> (timestamp, frame, boxes)."""

    def __init__(self, config: CameraConfig):
        self.config = config
        self.dyn = _DYNAMICS[config.dynamics]
        # stable across processes (Python's str hash is salted)
        cam_hash = zlib.crc32(config.camera_id.encode()) & 0x7FFFFFFF
        self._rng = np.random.default_rng(
            np.random.SeedSequence([config.seed, cam_hash]))
        self._t = 0
        self.background = self._make_background()
        self._spawn_movers()

    def _spawn_movers(self) -> None:
        """Roll a mover population for the CURRENT dynamics regime."""
        config = self.config
        n = int(self._rng.integers(self.dyn.num_objects[0], self.dyn.num_objects[1] + 1))
        h, w = config.height, config.width
        self._pos = self._rng.uniform([0, 0], [h - 1, w - 1], size=(n, 2))
        ang = self._rng.uniform(0, 2 * np.pi, size=n)
        self._vel = np.stack([np.sin(ang), np.cos(ang)], -1) * self.dyn.speed
        self._sizes = self._rng.integers(self.dyn.obj_size[0], self.dyn.obj_size[1] + 1,
                                         size=(n, 2))
        # pedestrians are taller than wide
        self._sizes[:, 0] = (self._sizes[:, 0] * 1.8).astype(self._sizes.dtype)
        self._shades = self._rng.integers(150, 255, size=(n, config.channels))

    def set_dynamics(self, dynamics: str) -> None:
        """Mid-stream scene regime change (workload shift): the mover
        population re-rolls under the new regime while the background, the
        frame clock, and the rng stream all carry over -- the scripted
        analogue of a quiet corridor turning into a rush-hour crowd, which
        is exactly the shift that makes characterization tables stale
        (scenario event ``SceneShift``).  Deterministic given the camera's
        seed and the stream position at which it is called."""
        self.dyn = _DYNAMICS[dynamics]
        self.config = dataclasses.replace(self.config, dynamics=dynamics)
        self._spawn_movers()

    # -- scene pieces -----------------------------------------------------------
    def _make_background(self) -> np.ndarray:
        c = self.config
        rng = self._rng
        # smooth low-frequency texture: sum of a few 2-D cosines + mild noise
        yy, xx = np.mgrid[0:c.height, 0:c.width].astype(np.float32)
        bg = np.full((c.height, c.width), 90.0, np.float32)
        for _ in range(4):
            fy, fx = rng.uniform(0.005, 0.05, 2)
            ph = rng.uniform(0, 2 * np.pi)
            bg += self.dyn.texture_amp * np.cos(2 * np.pi * (fy * yy + fx * xx) + ph)
        bg += rng.normal(0, self.dyn.texture_amp * 0.3, bg.shape)
        bg = np.clip(bg, 0, 255)
        if c.channels == 1:
            return bg.astype(np.uint8)
        chan = [np.clip(bg * s, 0, 255) for s in (1.0, 0.96, 0.92)[: c.channels]]
        return np.stack(chan, -1).astype(np.uint8)

    def _step_objects(self) -> None:
        h, w = self.config.height, self.config.width
        self._pos += self._vel
        for d, lim in ((0, h - 1), (1, w - 1)):
            low = self._pos[:, d] < 0
            high = self._pos[:, d] > lim
            self._vel[low | high, d] *= -1
            self._pos[low, d] *= -1
            self._pos[high, d] = 2 * lim - self._pos[high, d]

    # -- the stream ---------------------------------------------------------------
    def next_frame(self) -> tuple[float, np.ndarray, np.ndarray]:
        """Returns (timestamp_s, uint8 frame [H,W,C], boxes [N,4] y0x0y1x1)."""
        c = self.config
        self._step_objects()
        frame = self.background.astype(np.float32).copy()
        boxes = []
        h, w = c.height, c.width
        for (py, px), (sy, sx), shade in zip(self._pos, self._sizes, self._shades):
            y0 = int(np.clip(py - sy / 2, 0, h - 1)); y1 = int(np.clip(py + sy / 2, 1, h))
            x0 = int(np.clip(px - sx / 2, 0, w - 1)); x1 = int(np.clip(px + sx / 2, 1, w))
            if y1 - y0 < 2 or x1 - x0 < 2:
                continue
            if c.channels == 1:
                frame[y0:y1, x0:x1] = shade[0]
            else:
                frame[y0:y1, x0:x1, :] = shade[None, None, :]
            boxes.append((y0, x0, y1, x1))
        frame += self._rng.normal(0, c.noise_sigma, frame.shape)
        frame = np.clip(frame, 0, 255).astype(np.uint8)
        ts = self._t / c.fps
        self._t += 1
        return ts, frame, np.asarray(boxes, np.float32).reshape(-1, 4)

    def stream(self, n: int):
        for _ in range(n):
            yield self.next_frame()
