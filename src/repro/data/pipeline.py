"""Host-side data pipeline: token streams, prefetch, straggler mitigation.

The training loop must never wait on the host: a ``Prefetcher`` keeps a
bounded queue of ready batches filled by a producer thread, and a
``BackupFetcher`` applies the classic tail-at-scale mitigation -- if a fetch
exceeds a deadline derived from the observed p95 fetch time, a backup fetch
is issued and whichever finishes first wins (duplicates discarded).  This is
the same timeout-driven fault philosophy the paper uses for its brokers
(Section 4.4), applied to input stragglers.

``TokenStream`` generates deterministic synthetic LM batches (zipfian token
ids) -- the stand-in corpus for the end-to-end example; ``CameraBatcher``
adapts Mez subscriptions (DeliveredFrame streams) into model batches for the
vision serving path.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator

import numpy as np

__all__ = ["TokenStream", "Prefetcher", "BackupFetcher", "CameraBatcher"]


class TokenStream:
    """Deterministic synthetic LM batches: zipfian unigrams + a repeated-
    ngram structure so a real model can actually reduce loss on it."""

    def __init__(self, vocab_size: int, batch: int, seq: int, *,
                 seed: int = 0, ngram: int = 8):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq
        self.ngram = ngram
        self._rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        # a small bank of "phrases" the stream repeats (learnable structure)
        self._phrases = self._rng.integers(
            0, vocab_size, size=(64, ngram)).astype(np.int32)

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> dict:
        toks = self._rng.choice(self.vocab, size=(self.batch, self.seq + 1),
                                p=self._probs).astype(np.int32)
        # overwrite random windows with phrases (predictable continuations)
        n_spans = (self.seq // self.ngram) // 2
        for b in range(self.batch):
            starts = self._rng.integers(0, self.seq - self.ngram,
                                        size=n_spans)
            ids = self._rng.integers(0, len(self._phrases), size=n_spans)
            for s, i in zip(starts, ids):
                toks[b, s : s + self.ngram] = self._phrases[i]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Bounded-depth background prefetch of an iterator."""

    _SENTINEL = object()

    def __init__(self, it: Iterator, *, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: BaseException | None = None

        def run():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:  # noqa: BLE001
                self._err = e
            finally:
                self._q.put(self._SENTINEL)

        self._t = threading.Thread(target=run, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


class BackupFetcher:
    """Tail-at-scale straggler mitigation for fetch functions.

    Tracks fetch latencies; when a fetch exceeds ``hedge_factor x p95``, a
    backup fetch is launched and the first result wins.  ``fetch_fn(i)`` must
    be idempotent (same i -> same batch), so duplicates are harmless --
    at-most-once delivery to the consumer is enforced here.
    """

    def __init__(self, fetch_fn: Callable[[int], object], *,
                 hedge_factor: float = 3.0, min_history: int = 8):
        self.fetch_fn = fetch_fn
        self.hedge_factor = hedge_factor
        self.min_history = min_history
        self._lat: list[float] = []
        self.hedges_issued = 0
        self.hedges_won = 0

    def _deadline(self) -> float | None:
        if len(self._lat) < self.min_history:
            return None
        return float(np.percentile(self._lat, 95)) * self.hedge_factor

    def fetch(self, i: int):
        deadline = self._deadline()
        result: queue.Queue = queue.Queue()

        def worker(tag: str):
            t0 = time.monotonic()
            out = self.fetch_fn(i)
            result.put((tag, out, time.monotonic() - t0))

        t_primary = threading.Thread(target=worker, args=("primary",),
                                     daemon=True)
        t0 = time.monotonic()
        t_primary.start()
        hedged = False
        while True:
            timeout = None
            if deadline is not None and not hedged:
                timeout = max(1e-3, deadline - (time.monotonic() - t0))
            try:
                tag, out, dt = result.get(timeout=timeout)
                break
            except queue.Empty:
                # primary exceeded the straggler deadline: hedge
                hedged = True
                self.hedges_issued += 1
                threading.Thread(target=worker, args=("backup",),
                                 daemon=True).start()
        if tag == "backup":
            self.hedges_won += 1
        self._lat.append(time.monotonic() - t0)
        self._lat = self._lat[-256:]
        return out


class CameraBatcher:
    """Adapts Mez `DeliveredFrame` streams into fixed-size model batches
    (dropped frames are skipped -- at-most-once semantics end to end).

    Consumes either single v1 frames (``push``) or whole v2 ``FrameBatch``
    units (``push_batch``) -- the fan-in merge already happened broker-side,
    so batching here is just accumulation to the model's batch size.
    """

    def __init__(self, batch: int):
        self.batch = batch
        self._buf: list[np.ndarray] = []

    def push_batch(self, frame_batch) -> list[np.ndarray]:
        """Feed one ``FrameBatch``; returns every model batch it completed
        (possibly none, possibly several)."""
        out = []
        for d in frame_batch:
            b = self.push(d)
            if b is not None:
                out.append(b)
        return out

    def push(self, delivered) -> np.ndarray | None:
        if delivered.frame is None:
            return None
        self._buf.append(np.asarray(delivered.frame, dtype=np.float32))
        if len(self._buf) >= self.batch:
            # pad ragged knob-resized frames to the max shape in the batch
            hmax = max(f.shape[0] for f in self._buf)
            wmax = max(f.shape[1] for f in self._buf)
            out = np.zeros((self.batch, hmax, wmax) + self._buf[0].shape[2:],
                           np.float32)
            for i, f in enumerate(self._buf[: self.batch]):
                out[i, : f.shape[0], : f.shape[1]] = f
            self._buf = self._buf[self.batch:]
            return out
        return None
