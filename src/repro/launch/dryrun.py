import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without real hardware:
``jax.jit(step, in_shardings, out_shardings).lower(*structs).compile()``
must succeed on the single-pod (16, 16) mesh AND the 2-pod (2, 16, 16) mesh
for every assigned architecture x input shape.  Records per cell:

  * memory_analysis(): per-device argument/output/temp bytes (proves it fits)
  * cost_analysis(): HLO FLOPs + bytes accessed (roofline numerator)
  * collective bytes by op kind, parsed from the compiled HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
     collective-permute)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--out results/dryrun]

Results are written incrementally to <out>/<arch>__<shape>__<mesh>.json so
interrupted runs resume cheaply (--force recomputes).
"""

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCHS, cells_for, get_config, skipped_cells_for
from repro.configs.base import SHAPE_CELLS
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (build_prefill_step, build_serve_step,
                                build_train_step)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op, by kind.

    HLO lines look like:
      %ar = bf16[256,896]{1,0} all-reduce(bf16[256,896]{1,0} %x), ...
    The output shape equals the moved payload for all-reduce / all-to-all /
    collective-permute; for all-gather it's the gathered (post) size and for
    reduce-scatter the pre-reduce operand is the moved payload -- we record
    output bytes per kind and apply per-kind wire factors in the roofline.
    """
    out = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3:]
        for kind in _COLLECTIVES:
            # match the op name right after the result type
            m = re.match(r"^(\([^)]*\)|[\w\[\],{}:#\s]*?)\s*" + kind + r"(-start|-done)?\(",
                         rhs)
            if m:
                if m.group(2) == "-done":
                    break  # counted at -start
                out[kind]["bytes"] += _shape_bytes(m.group(1))
                out[kind]["count"] += 1
                break
    return out


def run_cell(arch: str, shape: str, mesh_kind: str) -> dict:
    cfg = get_config(arch)
    cell = SHAPE_CELLS[shape]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    if cell.kind == "train":
        bundle = build_train_step(cfg, cell, mesh)
    elif cell.kind == "prefill":
        bundle = build_prefill_step(cfg, cell, mesh)
    else:
        bundle = build_serve_step(cfg, cell, mesh)

    with mesh:
        # mezlint: disable=MZ02 -- one-shot driver: this cell's lower/compile cost IS the measurement
        jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.arg_structs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    result = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "devices": int(np.prod(list(mesh.shape.values()))),
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "transcendentals": cost.get("transcendentals"),
        },
        "collectives": coll,
        "hlo_lines": hlo.count("\n"),
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCHS)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        shapes = cells_for(arch)
        if args.shape:
            shapes = [args.shape] if args.shape in shapes else []
        for skip, why in skipped_cells_for(arch).items():
            if args.shape in (None, skip):
                print(f"SKIP {arch} x {skip}: {why}")
                n_skip += 1
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch}__{shape}__{mesh_kind}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    with open(path) as fh:
                        prev = json.load(fh)
                    if prev.get("ok"):
                        print(f"CACHED {tag}")
                        n_ok += 1
                        continue
                print(f"RUN {tag} ...", flush=True)
                try:
                    result = run_cell(arch, shape, mesh_kind)
                    n_ok += 1
                except Exception as e:  # noqa: BLE001 - report, keep going
                    result = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                              "ok": False, "error": repr(e),
                              "traceback": traceback.format_exc()[-4000:]}
                    n_fail += 1
                    print(f"FAIL {tag}: {e!r}")
                with open(path, "w") as fh:
                    json.dump(result, fh, indent=1)
                if result.get("ok"):
                    mem = result["memory"]
                    per_dev = (mem["argument_bytes"] or 0) + (mem["temp_bytes"] or 0)
                    print(f"  ok: compile={result['compile_s']}s "
                          f"flops={result['cost']['flops']:.3e} "
                          f"args+temp/dev={per_dev/1e9:.2f}GB "
                          f"coll={ {k: round(v['bytes']/1e6,1) for k, v in result['collectives'].items() if v['bytes']} }",
                          flush=True)
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
