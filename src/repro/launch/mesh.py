"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS before any jax import to get 512 host devices.

Production topology (TPU v5e): 16x16 = 256 chips per pod; multi-pod adds a
leading "pod" axis (2 pods = 512 chips).  The pod axis composes with "data"
for DP/FSDP; "model" is the intra-pod TP/SP axis (ICI-only collectives).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "HardwareSpec", "V5E"]

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants (per chip)."""
    name: str
    peak_flops_bf16: float     # FLOP/s
    hbm_bandwidth: float       # bytes/s
    ici_bandwidth: float       # bytes/s per link
    hbm_bytes: float


V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    hbm_bytes=16e9,
)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests/benchmarks (e.g. (1, 1) on one CPU device)."""
    return jax.make_mesh(shape, axes)
