"""Step functions lowered by the dry-run / executed by train.py & serve.py.

  train_step(params, opt_state, batch)        -> (params, opt_state, metrics)
  prefill_step(params, batch, cache)          -> (logits, cache)
  serve_step(params, tokens, cache)           -> (logits, cache)

Each builder closes over the ModelConfig and returns a pure function plus the
(in_shardings, out_shardings) trees for jax.jit, derived from
repro.sharding.partition.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.models.registry import (DECODE_SLACK, Model, build_model,
                                   cache_spec, input_specs)
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.sharding import partition
from repro.sharding import api as shard_api

__all__ = ["build_train_step", "build_prefill_step", "build_serve_step",
           "StepBundle"]

import dataclasses


@dataclasses.dataclass
class StepBundle:
    """Everything the launcher/dry-run needs for one (arch, cell) step."""
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    arg_structs: tuple       # ShapeDtypeStructs to lower with
    donate_argnums: tuple = ()


def _named(specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def _param_structs(model: Model):
    return jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))


def build_train_step(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                     opt: AdamWConfig | None = None,
                     grad_compress: Callable | None = None) -> StepBundle:
    """Full training step: loss -> grads -> (optional cross-pod compressed
    reduction) -> AdamW.  ``grad_compress`` hooks the Mez approximate
    collective (core/approx_comm) into the gradient path."""
    shard_api.activate(mesh, zero3=cfg.zero3)
    model = build_model(cfg)
    opt = opt or AdamWConfig()

    # Adaptive microbatch count: each microbatch must still shard evenly over
    # the DP axes (B/M % dp == 0), otherwise GSPMD replicates activations.
    import numpy as np
    dp_names = ("pod", "data", "model") if cfg.zero3 else ("pod", "data")
    dp_size = int(np.prod([mesh.shape[a] for a in dp_names
                           if a in mesh.axis_names]))
    microbatches = max(1, cfg.train_microbatches)
    while microbatches > 1 and (
            cell.global_batch % microbatches != 0
            or (cell.global_batch // microbatches) % dp_size != 0):
        microbatches -= 1

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        else:
            # gradient accumulation: scan over microbatches (activation
            # memory ~ 1/M; grads accumulate in fp32, sharded like params)
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]),
                batch)

            def one(carry, mbatch):
                loss_sum, acc = carry
                l, g = jax.value_and_grad(model.loss_fn)(params, mbatch)
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return (loss_sum + l, acc), None

            acc0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(
                one, (jnp.zeros((), jnp.float32), acc0), mb)
            loss = loss_sum / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        if grad_compress is not None:
            grads = grad_compress(grads)
        new_params, new_opt = adamw_update(opt, params, grads, opt_state)
        metrics = {"loss": loss.astype(jnp.float32)}
        return new_params, new_opt, metrics

    p_struct = _param_structs(model)
    o_struct = jax.eval_shape(lambda: init_opt_state(p_struct))
    b_struct = input_specs(cfg, cell)["batch"]

    p_specs = partition.param_specs(p_struct, cfg, mesh)
    o_specs = {"m": p_specs, "v": p_specs, "count": P()}
    b_specs = partition.batch_specs(b_struct, cfg, mesh, cell)

    in_sh = (_named(p_specs, mesh), _named(o_specs, mesh), _named(b_specs, mesh))
    out_sh = (_named(p_specs, mesh), _named(o_specs, mesh),
              _named({"loss": P()}, mesh))
    return StepBundle(fn=train_step, in_shardings=in_sh, out_shardings=out_sh,
                      arg_structs=(p_struct, o_struct, b_struct),
                      donate_argnums=(0, 1))


def build_prefill_step(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh
                       ) -> StepBundle:
    shard_api.activate(mesh, zero3=cfg.zero3)
    model = build_model(cfg)

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    p_struct = _param_structs(model)
    b_struct = input_specs(cfg, cell)["batch"]
    c_struct = cache_spec(cfg, cell.global_batch, cell.seq_len)

    p_specs = partition.param_specs(p_struct, cfg, mesh)
    b_specs = partition.batch_specs(b_struct, cfg, mesh, cell)
    c_specs = partition.cache_specs(c_struct, cfg, mesh, cell)
    logits_struct, cache_out = jax.eval_shape(prefill_step, p_struct, b_struct,
                                              c_struct)
    l_spec = _logits_spec(logits_struct, cfg, mesh)

    in_sh = (_named(p_specs, mesh), _named(b_specs, mesh), _named(c_specs, mesh))
    out_sh = (_named(l_spec, mesh), _named(c_specs, mesh))
    return StepBundle(fn=prefill_step, in_shardings=in_sh, out_shardings=out_sh,
                      arg_structs=(p_struct, b_struct, c_struct),
                      donate_argnums=(2,))


def build_serve_step(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh
                     ) -> StepBundle:
    shard_api.activate(mesh, zero3=cfg.zero3)
    model = build_model(cfg)

    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    p_struct = _param_structs(model)
    specs = input_specs(cfg, cell)
    t_struct, c_struct = specs["tokens"], specs["cache"]

    p_specs = partition.param_specs(p_struct, cfg, mesh)
    t_specs = partition.batch_specs({"tokens": t_struct}, cfg, mesh,
                                    cell)["tokens"]
    c_specs = partition.cache_specs(c_struct, cfg, mesh, cell)
    logits_struct, _ = jax.eval_shape(serve_step, p_struct, t_struct, c_struct)
    l_spec = _logits_spec(logits_struct, cfg, mesh)

    in_sh = (_named(p_specs, mesh), _named(t_specs, mesh), _named(c_specs, mesh))
    out_sh = (_named(l_spec, mesh), _named(c_specs, mesh))
    return StepBundle(fn=serve_step, in_shardings=in_sh, out_shardings=out_sh,
                      arg_structs=(p_struct, t_struct, c_struct),
                      donate_argnums=(2,))


def _logits_spec(logits_struct, cfg: ModelConfig, mesh: Mesh):
    b, s, v = logits_struct.shape
    names = ("pod", "data", "model") if cfg.zero3 else ("pod", "data")
    dp = tuple(n for n in names if n in mesh.axis_names)
    import numpy as np
    bspec = dp if b % int(np.prod([mesh.shape[a] for a in dp])) == 0 else None
    vspec = ("model" if not cfg.zero3 and v % mesh.shape["model"] == 0
             else None)
    return P(bspec, None, vspec)
