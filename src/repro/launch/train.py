"""Fault-tolerant training driver.

Runs the jitted ``train_step`` under a supervisor loop implementing the Mez
fault philosophy (paper Section 4.4) on the training plane:

  * detection by timeout on the step itself (piggybacked on real traffic --
    no separate heartbeat): a watchdog marks the step dead if it exceeds
    ``step_timeout`` (here: simulated failures via --inject-failure),
  * recovery by restore-from-checkpoint: CRC-validated, torn checkpoints
    skipped automatically (Checkpointer.latest_valid_step),
  * elastic re-admission: the checkpoint format is mesh-independent, so a
    restart may use a different device count / mesh shape (--elastic demo
    restores onto a reshaped mesh),
  * async checkpointing off the critical path every --checkpoint-every steps.

On this CPU container it trains REDUCED configs for real (examples use it);
the full configs go through launch/dryrun.py instead.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --steps 50 \
      --batch 8 --seq 128 --reduced --checkpoint-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.core.approx_comm import make_grad_compressor
from repro.data.pipeline import Prefetcher, TokenStream
from repro.launch.steps import build_train_step
from repro.models.registry import build_model, make_batch
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.sharding import partition


class StepWatchdog:
    """Timeout-based failure detection for the training step."""

    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s
        self.failures = 0

    def run(self, fn, *args):
        t0 = time.monotonic()
        out = fn(*args)
        out = jax.block_until_ready(out)
        if time.monotonic() - t0 > self.timeout_s:
            self.failures += 1
            raise TimeoutError(
                f"step exceeded {self.timeout_s}s (straggler/failed worker)")
        return out


def train(arch: str, *, steps: int = 50, batch: int = 8, seq: int = 128,
          reduced: bool = True, checkpoint_dir: str | None = None,
          checkpoint_every: int = 20, restore: bool = False,
          grad_bits: int = 16, inject_failure_at: int = -1,
          step_timeout: float = 120.0, mesh_shape: tuple = None,
          seed: int = 0, log_every: int = 10) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, train_microbatches=1)
    n_dev = len(jax.devices())
    if mesh_shape is None:
        mesh_shape, axes = (1, n_dev), ("data", "model")
    else:
        axes = ("data", "model") if len(mesh_shape) == 2 else (
            "pod", "data", "model")
    mesh = jax.make_mesh(mesh_shape, axes)
    cell = ShapeCell("custom", seq, batch, "train")

    compressor = (make_grad_compressor(grad_bits, min_size=1024)
                  if grad_bits < 16 else None)
    bundle = build_train_step(cfg, cell, mesh, AdamWConfig(),
                              grad_compress=compressor)
    model = build_model(cfg)

    with mesh:
        # mezlint: disable=MZ02 -- one wrapper per training run, reused across all steps
        step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                          out_shardings=bundle.out_shardings,
                          donate_argnums=bundle.donate_argnums)
        params = model.init_params(jax.random.PRNGKey(seed))
        opt_state = init_opt_state(params)

        ckpt = Checkpointer(checkpoint_dir) if checkpoint_dir else None
        start_step = 0
        if ckpt and restore:
            latest = ckpt.latest_valid_step()
            if latest is not None:
                p_specs = partition.param_specs(
                    jax.eval_shape(lambda: model.init_params(
                        jax.random.PRNGKey(0))), cfg, mesh)
                sh = jax.tree_util.tree_map(
                    lambda s: jax.sharding.NamedSharding(mesh, s), p_specs,
                    is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec))
                params, start_step = ckpt.restore(params, shardings=sh)
                opt_state, _ = ckpt.restore(opt_state, step=start_step) \
                    if False else (opt_state, start_step)
                print(f"[train] restored params from step {start_step}")

        stream = Prefetcher(
            iter(TokenStream(cfg.vocab_size, batch, seq, seed=seed)), depth=2)
        watchdog = StepWatchdog(step_timeout)
        losses = []
        t_start = time.time()
        step = start_step
        while step < steps:
            raw = next(stream)
            b = {"tokens": jnp.asarray(raw["tokens"]),
                 "labels": jnp.asarray(raw["labels"])}
            if cfg.family == "vlm":
                b["patch_embeds"] = jnp.zeros(
                    (batch, cfg.frontend_tokens, cfg.d_model),
                    jnp.float32)
            if cfg.family == "audio":
                b = {"embeds": jnp.asarray(
                        np.random.default_rng(step).normal(
                            0, 0.02, (batch, seq, cfg.d_model))
                        .astype(np.float32)),
                     "tokens": b["tokens"], "labels": b["labels"]}
            try:
                if step == inject_failure_at:
                    # simulated node failure mid-run
                    raise TimeoutError("injected node failure")
                params, opt_state, metrics = watchdog.run(
                    step_fn, params, opt_state, b)
            except TimeoutError as e:
                print(f"[train] step {step} FAILED ({e}); recovering...")
                if ckpt is None:
                    raise
                latest = ckpt.latest_valid_step()
                if latest is None:
                    print("[train] no checkpoint; restarting from init")
                    params = model.init_params(jax.random.PRNGKey(seed))
                    opt_state = init_opt_state(params)
                    step = 0
                else:
                    params, step = ckpt.restore(params)
                    opt_state = init_opt_state(params)
                    print(f"[train] resumed from checkpoint step {step}")
                inject_failure_at = -1   # don't loop the injection
                continue
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % log_every == 0:
                print(f"[train] step {step} loss {loss:.4f}")
            if ckpt and step > 0 and step % checkpoint_every == 0:
                ckpt.save(step, jax.tree_util.tree_map(np.asarray, params),
                          meta={"arch": arch, "loss": loss})
            step += 1
        wall = time.time() - t_start
    return {"losses": losses, "steps": step - start_step, "wall_s": wall,
            "final_loss": losses[-1] if losses else None,
            "first_loss": losses[0] if losses else None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--grad-bits", type=int, default=16, choices=[16, 8, 4])
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    args = ap.parse_args()
    out = train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
                reduced=args.reduced, checkpoint_dir=args.checkpoint_dir,
                checkpoint_every=args.checkpoint_every, restore=args.restore,
                grad_bits=args.grad_bits,
                inject_failure_at=args.inject_failure_at)
    print(f"[train] done: {out['steps']} steps, "
          f"loss {out['first_loss']:.4f} -> {out['final_loss']:.4f}, "
          f"{out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
