"""Batched serving driver: prefill + decode with a Mez-fed request stream.

Serves a (reduced-on-CPU) model with batched requests: prompts are prefilled
once, then decode steps generate tokens for the whole batch.  Demonstrates
the serving-side runtime the decode_* dry-run cells lower:

  * preallocated KV cache with slack, length-masked decode
  * per-step latency tracking (p50/p95) and tokens/sec
  * optional Mez ingestion: a camera topic is subscribed with
    (latency, accuracy) bounds and delivered frames are batched into
    patch embeddings for the VLM family (the end-to-end IoT-Edge loop).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.registry import DECODE_SLACK, build_model, make_batch

__all__ = ["serve"]


def serve(arch: str, *, batch: int = 4, prompt_len: int = 64, gen: int = 32,
          reduced: bool = True, seed: int = 0,
          temperature: float = 0.0) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init_params(key)

    pb = make_batch(cfg, batch, prompt_len, train=False, key=key)
    kw = {"enc_len": prompt_len} if cfg.family == "audio" else {}
    cache = model.init_cache(batch, prompt_len + gen + DECODE_SLACK, **kw)

    # mezlint: disable=MZ02 -- jitted once per serve process, reused every token
    prefill = jax.jit(model.prefill)
    # mezlint: disable=MZ02 -- same: one wrapper per process
    decode = jax.jit(model.decode_step, donate_argnums=(2,))

    t0 = time.monotonic()
    logits, cache = jax.block_until_ready(prefill(params, pb, cache))
    t_prefill = time.monotonic() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    lat = []
    out_tokens = [np.asarray(tok)]
    for i in range(gen):
        t0 = time.monotonic()
        logits, cache = jax.block_until_ready(decode(params, tok, cache))
        lat.append(time.monotonic() - t0)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    lat = np.asarray(lat)
    toks = np.concatenate(out_tokens, axis=1)
    assert int(toks.max()) < cfg.vocab_size, "padded-vocab token leaked"
    return {
        "prefill_s": t_prefill,
        "decode_p50_ms": float(np.percentile(lat, 50) * 1e3) if len(lat) else 0,
        "decode_p95_ms": float(np.percentile(lat, 95) * 1e3) if len(lat) else 0,
        "tokens_per_s": float(batch * len(lat) / lat.sum()) if len(lat) else 0,
        "tokens": toks,
        "cache_len": int(cache.length),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    out = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen=args.gen, temperature=args.temperature)
    print(f"[serve] prefill {out['prefill_s']*1e3:.1f} ms; decode p50 "
          f"{out['decode_p50_ms']:.2f} ms p95 {out['decode_p95_ms']:.2f} ms; "
          f"{out['tokens_per_s']:.1f} tok/s; cache_len={out['cache_len']}")


if __name__ == "__main__":
    main()
