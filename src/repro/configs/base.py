"""Config system: model configs, shape cells, dtype policies.

One ``ModelConfig`` dataclass covers every assigned architecture family
(dense / MoE / SSM / hybrid / VLM / enc-dec); family-specific fields are
ignored by families that don't use them.  Each arch file in this package
exports ``CONFIG`` (the exact published configuration) and the registry in
``repro.configs`` maps ``--arch`` ids to them.

``reduced()`` derives the smoke-test configuration (same family & wiring,
tiny dims) used by per-arch CPU tests; the full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeCell", "SHAPE_CELLS", "dtype_of"]

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
           "float16": jnp.float16}


def dtype_of(name: str):
    return _DTYPES[name]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # attention
    qk_norm: bool = False
    rope_theta: float = 1.0e4
    mrope_sections: tuple[int, int, int] | None = None   # qwen2-vl M-RoPE
    attention_impl: str = "chunked"  # naive | chunked | pallas
    attention_chunk: int = 512

    # MoE
    num_experts: int = 0
    num_experts_per_token: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba2 / zamba2 backbone)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4

    # hybrid (zamba2): a SHARED full-attention block applied every
    # ``shared_attn_period`` backbone layers, with per-invocation LoRA.
    shared_attn_period: int = 0
    lora_rank: int = 0

    # RWKV6
    rwkv_decay_lora: int = 64        # rank of the data-dependent decay MLP
    rwkv_mix_lora: int = 32          # rank of the token-shift mix MLPs

    # enc-dec (seamless)
    is_encoder_decoder: bool = False
    num_decoder_layers: int = 0

    # numerics / runtime
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    norm_type: str = "rms"           # rms | layer
    act: str = "swiglu"              # swiglu | gelu | relu_sq
    tie_embeddings: bool = False
    remat: str = "none"              # none | full | dots
    scan_layers: bool = True

    # sharding hints (consumed by repro.sharding.partition)
    fsdp: bool = True                # shard params over the data axis too
    moe_parallel: str = "ep"         # ep (experts over model) | tp
    # gradient accumulation: split the global batch into this many
    # microbatches per train step (activation memory ~ 1/M)
    train_microbatches: int = 1
    # Megatron-SP-style sequence parallelism: activations between blocks are
    # sharded over (model) on the SEQUENCE dim.  XLA then lowers the TP
    # all-reduces into reduce-scatter + all-gather pairs (half the wire
    # bytes) and per-device activation memory drops by the model-axis size.
    # Also the escape hatch for archs whose head counts don't divide the
    # model axis (phi3: 40H/10KV vs 16): attention runs context-parallel
    # (q sequence-sharded) instead of head-sharded-with-redundancy.
    sequence_parallel: bool = False
    # ZeRO-3 mode: NO tensor parallelism -- weights/optimizer shard over ALL
    # mesh axes (pod x data x model) on their d_model dim and the batch
    # shards over all axes too.  Collectives become per-layer weight
    # all-gathers + gradient reduce-scatters (no per-activation ARs).
    zero3: bool = False

    # modality frontend stub (vlm/audio): #stub-embedding positions
    frontend_tokens: int = 0

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the vocab dim always shards
        over the model axis (Megatron-style; padded logit columns are masked
        to -inf in the loss/serve paths)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic backbones only (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ModelConfig":
        """Smoke-test configuration: same family/wiring, tiny dims."""
        kv = max(1, min(self.num_kv_heads, 2))
        heads = max(kv * 2, 4)
        mrope = (2, 3, 3) if self.mrope_sections is not None else None  # half=8
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            mrope_sections=mrope,
            num_layers=min(self.num_layers, 2 * max(1, self.shared_attn_period)
                           if self.shared_attn_period else 2),
            num_decoder_layers=min(self.num_decoder_layers, 2),
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=96,
            vocab_size=512,
            num_experts=min(self.num_experts, 8) if self.num_experts else 0,
            num_experts_per_token=(min(self.num_experts_per_token, 2)
                                   if self.num_experts_per_token else 0),
            num_shared_experts=min(self.num_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else self.ssm_headdim,
            lora_rank=min(self.lora_rank, 4),
            rwkv_decay_lora=8,
            rwkv_mix_lora=4,
            attention_chunk=32,
            frontend_tokens=min(self.frontend_tokens, 16),
            param_dtype="float32",
            compute_dtype="float32",
        )

    # -- parameter counting (for MODEL_FLOPS = 6 N D in the roofline) ----------
    def param_count(self) -> int:
        """Analytic parameter count (embedding included; approximate for
        exotic families but consistent with the implementations here)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, kh, hd = self.num_heads, self.num_kv_heads, self.head_dim
        attn = d * h * hd + 2 * d * kh * hd + h * hd * d
        if self.act == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        per_layer_dense = attn + mlp + 2 * d
        if self.family == "moe":
            expert = 3 * d * f if self.act == "swiglu" else 2 * d * f
            moe = self.num_experts * expert + d * self.num_experts
            moe += self.num_shared_experts * expert
            per_layer = attn + moe + 2 * d
            total = self.num_layers * per_layer
        elif self.family == "ssm":       # rwkv6
            d_in = d
            tm = (4 * d * d_in          # r,k,v,g   (w is lora-only)
                  + d * hd              # output proj is d x d below; approx
                  )
            tm = 5 * d * d              # r,k,v,g,o
            tm += 5 * self.rwkv_mix_lora * 2 * d + self.rwkv_decay_lora * 2 * d
            cm = 2 * d * f
            per_layer = tm + cm + 2 * d
            total = self.num_layers * per_layer
        elif self.family == "hybrid":    # zamba2: mamba2 backbone + shared attn
            d_in = d * self.ssm_expand
            nheads = d_in // self.ssm_headdim
            mamba = (d * (2 * d_in + 2 * self.ssm_state + nheads)  # in_proj
                     + d_in * d                                    # out_proj
                     + self.ssm_conv * (d_in + 2 * self.ssm_state)
                     + 2 * nheads)                                 # A, D
            per_layer = mamba + 2 * d
            total = self.num_layers * per_layer
            n_inv = self.num_layers // max(1, self.shared_attn_period)
            shared = attn + mlp + 2 * d
            lora = n_inv * self.lora_rank * 2 * d * 4
            total += shared + lora
        else:
            total = self.num_layers * per_layer_dense
        if self.is_encoder_decoder:
            # decoder layers add cross attention
            total += self.num_decoder_layers * (per_layer_dense + attn + d)
        total += v * d                        # embeddings
        if not self.tie_embeddings:
            total += v * d                    # lm head
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        expert = 3 * d * f if self.act == "swiglu" else 2 * d * f
        inactive = (self.num_experts - self.num_experts_per_token) * expert
        return int(self.param_count() - self.num_layers * inactive)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}
