"""The paper's own deployment configuration: the Mez IoT-Edge testbed
(Section 2.1) -- 5 IoT camera nodes, one edge server, 802.11ac, plus the
controller targets used in Section 5 (100 ms latency, 95% normalized F1).

This is not an LM architecture; it parameterizes the Mez substrate
(channel, cameras, controller) for the reproduction benchmarks.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MezEdgeConfig:
    num_cameras: int = 5
    fps: float = 5.0
    distance_m: float = 6.0
    latency_target: float = 0.100        # seconds (p95)
    accuracy_target: float = 0.95        # normalized F1
    frame_height: int = 144
    frame_width: int = 256
    log_capacity: int = 2048             # ~7 min at 5 fps (paper Section 4.3)
    feedback_window: int = 8
    fetch_window: int = 2
    characterization_clip: int = 32
    seed: int = 7


CONFIG = MezEdgeConfig()
