"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206 -- enc-dec, multimodal.  [arXiv:2308.11596; hf]

Encoder-decoder: 24 encoder layers over STUB audio-frame embeddings
(``input_specs()`` provides [B, S_enc, d_model] precomputed frames) + 24
decoder layers (causal self-attn + cross-attn) over text tokens.  For the
LM shape cells, seq_len is split evenly between encoder frames and decoder
tokens for training; prefill lowers the encoder + decoder prefill; decode
lowers one decoder step against cached encoder output of length seq_len.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,               # encoder layers
    num_decoder_layers=24,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    rope_theta=10000.0,
    act="gelu",
    norm_type="layer",
    frontend_tokens=0,           # encoder input IS the stub embedding stream
    remat="full",
    train_microbatches=8,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
