"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Shape-cell applicability is encoded here too (long_500k only for
sub-quadratic backbones; see DESIGN.md Section 4).
"""

from __future__ import annotations

from repro.configs import (internlm2_1_8b, llama3_8b, moonshot_v1_16b_a3b,
                           phi3_5_moe_42b_a6_6b, phi3_medium_14b, qwen2_vl_72b,
                           qwen3_1_7b, rwkv6_1_6b, seamless_m4t_large_v2,
                           zamba2_7b)
from repro.configs.base import SHAPE_CELLS, ModelConfig, ShapeCell

ARCHS: dict[str, ModelConfig] = {
    "zamba2-7b": zamba2_7b.CONFIG,
    "phi3-medium-14b": phi3_medium_14b.CONFIG,
    "qwen3-1.7b": qwen3_1_7b.CONFIG,
    "internlm2-1.8b": internlm2_1_8b.CONFIG,
    "llama3-8b": llama3_8b.CONFIG,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi3_5_moe_42b_a6_6b.CONFIG,
    "rwkv6-1.6b": rwkv6_1_6b.CONFIG,
    "qwen2-vl-72b": qwen2_vl_72b.CONFIG,
    "seamless-m4t-large-v2": seamless_m4t_large_v2.CONFIG,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def cells_for(arch: str) -> list[str]:
    """The shape cells this arch runs (skips per DESIGN.md Section 4)."""
    cfg = get_config(arch)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        cells.append("long_500k")
    return cells


def skipped_cells_for(arch: str) -> dict[str, str]:
    cfg = get_config(arch)
    if not cfg.supports_long_context:
        return {"long_500k": "pure full-attention arch: 500k-token context "
                             "needs a sub-quadratic backbone (DESIGN.md §4)"}
    return {}


__all__ = ["ARCHS", "get_config", "cells_for", "skipped_cells_for",
           "ModelConfig", "ShapeCell", "SHAPE_CELLS"]
