"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 -- kimi/moonlight fine-grained experts.
[hf:moonshotai/Moonlight-16B-A3B; hf]

Moonlight follows the DeepSeek-V3 recipe: fine-grained experts (d_ff 1408)
with 2 shared experts alongside the 64 routed ones.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    num_experts_per_token=6,
    num_shared_experts=2,
    rope_theta=50000.0,
    act="swiglu",
    remat="full",
    train_microbatches=8,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    moe_parallel="ep",
)
