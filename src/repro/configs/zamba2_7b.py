"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 -- Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; unverified]

Implementation note: the backbone is 81 Mamba2 (SSD) layers; a single SHARED
full-attention+MLP block (32 heads, d_ff 14336) is invoked after every 6th
backbone layer (13 invocations), each invocation with its own LoRA adapters
on the attention projections -- the zamba2 weight-sharing scheme.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    shared_attn_period=6,
    lora_rank=64,
    rope_theta=10000.0,
    act="swiglu",
    remat="full",
    train_microbatches=8,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
