"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 -- RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    rope_theta=10000.0,
    act="swiglu",
    remat="full",
    train_microbatches=8,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
