"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536
-- Finch: data-dependent decay.  [arXiv:2404.05892; unverified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,            # wkv heads: d_model / 64
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    rwkv_decay_lora=64,
    rwkv_mix_lora=32,
    act="relu_sq",           # rwkv channel-mix uses squared relu
    norm_type="layer",
    remat="full",
    train_microbatches=4,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
