"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 -- M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

The vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings ([B, frontend_tokens, d_model]) which are
prepended to the text token embeddings; M-RoPE position ids (3 streams:
temporal/height/width) cover the combined sequence.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    mrope_sections=(16, 24, 24),     # t/h/w sections of head_dim/2 = 64
    rope_theta=1000000.0,
    act="swiglu",
    frontend_tokens=1024,            # stub patch embeddings per sample
    remat="full",
    train_microbatches=16,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
)
