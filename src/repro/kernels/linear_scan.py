"""Gated linear recurrence (RWKV6 wkv) Pallas kernel.

    y_t     = r_t . (state_{t-1} + diag(u) k_t v_t^T)
    state_t = diag(w_t) state_{t-1} + k_t v_t^T          state: [K, V]

Grid = (batch, heads); each program owns its head's [K, V] state in a VMEM
scratch accumulator (fp32) and walks the sequence in chunks of BT steps.
Within a chunk the cross-term is an exact [BT, BT] decay-weighted matmul
(all exponents <= 0 -- numerically safe), so the MXU does the heavy lifting
and the serial dependency only crosses chunk boundaries.  This is the TPU
adaptation of the RWKV CUDA kernel: instead of one-thread-per-channel serial
scans, chunk-parallel matmuls + a carried VMEM state.
"""

# mezlint: ref-parity: repro.kernels.ref.wkv_ref

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv_linear_scan"]


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_out_ref,
                state_ref, *, block_t: int, seq: int):
    kd = r_ref.shape[-1]
    state_ref[...] = jnp.zeros((kd, kd), jnp.float32)
    n_chunks = seq // block_t
    # strict lower-triangular mask: s < t
    mask = (jax.lax.broadcasted_iota(jnp.int32, (block_t, block_t), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (block_t, block_t), 1))
    u = u_ref[0, :].astype(jnp.float32)

    def chunk(ci, _):
        sl = (0, pl.ds(ci * block_t, block_t), 0, slice(None))
        r = pl.load(r_ref, sl).astype(jnp.float32)   # [BT, K] (ints squeeze)
        k = pl.load(k_ref, sl).astype(jnp.float32)
        v = pl.load(v_ref, sl).astype(jnp.float32)
        lw = pl.load(lw_ref, sl).astype(jnp.float32)

        cum = jnp.cumsum(lw, axis=0)                              # [BT, K]
        cum_tm1 = cum - lw
        state = state_ref[...]

        # incoming-state + diagonal bonus terms
        y = ((r * jnp.exp(cum_tm1)) @ state
             + jnp.einsum("tk,tk,tv->tv", r * u, k, v))
        # intra-chunk cross terms, exact per-channel decay:
        #   att[t,s] = sum_k r[t,k] k[s,k] exp(cum_{t-1}[t,k] - cum[s,k]), s<t
        att = jnp.einsum("tk,sk,tsk->ts", r, k,
                         jnp.exp(cum_tm1[:, None, :] - cum[None, :, :]))
        att = jnp.where(mask, att, 0.0)
        y = y + att @ v
        pl.store(y_ref, (0, pl.ds(ci * block_t, block_t), 0, slice(None)),
                 y.astype(y_ref.dtype))

        # state update: state = diag(exp(cum_end)) state + sum_s dec_s k_s v_s^T
        dec_end = jnp.exp(cum[-1][None, :] - cum)                 # [BT, K]
        state_ref[...] = (jnp.exp(cum[-1])[:, None] * state
                          + (k * dec_end).T @ v)
        return 0

    jax.lax.fori_loop(0, n_chunks, chunk, 0)
    s_out_ref[0, 0, :, :] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv_linear_scan(r: jax.Array, k: jax.Array, v: jax.Array,
                    logw: jax.Array, u: jax.Array, *, block_t: int = 64,
                    interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """r/k/v/logw: [B, S, H, K]; u: [H, K] -> (y [B,S,H,K], state [B,H,K,K])."""
    b, s, h, kd = r.shape
    block_t = min(block_t, s)
    assert s % block_t == 0, (s, block_t)
    grid = (b, h)
    y, state = pl.pallas_call(
        functools.partial(_wkv_kernel, block_t=block_t, seq=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s, 1, kd), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, s, 1, kd), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, s, 1, kd), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, s, 1, kd), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, kd), lambda bi, hi: (hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s, 1, kd), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, 1, kd, kd), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, kd), r.dtype),
            jax.ShapeDtypeStruct((b, h, kd, kd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kd, kd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
    return y, state
