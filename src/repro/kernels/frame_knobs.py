"""Fused frame-quality kernels: the knob grid as device-resident compute.

The paper measures knob processing at ~10 ms/frame on the camera node's ARM
CPU -- 20.5% of end-to-end latency (Fig. 16) -- and proposes offload as
future work.  Two kernels implement that offload, TPU-native:

``frame_knobs``      the original fixed-function kernel (2x2 mean pool +
                     box blur + knob5 change metric on gray planes), kept
                     for the streaming hot path and back-compat.

``frame_knob_grid``  the generalized characterization kernel: ONE pass over
                     a clip evaluates a whole batch of knob settings.  Per
                     (setting, frame) grid program it applies

  1. knob4 artifact removal: background subtraction against a per-call
     background frame (channel-mean |f - bg| > 18, cross dilation, keep
     movers or just their contours, zero the rest) -- the per-setting mode
     id selects off/movers/contours, and a per-frame enable flag lets the
     caller exempt the background/padding frames, so knob4 characterization
     no longer falls back to the minutes-long NumPy path,
  2. knob2 colorspace: BGR planes / gray / packed 4:2:0 YUV (Y on top,
     U|V below -- the exact wire layout of ``knobs._to_colorspace``),
  3. knob1 resolution: arbitrary-factor bilinear resize expressed as a pair
     of per-axis operator matrices (``Ry @ plane @ Rx^T``) so any
     ``RESOLUTION_SCALES`` entry runs on the MXU -- the old kernel's 2x2
     mean pool is the special case ``scale=0.5``,
  4. knob3 blur: every ``BLUR_KERNELS`` width as per-setting edge-clamped
     band matrices (``By[s] @ img @ Bx[s]^T``),
  5. knob5 change metric: fraction of pixels changed vs. the previous
     frame (``|f - prev| > pixel_delta`` after channel-mean),
  6. wire-size proxy features: per-payload horizontal/vertical byte-delta
     statistics (sum of log2(1+|d|), zero-delta count, |d|<=2 count) that
     ``core.grid_engine`` calibrates against zlib level-1 -- so deflate
     never runs on the characterization hot path.

Rounding matches the host pipeline stage for stage (uint8 round/clip after
colorspace, after resize, after blur), so the kernel is bit-exact against
``repro.kernels.ref.frame_knob_grid_ref`` and within one grey level of the
float64 NumPy path in ``knobs.transform_frame``.

Geometry (colorspace mode, output height/width) is static per call; the
settings batch dimension carries the per-setting blur operators, so one
``pallas_call`` evaluates ``[n_settings, n_frames]`` programs in a single
HBM pass over the clip.  ``core.grid_engine`` groups the full knob grid by
(resolution, colorspace) and issues one call per group.
"""

# mezlint: ref-parity: repro.kernels.ref.frame_knobs_ref
# mezlint: ref-parity: repro.kernels.ref.frame_knob_grid_ref

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["frame_knobs", "TransformPlan", "build_transform_plan",
           "frame_knob_grid", "resize_operator", "blur_operator",
           "proxy_features", "proxy_features_host", "N_PROXY_FEATURES",
           "ARTIFACT_THRESH"]

N_PROXY_FEATURES = 6   # (log2-sum, zero-count, <=2-count) x (dx, dy)
ARTIFACT_THRESH = 18.0  # knobs._artifact_removal's default mask threshold


# =============================================================================
# Original fixed-function kernel (unchanged semantics, back-compat)
# =============================================================================


def _knobs_kernel(f_ref, p_ref, o_ref, c_ref, *, blur_k: int,
                  pixel_delta: float):
    f = f_ref[0].astype(jnp.float32)                   # [H, W]
    prev = p_ref[0].astype(jnp.float32)
    h, w = f.shape

    # knob5 change metric
    changed = (jnp.abs(f - prev) > pixel_delta).astype(jnp.float32)
    c_ref[0] = changed.sum() / (h * w)

    # knob1: 2x2 mean pool
    pooled = f.reshape(h // 2, 2, w // 2, 2).mean(axis=(1, 3))

    # knob3: separable box blur with edge clamp (block-local)
    if blur_k > 1:
        pad = blur_k // 2
        acc = jnp.zeros_like(pooled)
        for dy in range(-pad, blur_k - pad):
            idx = jnp.clip(jnp.arange(h // 2) + dy, 0, h // 2 - 1)
            acc = acc + pooled[idx]
        pooled = acc / blur_k
        acc = jnp.zeros_like(pooled)
        for dx in range(-pad, blur_k - pad):
            idx = jnp.clip(jnp.arange(w // 2) + dx, 0, w // 2 - 1)
            acc = acc + pooled[:, idx]
        pooled = acc / blur_k

    o_ref[0] = pooled.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blur_k", "pixel_delta",
                                             "interpret"))
def frame_knobs(frames: jax.Array, prev: jax.Array, *, blur_k: int = 5,
                pixel_delta: float = 8.0, interpret: bool = False
                ) -> tuple[jax.Array, jax.Array]:
    """frames/prev: [N, H, W] (uint8 or float) -> (out [N, H/2, W/2] f32,
    changed_frac [N] f32)."""
    n, h, w = frames.shape
    assert h % 2 == 0 and w % 2 == 0, (h, w)
    return pl.pallas_call(
        functools.partial(_knobs_kernel, blur_k=blur_k,
                          pixel_delta=pixel_delta),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1, h // 2, w // 2), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n, h // 2, w // 2), jnp.float32),
                   jax.ShapeDtypeStruct((n,), jnp.float32)],
        interpret=interpret,
    )(frames, prev)


# =============================================================================
# Generalized knob-grid kernel
# =============================================================================

# Colorspace ids (static per call; match knobs.COLORSPACES order).
CS_BGR, CS_GRAY, CS_YUV420 = 0, 1, 2


def resize_operator(n_in: int, n_out: int, scale: float) -> np.ndarray:
    """One axis of ``knobs._resize_area`` as an [n_out, n_in] f32 operator.

    Row i carries the two bilinear taps of output sample i (edge-clamped,
    half-pixel-centre aligned).  ``scale >= 0.999`` yields the identity, so
    the full-resolution setting is exact pass-through.
    """
    if scale >= 0.999:
        return np.eye(n_in, dtype=np.float32)
    xs = np.clip((np.arange(n_out) + 0.5) / scale - 0.5, 0, n_in - 1)
    x0 = np.floor(xs).astype(np.int64)
    x1 = np.minimum(x0 + 1, n_in - 1)
    wx = (xs - x0).astype(np.float32)
    m = np.zeros((n_out, n_in), np.float32)
    np.add.at(m, (np.arange(n_out), x0), 1.0 - wx)
    np.add.at(m, (np.arange(n_out), x1), wx)
    return m


def blur_operator(n: int, k: int) -> np.ndarray:
    """``knobs._box_blur`` along one axis as an [n, n] edge-clamped band
    matrix (identity for k <= 1)."""
    m = np.zeros((n, n), np.float32)
    if k <= 1:
        np.fill_diagonal(m, 1.0)
        return m
    pad = k // 2
    rows = np.arange(n)
    for off in range(-pad, k - pad):
        np.add.at(m, (rows, np.clip(rows + off, 0, n - 1)),
                  np.float32(1.0 / k))
    return m


@dataclasses.dataclass(frozen=True)
class TransformPlan:
    """Device-ready operators for one (resolution, colorspace) group of the
    knob grid, batching every (artifact mode, blur width) pair of that group.

    The settings axis is artifact-major: setting ``a * len(blur_ks) + b``
    pairs artifact mode ``art_modes[a]`` with blur width ``blur_ks[b]``
    (``art_ids``/``blur_ids`` carry the per-setting values).  The plan fully
    determines output geometry, so one ``pallas_call`` (or its XLA twin in
    ``ref``) covers ``n_settings`` settings per frame.
    """
    cs: int                    # CS_BGR / CS_GRAY / CS_YUV420
    scale: float
    blur_ks: tuple[int, ...]
    art_modes: tuple[int, ...]  # knob4 modes batched (0=off, 1=movers, 2=contours)
    in_h: int                  # camera frame height
    in_w: int
    packed_h: int              # post-colorspace height (h + h//2 for yuv420)
    out_h: int                 # payload height after resize
    out_w: int
    n_planes: int              # 3 for bgr, 1 otherwise
    ry: np.ndarray             # [out_h, packed_h]
    rx: np.ndarray             # [out_w, in_w]
    bys: np.ndarray            # [S, out_h, out_h]
    bxs: np.ndarray            # [S, out_w, out_w]
    art_ids: np.ndarray        # [S] i32, per-setting artifact mode
    blur_ids: np.ndarray       # [S] i32, per-setting blur width

    @property
    def n_settings(self) -> int:
        return len(self.blur_ks) * len(self.art_modes)

    @property
    def with_artifact(self) -> bool:
        return bool((self.art_ids != 0).any())

    @property
    def payload_bytes(self) -> int:
        return self.n_planes * self.out_h * self.out_w


def build_transform_plan(h: int, w: int, *, scale: float, cs: int,
                         blur_ks: tuple[int, ...],
                         art_modes: tuple[int, ...] = (0,)) -> TransformPlan:
    """Build the operator bundle for one (resolution, colorspace) group.

    Requires even ``h``/``w`` for yuv420 (4:2:0 subsampling); the host
    NumPy path stays the oracle for odd geometries.
    """
    if cs == CS_YUV420 and (h % 2 or w % 2):
        raise ValueError(f"yuv420 grid transform needs even dims, got {h}x{w}")
    packed_h = h + h // 2 if cs == CS_YUV420 else h
    ry = resize_operator(packed_h, max(1, int(round(packed_h * scale))), scale)
    rx = resize_operator(w, max(1, int(round(w * scale))), scale)
    out_h, out_w = ry.shape[0], rx.shape[0]
    by_of = {k: blur_operator(out_h, k) for k in blur_ks}
    bx_of = {k: blur_operator(out_w, k) for k in blur_ks}
    pairs = [(a, k) for a in art_modes for k in blur_ks]   # artifact-major
    bys = np.stack([by_of[k] for _, k in pairs])
    bxs = np.stack([bx_of[k] for _, k in pairs])
    art_ids = np.asarray([a for a, _ in pairs], np.int32)
    blur_ids = np.asarray([k for _, k in pairs], np.int32)
    return TransformPlan(cs=cs, scale=scale, blur_ks=tuple(blur_ks),
                         art_modes=tuple(art_modes),
                         in_h=h, in_w=w, packed_h=packed_h,
                         out_h=out_h, out_w=out_w,
                         n_planes=3 if cs == CS_BGR else 1,
                         ry=ry, rx=rx, bys=bys, bxs=bxs,
                         art_ids=art_ids, blur_ids=blur_ids)


def _to_planes(frame: jax.Array, cs: int) -> jax.Array:
    """uint8 [H, W, 3] -> f32 planes [P, packed_h, W] (knob2, wire layout)."""
    f = frame.astype(jnp.float32)
    b, g, r = f[..., 0], f[..., 1], f[..., 2]
    if cs == CS_BGR:
        return jnp.stack([b, g, r], axis=0)
    y = 0.114 * b + 0.587 * g + 0.299 * r
    if cs == CS_GRAY:
        return jnp.clip(jnp.round(y), 0, 255)[None]
    u = 0.492 * (b - y) + 128.0
    v = 0.877 * (r - y) + 128.0
    y8 = jnp.clip(jnp.round(y), 0, 255)
    u8 = jnp.clip(jnp.round(u[::2, ::2]), 0, 255)
    v8 = jnp.clip(jnp.round(v[::2, ::2]), 0, 255)
    return jnp.concatenate([y8, jnp.concatenate([u8, v8], axis=1)],
                           axis=0)[None]


def _artifact_masks(frame: jax.Array, bg: jax.Array, *,
                    thresh: float) -> tuple[jax.Array, jax.Array]:
    """knob4 keep-masks (movers, contours) of one uint8 [H, W, 3] frame
    against the raw background -- the exact semantics of
    ``knobs._artifact_removal``: channel-mean abs diff > thresh, cross
    dilation (false borders), contours = dilated minus its cross erosion
    (true borders)."""
    d = jnp.abs(frame.astype(jnp.float32) - bg.astype(jnp.float32))
    mask = d.mean(axis=-1) > thresh
    fr = jnp.zeros_like(mask[:1, :])
    fc = jnp.zeros_like(mask[:, :1])
    m = mask
    m = m | jnp.concatenate([fr, mask[:-1, :]], axis=0)
    m = m | jnp.concatenate([mask[1:, :], fr], axis=0)
    m = m | jnp.concatenate([fc, mask[:, :-1]], axis=1)
    m = m | jnp.concatenate([mask[:, 1:], fc], axis=1)
    tr = jnp.ones_like(m[:1, :])
    tc = jnp.ones_like(m[:, :1])
    er = m
    er = er & jnp.concatenate([tr, m[:-1, :]], axis=0)
    er = er & jnp.concatenate([m[1:, :], tr], axis=0)
    er = er & jnp.concatenate([tc, m[:, :-1]], axis=1)
    er = er & jnp.concatenate([m[:, 1:], tc], axis=1)
    return m, m & ~er


def _apply_artifact(frame: jax.Array, bg: jax.Array, mode: jax.Array, *,
                    thresh: float) -> jax.Array:
    """Apply knob4 with a traced per-setting ``mode`` scalar (0 off,
    1 movers, 2 contours): both masks are computed and the live one is
    selected, so one kernel instance serves the whole settings batch."""
    movers, contours = _artifact_masks(frame, bg, thresh=thresh)
    keep = jnp.where(mode == 1, movers,
                     jnp.where(mode == 2, contours,
                               jnp.ones_like(movers)))
    return jnp.where(keep[..., None], frame, jnp.zeros_like(frame))


def proxy_features(payload: jax.Array) -> jax.Array:
    """Wire-size proxy features of a ``[..., P, oh, ow]`` payload batch:
    (sum log2(1+|d|), zero-delta count, |d|<=2 count) for horizontal and
    vertical byte deltas -- 6 values per payload, reduced over the last
    three axes.  The single definition serves the Pallas kernel, the ref
    oracle, and the CPU XLA twin in ``core.grid_engine``."""
    a = payload.astype(jnp.int32)
    dx = jnp.abs(a[..., :, 1:] - a[..., :, :-1]).astype(jnp.float32)
    dy = jnp.abs(a[..., 1:, :] - a[..., :-1, :]).astype(jnp.float32)
    axes = (-3, -2, -1)
    return jnp.stack([
        jnp.log2(1.0 + dx).sum(axes), (dx == 0).sum(axes).astype(jnp.float32),
        (dx <= 2).sum(axes).astype(jnp.float32),
        jnp.log2(1.0 + dy).sum(axes), (dy == 0).sum(axes).astype(jnp.float32),
        (dy <= 2).sum(axes).astype(jnp.float32),
    ], axis=-1)


def proxy_features_host(payload: np.ndarray) -> np.ndarray:
    """NumPy twin of ``proxy_features`` for one host payload (any shape with
    at least 2 dims; a 2-D payload is treated as one plane).  Used by
    ``CamBroker.fetch``'s per-frame candidate pre-screen, where dispatching
    a jitted op per frame would cost more than the feature math itself."""
    a = np.asarray(payload).astype(np.int64)
    if a.ndim == 2:
        a = a[None]                      # packed/gray -> one plane
    else:
        a = np.moveaxis(a, -1, 0)        # interleaved HxWxC -> planes
    dx = np.abs(a[:, :, 1:] - a[:, :, :-1]).astype(np.float32)
    dy = np.abs(a[:, 1:, :] - a[:, :-1, :]).astype(np.float32)
    return np.asarray([
        np.log2(1.0 + dx).sum(), float((dx == 0).sum()),
        float((dx <= 2).sum()),
        np.log2(1.0 + dy).sum(), float((dy == 0).sum()),
        float((dy <= 2).sum()),
    ], np.float32)


def _grid_compute(frame: jax.Array, prev: jax.Array, ry: jax.Array,
                  rx: jax.Array, by: jax.Array, bx: jax.Array, *,
                  cs: int, pixel_delta: float,
                  bg: jax.Array | None = None,
                  art_mode: jax.Array | None = None,
                  art_thresh: float = ARTIFACT_THRESH,
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The fused per-(setting, frame) pipeline, shared op-for-op with the
    interpret-mode oracle contract.  All matmuls accumulate in f32."""
    # knob5 change metric on the raw frame (channel-mean, like
    # ``knobs.frame_difference``) -- measured BEFORE knob4, matching
    # ``knobs.apply_knobs``' pipeline order
    d = jnp.abs(frame.astype(jnp.float32) - prev.astype(jnp.float32))
    d = d.mean(axis=-1)
    changed = (d > pixel_delta).astype(jnp.float32).mean()

    if bg is not None:
        frame = _apply_artifact(frame, bg, art_mode, thresh=art_thresh)
    planes = _to_planes(frame, cs)                                 # [P,Hc,W]
    rs = jnp.einsum("ah,phw->paw", ry, planes)                     # knob1
    rs = jnp.einsum("bw,paw->pab", rx, rs)
    rs = jnp.clip(jnp.round(rs), 0, 255)
    bl = jnp.einsum("ab,pbw->paw", by, rs)                         # knob3
    bl = jnp.einsum("cw,paw->pac", bx, bl)
    payload = jnp.clip(jnp.round(bl), 0, 255).astype(jnp.uint8)

    return payload, proxy_features(payload), changed


def _grid_kernel(f_ref, p_ref, ry_ref, rx_ref, by_ref, bx_ref,
                 o_ref, ft_ref, ch_ref, *, cs: int, pixel_delta: float):
    payload, feats, changed = _grid_compute(
        f_ref[0], p_ref[0], ry_ref[...], rx_ref[...], by_ref[0], bx_ref[0],
        cs=cs, pixel_delta=pixel_delta)
    o_ref[0, 0] = payload
    ft_ref[0, 0] = feats
    ch_ref[0, 0] = changed


def _grid_kernel_art(f_ref, p_ref, bg_ref, en_ref, am_ref, ry_ref, rx_ref,
                     by_ref, bx_ref, o_ref, ft_ref, ch_ref, *, cs: int,
                     pixel_delta: float, art_thresh: float):
    # per-frame enable gates knob4 off for the background / padding frames
    mode = am_ref[0] * en_ref[0]
    payload, feats, changed = _grid_compute(
        f_ref[0], p_ref[0], ry_ref[...], rx_ref[...], by_ref[0], bx_ref[0],
        cs=cs, pixel_delta=pixel_delta, bg=bg_ref[...], art_mode=mode,
        art_thresh=art_thresh)
    o_ref[0, 0] = payload
    ft_ref[0, 0] = feats
    ch_ref[0, 0] = changed


@functools.partial(jax.jit, static_argnames=("cs", "geom", "pixel_delta",
                                             "art_thresh", "interpret"))
def _grid_call(frames, prev, ry, rx, bys, bxs, *, cs, geom, pixel_delta,
               interpret, bg=None, art_enable=None, art_ids=None,
               art_thresh=ARTIFACT_THRESH):
    h, w, packed_h, out_h, out_w, n_planes = geom
    s = bys.shape[0]
    f = frames.shape[0]
    with_art = bg is not None
    if with_art:
        kernel = functools.partial(_grid_kernel_art, cs=cs,
                                   pixel_delta=pixel_delta,
                                   art_thresh=art_thresh)
        extra_in = [
            pl.BlockSpec((h, w, 3), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((1,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ]
        extra_args = (bg, art_enable, art_ids)
    else:
        kernel = functools.partial(_grid_kernel, cs=cs,
                                   pixel_delta=pixel_delta)
        extra_in, extra_args = [], ()
    return pl.pallas_call(
        kernel,
        grid=(s, f),
        in_specs=[
            pl.BlockSpec((1, h, w, 3), lambda i, j: (j, 0, 0, 0)),
            pl.BlockSpec((1, h, w, 3), lambda i, j: (j, 0, 0, 0)),
            *extra_in,
            pl.BlockSpec((out_h, packed_h), lambda i, j: (0, 0)),
            pl.BlockSpec((out_w, w), lambda i, j: (0, 0)),
            pl.BlockSpec((1, out_h, out_h), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, out_w, out_w), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, n_planes, out_h, out_w),
                         lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, N_PROXY_FEATURES), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, f, n_planes, out_h, out_w), jnp.uint8),
            jax.ShapeDtypeStruct((s, f, N_PROXY_FEATURES), jnp.float32),
            jax.ShapeDtypeStruct((s, f), jnp.float32),
        ],
        interpret=interpret,
    )(frames, prev, *extra_args, ry, rx, bys, bxs)


def frame_knob_grid(frames: jax.Array, prev: jax.Array, plan: TransformPlan,
                    *, background: jax.Array | None = None,
                    art_enable: jax.Array | None = None,
                    pixel_delta: float = 8.0,
                    art_thresh: float = ARTIFACT_THRESH,
                    interpret: bool = False
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Evaluate one plan's settings batch over a clip in a single HBM pass.

    frames/prev: uint8 ``[F, H, W, 3]`` (prev = the clip shifted by one for
    the knob5 metric).  Plans with knob4 settings additionally need
    ``background`` (uint8 ``[H, W, 3]``, the raw background model) and may
    pass ``art_enable`` (i32 ``[F]``, default all-on) to exempt individual
    frames -- ``core.grid_engine`` exempts the background/padding frames it
    prepends for the detector diff.  Returns

      payload [S, F, P, out_h, out_w] uint8   the shipped representation
                                              (P planes: b/g/r, or one
                                              gray / packed-yuv plane),
      feats   [S, F, 6] f32                   wire-size proxy features,
      changed [S, F] f32                      knob5 changed-pixel fraction
                                              (setting-independent: every
                                              row carries the same values).
    """
    n, h, w, c = frames.shape
    assert (h, w) == (plan.in_h, plan.in_w) and c == 3, (frames.shape, plan)
    geom = (plan.in_h, plan.in_w, plan.packed_h, plan.out_h, plan.out_w,
            plan.n_planes)
    if plan.with_artifact and background is None:
        raise ValueError("plan batches knob4 settings; pass background=")
    kwargs = {}
    if background is not None:
        if art_enable is None:
            art_enable = jnp.ones((n,), jnp.int32)
        kwargs = dict(bg=jnp.asarray(background),
                      art_enable=jnp.asarray(art_enable, jnp.int32),
                      art_ids=jnp.asarray(plan.art_ids),
                      art_thresh=art_thresh)
    return _grid_call(frames, prev, jnp.asarray(plan.ry),
                      jnp.asarray(plan.rx), jnp.asarray(plan.bys),
                      jnp.asarray(plan.bxs), cs=plan.cs, geom=geom,
                      pixel_delta=pixel_delta, interpret=interpret, **kwargs)
