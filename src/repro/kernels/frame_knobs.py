"""Fused frame-quality kernel: downsample + box blur + change metric.

The paper measures knob processing at ~10 ms/frame on the camera node's ARM
CPU -- 20.5% of end-to-end latency (Fig. 16) -- and proposes offload as
future work.  This kernel is that offload, TPU-native: one pass over the
frame applies

  1. knob5 sensor: fraction of pixels changed vs. the previous SENT frame
     (|diff| > pixel_delta) -- the transport layer drops the frame when the
     fraction is under the controller's threshold,
  2. knob1: 2x2 mean-pool downsample,
  3. knob3: separable k x k box blur (edge-clamped), applied on the pooled
     plane (so its VMEM working set is 1/4 of the input),

reading the frame from HBM exactly once.  Grid = (num_frames,): one whole
gray plane per program (a 1080p plane is ~2 MB fp32 pooled -- comfortably
VMEM-resident; color runs as 3 planes).  Blur is block-local by
construction, matching `ref.frame_knobs_ref` exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["frame_knobs"]


def _knobs_kernel(f_ref, p_ref, o_ref, c_ref, *, blur_k: int,
                  pixel_delta: float):
    f = f_ref[0].astype(jnp.float32)                   # [H, W]
    prev = p_ref[0].astype(jnp.float32)
    h, w = f.shape

    # knob5 change metric
    changed = (jnp.abs(f - prev) > pixel_delta).astype(jnp.float32)
    c_ref[0] = changed.sum() / (h * w)

    # knob1: 2x2 mean pool
    pooled = f.reshape(h // 2, 2, w // 2, 2).mean(axis=(1, 3))

    # knob3: separable box blur with edge clamp (block-local)
    if blur_k > 1:
        pad = blur_k // 2
        acc = jnp.zeros_like(pooled)
        for dy in range(-pad, blur_k - pad):
            idx = jnp.clip(jnp.arange(h // 2) + dy, 0, h // 2 - 1)
            acc = acc + pooled[idx]
        pooled = acc / blur_k
        acc = jnp.zeros_like(pooled)
        for dx in range(-pad, blur_k - pad):
            idx = jnp.clip(jnp.arange(w // 2) + dx, 0, w // 2 - 1)
            acc = acc + pooled[:, idx]
        pooled = acc / blur_k

    o_ref[0] = pooled.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blur_k", "pixel_delta",
                                             "interpret"))
def frame_knobs(frames: jax.Array, prev: jax.Array, *, blur_k: int = 5,
                pixel_delta: float = 8.0, interpret: bool = False
                ) -> tuple[jax.Array, jax.Array]:
    """frames/prev: [N, H, W] (uint8 or float) -> (out [N, H/2, W/2] f32,
    changed_frac [N] f32)."""
    n, h, w = frames.shape
    assert h % 2 == 0 and w % 2 == 0, (h, w)
    return pl.pallas_call(
        functools.partial(_knobs_kernel, blur_k=blur_k,
                          pixel_delta=pixel_delta),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1, h // 2, w // 2), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n, h // 2, w // 2), jnp.float32),
                   jax.ShapeDtypeStruct((n,), jnp.float32)],
        interpret=interpret,
    )(frames, prev)
