"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode so every test
validates the actual kernel body; on TPU they compile through Mosaic.  Model
code imports from here (``attention_impl="pallas"`` paths).
"""

from __future__ import annotations

import jax

from repro.kernels import decode_attention as _decode
from repro.kernels import flash_attention as _flash
from repro.kernels import frame_knobs as _knobs
from repro.kernels import linear_scan as _scan
from repro.kernels import quantize as _quant

__all__ = ["flash_attention", "decode_attention", "wkv_linear_scan",
           "quantize_blocks", "dequantize_blocks", "frame_knobs", "INTERPRET"]

INTERPRET = jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal=True, scale=None, block_q=256,
                    block_k=512):
    return _flash.flash_attention(q, k, v, causal=causal, scale=scale,
                                  block_q=block_q, block_k=block_k,
                                  interpret=INTERPRET)


def decode_attention(q, k_cache, v_cache, length, *, scale=None, block_k=512):
    return _decode.decode_attention(q, k_cache, v_cache, length, scale=scale,
                                    block_k=block_k, interpret=INTERPRET)


def wkv_linear_scan(r, k, v, logw, u, *, block_t=64):
    return _scan.wkv_linear_scan(r, k, v, logw, u, block_t=block_t,
                                 interpret=INTERPRET)


def quantize_blocks(x, *, block=(256, 512), bits=8):
    return _quant.quantize_blocks(x, block=block, bits=bits,
                                  interpret=INTERPRET)


def dequantize_blocks(q, scales, *, block=(256, 512), out_dtype=None):
    import jax.numpy as jnp
    return _quant.dequantize_blocks(q, scales, block=block,
                                    out_dtype=out_dtype or jnp.float32,
                                    interpret=INTERPRET)


def frame_knobs(frames, prev, *, blur_k=5, pixel_delta=8.0):
    return _knobs.frame_knobs(frames, prev, blur_k=blur_k,
                              pixel_delta=pixel_delta, interpret=INTERPRET)
