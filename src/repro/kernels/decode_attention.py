"""Flash-decode: single-query attention against a long KV cache, Pallas TPU.

decode_32k / long_500k cells are HBM-bandwidth-bound: the step reads the
whole KV cache once and does O(S*D) FLOPs per head.  Grid = (batch, q_heads);
each program streams its KV-head's cache in [BK, D] tiles through VMEM,
carrying the online-softmax (m, l, acc) for its single query row.  Entries
past ``length`` are masked (the cache is preallocated with slack).

GQA mapping as in flash_attention: kv head = q head // group in index_map.
"""

# mezlint: ref-parity: repro.kernels.ref.decode_attention_ref

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["decode_attention"]

NEG_INF = -2.3819763e38


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, scale: float,
                   block_k: int):
    q = q_ref[0, 0, 0, :].astype(jnp.float32) * scale          # [D]
    d = q.shape[0]
    length = len_ref[0]

    m0 = jnp.full((1,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((1,), jnp.float32)
    acc0 = jnp.zeros((1, d), jnp.float32)

    def body(kv_i, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, pl.ds(kv_i * block_k, block_k), 0,
                            slice(None))).astype(jnp.float32)   # [BK, D]
        v = pl.load(v_ref, (0, pl.ds(kv_i * block_k, block_k), 0,
                            slice(None))).astype(jnp.float32)
        s = (k @ q)[None, :]                                    # [1, BK]
        pos = kv_i * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    n_kv = pl.cdiv(length, block_k)
    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-37)[:, None]
    o_ref[0, 0, 0, :] = out[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array, *, scale: float | None = None,
                     block_k: int = 512, interpret: bool = False) -> jax.Array:
    """q: [B, 1, QH, D]; caches: [B, S_max, KH, D]; length: i32[] valid rows."""
    b, one, qh, d = q.shape
    assert one == 1
    _, smax, kh, _ = k_cache.shape
    group = qh // kh
    block_k = min(block_k, smax)
    assert smax % block_k == 0, (smax, block_k)
    scale = scale if scale is not None else d ** -0.5
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (1,))

    grid = (b, qh)
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),   # length (scalar prefetchable)
            pl.BlockSpec((1, 1, 1, d), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, smax, 1, d),
                         lambda bi, hi, group=group: (bi, 0, hi // group, 0)),
            pl.BlockSpec((1, smax, 1, d),
                         lambda bi, hi, group=group: (bi, 0, hi // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda bi, hi: (bi, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1, qh, d), q.dtype),
        interpret=interpret,
    )(length, q, k_cache, v_cache)
