"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each ref implements the kernel's EXACT semantics (including block-local
behaviour where the kernel is blockwise by design) so tests can
assert_allclose across shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["quantize_ref", "dequantize_ref", "flash_attention_ref",
           "decode_attention_ref", "wkv_ref", "frame_knobs_ref",
           "frame_knob_grid_ref"]


# -----------------------------------------------------------------------------
# quantize
# -----------------------------------------------------------------------------


def quantize_ref(x: jax.Array, *, block=(256, 512), bits: int = 8):
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    qmax = {8: 127.0, 4: 7.0}[bits]
    xb = x.astype(jnp.float32).reshape(m // bm, bm, n // bn, bn)
    xb = xb.transpose(0, 2, 1, 3)                     # [GM, GN, bm, bn]
    absmax = jnp.max(jnp.abs(xb), axis=(-1, -2))
    scales = jnp.maximum(absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(xb / scales[..., None, None]), -qmax, qmax)
    q = q.transpose(0, 2, 1, 3).reshape(m, n).astype(jnp.int8)
    return q, scales


def dequantize_ref(q: jax.Array, scales: jax.Array, *, block=(256, 512),
                   out_dtype=jnp.float32):
    m, n = q.shape
    bm, bn = min(block[0], m), min(block[1], n)
    qb = q.astype(jnp.float32).reshape(m // bm, bm, n // bn, bn)
    qb = qb.transpose(0, 2, 1, 3) * scales[..., None, None]
    return qb.transpose(0, 2, 1, 3).reshape(m, n).astype(out_dtype)


# -----------------------------------------------------------------------------
# attention
# -----------------------------------------------------------------------------


def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    """Reference = exact softmax attention (GQA-expanded inputs)."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, length, *, scale=None):
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    smax = k_cache.shape[1]
    valid = jnp.arange(smax)[None, :] < jnp.reshape(length, (-1, 1))
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)


# -----------------------------------------------------------------------------
# gated linear recurrence (rwkv6 wkv)
# -----------------------------------------------------------------------------


def wkv_ref(r, k, v, logw, u, *, state0=None):
    """Step-by-step recurrence.  r/k/v/logw: [B,S,H,K]; u: [H,K].

        y_t     = r_t . (state_{t-1} + diag(u) k_t v_t^T)
        state_t = diag(w_t) state_{t-1} + k_t v_t^T
    """
    b, s, h, kd = r.shape
    r32, k32, v32 = (x.astype(jnp.float32) for x in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))

    def step(state, xs):
        rt, kt, vt, wt = xs                       # [B,H,K]
        kv = kt[..., :, None] * vt[..., None, :]  # [B,H,K,V]
        y = jnp.einsum("bhk,bhkv->bhv", rt,
                       state + u[None, :, :, None] * kv)
        state = wt[..., None] * state + kv
        return state, y

    if state0 is None:
        state0 = jnp.zeros((b, h, kd, kd), jnp.float32)
    xs = tuple(x.transpose(1, 0, 2, 3) for x in (r32, k32, v32, w))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), state


# -----------------------------------------------------------------------------
# frame knobs (fused downsample + blur + change metric)
# -----------------------------------------------------------------------------


def frame_knobs_ref(frames: jax.Array, prev: jax.Array, *, blur_k: int = 5,
                    pixel_delta: float = 8.0):
    """Per-frame: 2x2 mean-pool -> block-local box blur (edge-clamped) ->
    fraction of changed pixels vs ``prev`` (pre-downsample).

    frames/prev: [N, H, W] float32 or uint8.  Returns (out [N,H/2,W/2] f32,
    changed_frac [N] f32).  Semantics match the Pallas kernel exactly
    (whole-frame blocks, edge-clamped blur).
    """
    f = frames.astype(jnp.float32)
    p = prev.astype(jnp.float32)
    changed = (jnp.abs(f - p) > pixel_delta).mean(axis=(1, 2))
    n, h, w = f.shape
    pooled = f.reshape(n, h // 2, 2, w // 2, 2).mean(axis=(2, 4))
    if blur_k > 1:
        pad = blur_k // 2
        padded = jnp.pad(pooled, ((0, 0), (pad, blur_k - 1 - pad), (0, 0)),
                         mode="edge")
        kern = jnp.ones((blur_k,), jnp.float32) / blur_k
        pooled = jax.vmap(
            lambda img: jax.vmap(lambda col: jnp.convolve(col, kern, mode="valid"),
                                 in_axes=1, out_axes=1)(img))(padded)
        padded = jnp.pad(pooled, ((0, 0), (0, 0), (pad, blur_k - 1 - pad)),
                         mode="edge")
        pooled = jax.vmap(
            lambda img: jax.vmap(lambda row: jnp.convolve(row, kern, mode="valid"))(img))(padded)
    return pooled, changed


# -----------------------------------------------------------------------------
# generalized knob grid (colorspace + arbitrary resize + blur + proxy feats)
# -----------------------------------------------------------------------------


def frame_knob_grid_ref(frames: jax.Array, prev: jax.Array, plan, *,
                        background: jax.Array | None = None,
                        art_enable: jax.Array | None = None,
                        pixel_delta: float = 8.0,
                        art_thresh: float | None = None):
    """Oracle for ``frame_knobs.frame_knob_grid``: one (setting, frame)
    program at a time via ``lax.map``, so every contraction runs at the
    exact per-program shapes of the Pallas grid -- bit-exact including the
    uint8 round/clip after each stage.

    frames/prev: uint8 [F, H, W, 3].  Plans batching knob4 settings need
    ``background`` (and optionally ``art_enable`` [F], default all-on),
    mirroring the kernel's inputs.  Returns (payload [S, F, P, oh, ow]
    uint8, feats [S, F, 6] f32, changed [S, F] f32).
    """
    from repro.kernels.frame_knobs import ARTIFACT_THRESH, _grid_compute

    if art_thresh is None:
        art_thresh = ARTIFACT_THRESH
    s = plan.bys.shape[0]
    f = frames.shape[0]
    ry = jnp.asarray(plan.ry)
    rx = jnp.asarray(plan.rx)
    bys = jnp.asarray(plan.bys)
    bxs = jnp.asarray(plan.bxs)
    with_art = background is not None
    if plan.with_artifact and not with_art:
        raise ValueError("plan batches knob4 settings; pass background=")
    if with_art:
        bg = jnp.asarray(background)
        art_ids = jnp.asarray(plan.art_ids)
        enable = (jnp.ones((f,), jnp.int32) if art_enable is None
                  else jnp.asarray(art_enable, jnp.int32))

    def one(idx):
        si, fi = idx // f, idx % f
        kwargs = {}
        if with_art:
            kwargs = dict(bg=bg, art_mode=art_ids[si] * enable[fi],
                          art_thresh=art_thresh)
        return _grid_compute(frames[fi], prev[fi], ry, rx, bys[si], bxs[si],
                             cs=plan.cs, pixel_delta=pixel_delta, **kwargs)

    payload, feats, changed = jax.lax.map(one, jnp.arange(s * f))
    return (payload.reshape(s, f, plan.n_planes, plan.out_h, plan.out_w),
            feats.reshape(s, f, -1), changed.reshape(s, f))
