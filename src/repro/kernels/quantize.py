"""Block-quantization Pallas kernels (the approximate-collective payload).

Symmetric per-block quantization: each (BM, BN) tile gets one fp32 scale =
absmax / qmax; values round to int8 (qmax=127) or int4-range int8 (qmax=7,
transport packs two per byte).  This is the Mez "colorspace knob" for tensor
payloads: the controller picks the bit-width, these kernels sit on the
critical path of every compressed cross-pod all-reduce.

TPU design: tiles are (BM, BN) = (256, 512) by default -- large enough to
amortize the two-pass absmax+quantize over one VMEM residency, lane-aligned
(last dim multiple of 128).  Grid = (M/BM, N/BN); absmax reduction and the
round happen entirely in VMEM/VREGs.
"""

# mezlint: ref-parity: repro.kernels.ref.quantize_ref
# mezlint: ref-parity: repro.kernels.ref.dequantize_ref

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quantize_blocks", "dequantize_blocks"]


def _quantize_kernel(x_ref, q_ref, s_ref, *, qmax: float):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    q_ref[...] = q
    s_ref[0, 0] = scale


def _dequantize_kernel(q_ref, s_ref, x_ref, *, out_dtype):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = (q * s_ref[0, 0]).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("block", "bits", "interpret"))
def quantize_blocks(x: jax.Array, *, block: tuple[int, int] = (256, 512),
                    bits: int = 8, interpret: bool = False
                    ) -> tuple[jax.Array, jax.Array]:
    """x: [M, N] -> (int8 [M, N], scales f32 [M/BM, N/BN]).

    M, N must be multiples of the block shape (callers pad; the collective
    payloads are weight/grad matrices with friendly shapes).
    """
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0, (x.shape, block)
    qmax = {8: 127.0, 4: 7.0}[bits]
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, qmax=qmax),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((m, n), jnp.int8),
                   jax.ShapeDtypeStruct(grid, jnp.float32)],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("block", "out_dtype", "interpret"))
def dequantize_blocks(q: jax.Array, scales: jax.Array, *,
                      block: tuple[int, int] = (256, 512),
                      out_dtype=jnp.float32, interpret: bool = False
                      ) -> jax.Array:
    m, n = q.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert scales.shape == (m // bm, n // bn), (q.shape, scales.shape, block)
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, out_dtype=out_dtype),
        grid=scales.shape,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(q, scales)
