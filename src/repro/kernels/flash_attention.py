"""Blockwise (flash) causal GQA attention, Pallas TPU.

Grid = (batch, q_heads, S/BQ); each program owns one [BQ, D] query tile in
VMEM and streams the KV sequence in [BK, D] tiles, maintaining the online
softmax (m, l, acc) in VREGs/VMEM scratch.  Causal masking skips fully-masked
KV tiles via the fori upper bound (no wasted MXU work past the diagonal).
GQA: the q-head index maps to its KV head (kh = qh // group) in the
BlockSpec index_map, so KV tiles are fetched once per group.

Block shapes default to (BQ, BK) = (256, 512): MXU-aligned (multiples of
128) and a [BQ,D]+[2*BK,D]+[BQ,BK] working set well under VMEM at D<=256.
"""

# mezlint: ref-parity: repro.kernels.ref.flash_attention_ref

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention"]

NEG_INF = -2.3819763e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
                  block_q: int, block_k: int, seq_k: int):
    qi = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale          # [BQ, D]
    bq, d = q.shape

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)

    def body(kv_i, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, pl.ds(kv_i * block_k, block_k), 0,
                            slice(None))).astype(jnp.float32)   # [BK, D]
        v = pl.load(v_ref, (0, pl.ds(kv_i * block_k, block_k), 0,
                            slice(None))).astype(jnp.float32)
        s = q @ k.T                                             # [BQ, BK]
        k_pos = kv_i * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (bq, block_k), 1)
        mask = k_pos < seq_k
        if causal:
            mask = mask & (q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    if causal:
        # last KV tile that intersects the causal frontier of this q tile
        hi = (qi + 1) * block_q
        n_kv = pl.cdiv(jnp.minimum(hi, seq_k), block_k)
    else:
        n_kv = pl.cdiv(seq_k, block_k)
    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-37)[:, None]
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 256, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: [B, Sq, QH, D]; k/v: [B, Sk, KH, D] (QH % KH == 0)."""
    b, sq, qh, d = q.shape
    _, sk, kh, _ = k.shape
    group = qh // kh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pad_q, sk + pad_k
    scale = scale if scale is not None else d ** -0.5

    grid = (b, qh, sq_p // block_q)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, seq_k=sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda bi, hi, qi: (bi, qi, hi, 0)),
            pl.BlockSpec((1, sk_p, 1, d),
                         lambda bi, hi, qi, group=group: (bi, 0, hi // group, 0)),
            pl.BlockSpec((1, sk_p, 1, d),
                         lambda bi, hi, qi, group=group: (bi, 0, hi // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda bi, hi, qi: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq_p, qh, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
