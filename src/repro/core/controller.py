"""The Mez network latency controller (paper Section 4.2, Algorithm 1).

Two implementations with identical control law:

``LatencyController``  -- host-side, lives next to the CamBroker (the paper's
                          deployment: a microservice on the IoT camera node).
``controller_step``    -- pure-JAX, jittable (lax-only control flow).  This is
                          the paper's future-work item "integrating the
                          controller as a part of the CamBroker" taken to its
                          TPU-native conclusion: the controller can run inside
                          a compiled step, where it drives the approximate-
                          collective knob (core/approx_comm.py).

``JaxControllerTables`` are TRACED inputs of ``controller_step``: padded to a
fixed ``capacity`` with an ``n_valid`` row count, a freshly characterized
table (``grid_engine.refresh_tables``) hot-swaps into a compiled step with no
recompile -- ``swap_tables`` reuses the live tables' donated device buffers.
That closes the online re-characterization loop: ``Session.update_qos``
re-runs the batched sweep and the very next compiled step consumes the new
tables.

Control law (Algorithm 1):

    nominal   = Regression^-1(latency_target)              # bytes
    error     = latency_sampled - latency_target           # seconds
    size      = nominal + K1 * error + K2 * integral(error)
    accuracy, knob = Table.query(size)                     # BST + hash lookups
    if accuracy >= accuracy_target: apply knob
    else: report infeasible (application decides: relax or fail)

K1, K2 < 0: positive latency error shrinks the requested size.  Gains are
auto-scaled from the regression slope so they are expressed in natural units
("how many bytes does one second of error buy").
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.characterization import CharacterizationTable, LatencyRegression
from repro.core.knobs import KnobSetting

__all__ = ["ControllerConfig", "ControlDecision", "LatencyController",
           "JaxControllerTables", "ControllerState", "controller_init",
           "controller_step", "swap_tables"]


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    latency_target: float            # seconds (paper: 100 ms)
    accuracy_target: float           # normalized F1 floor (paper: 0.95-0.96)
    error_threshold: float = 0.010   # seconds; inside the band = no action
    alpha_p: float = 0.8             # K1 = -alpha_p / slope
    alpha_i: float = 0.25            # K2 = -alpha_i / slope
    integral_clip: float = 1.0       # anti-windup, seconds*samples
    relax: bool = True               # also act when latency is far BELOW target
                                     # (paper's Alg. 1 is one-sided; relaxation
                                     # restores quality after interference ends)


@dataclasses.dataclass(frozen=True)
class ControlDecision:
    feasible: bool
    setting: KnobSetting | None
    setting_index: int
    predicted_accuracy: float
    requested_size: float
    error: float
    acted: bool


class LatencyController:
    """Host-side PI controller (one per IoT camera node; no central control,
    so camera nodes scale independently -- paper Section 4.2)."""

    def __init__(self, config: ControllerConfig, table: CharacterizationTable,
                 regression: LatencyRegression):
        self.config = config
        self.table = table
        self.regression = regression
        self.integral = 0.0
        self.k1 = -config.alpha_p / max(regression.slope, 1e-12)
        self.k2 = -config.alpha_i / max(regression.slope, 1e-12)
        self._nominal = regression.invert(config.latency_target)
        # Algorithm 1: the starting operating point is the nominal size the
        # regression model predicts for the latency target (not full quality).
        _, idx = self.table.query_size(
            float(np.clip(self._nominal, self.table.sizes_sorted[0],
                          self.table.sizes_sorted[-1])))
        self._current = int(idx)

    def set_target(self, latency_target: float, accuracy_target: float) -> None:
        """The CamBroker's internal SetTarget API (paper Fig. 9).

        Runtime retarget: callable mid-stream (v2 ``update_qos``).  Besides
        resetting the integral, the operating point is re-seeded from the new
        target's nominal size so the renegotiated bounds take effect on the
        very next fetch -- within one control interval -- instead of waiting
        for the error signal to walk the old setting over.
        """
        self.config = dataclasses.replace(
            self.config, latency_target=latency_target,
            accuracy_target=accuracy_target)
        self._nominal = self.regression.invert(latency_target)
        self.integral = 0.0
        _, idx = self.table.query_size(
            float(np.clip(self._nominal, self.table.sizes_sorted[0],
                          self.table.sizes_sorted[-1])))
        self._current = int(idx)

    def swap_table(self, table: CharacterizationTable) -> None:
        """Hot-swap a freshly characterized table (online
        re-characterization).  Unlike ``set_target`` this keeps the PI
        state: the integral carries over (network conditions did not reset
        just because the tables did) and only the operating point is
        re-seeded into the new table's size axis."""
        self.table = table
        _, idx = table.query_size(
            float(np.clip(self._nominal, table.sizes_sorted[0],
                          table.sizes_sorted[-1])))
        self._current = int(idx)

    def update(self, latency_sampled: float) -> ControlDecision:
        cfg = self.config
        error = latency_sampled - cfg.latency_target
        act = error > cfg.error_threshold or (
            cfg.relax and error < -cfg.error_threshold)
        if not act:
            # inside the band: hold the current setting
            idx = self._current
            acc = float(self.table.acc_by_setting[idx]) if idx >= 0 else 0.0
            return ControlDecision(idx >= 0, self.table.setting_for(idx) if idx >= 0
                                   else None, idx, acc, self._nominal, error, False)
        self.integral = float(np.clip(self.integral + error,
                                      -cfg.integral_clip, cfg.integral_clip))
        size = self._nominal + self.k1 * error + self.k2 * self.integral
        size = float(np.clip(size, self.table.sizes_sorted[0],
                             self.table.sizes_sorted[-1]))
        accuracy, idx = self.table.query_size(size)
        if accuracy >= cfg.accuracy_target and idx >= 0:
            self._current = idx
            return ControlDecision(True, self.table.setting_for(idx), idx,
                                   accuracy, size, error, True)
        # Paper: "If the application requested latency and accuracy are
        # infeasible, the application is notified.  At this point, the
        # application has to decide whether to continue operation with
        # relaxed latency/accuracy requirements, or notify the system
        # operator of failure."  We notify (feasible=False) AND return the
        # best-accuracy setting within the size budget so a subscriber that
        # chooses "continue relaxed" degrades gracefully instead of
        # reverting to raw frames.
        if idx >= 0:
            self._current = idx
        return ControlDecision(False,
                               self.table.setting_for(idx) if idx >= 0 else None,
                               idx, accuracy, size, error, True)

    @property
    def current_setting(self) -> KnobSetting | None:
        return self.table.setting_for(self._current) if self._current >= 0 else None


# =============================================================================
# Jittable controller
# =============================================================================


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class JaxControllerTables:
    """Characterization tables as device arrays (sorted by size).

    Every field is a pytree LEAF, so the whole object is a traced input of
    ``controller_step`` -- refreshed values flow into a compiled step
    without retracing.  ``from_table(capacity=)`` pads the row axis to a
    fixed size (``sizes_sorted`` with +inf so ``searchsorted`` never lands
    in the padding) and records the live row count in ``n_valid``; tables
    of any kept-set size then share ONE compiled step, which is what makes
    online re-characterization swap-in free.
    """
    sizes_sorted: jax.Array   # f32[capacity], +inf beyond n_valid
    best_acc: jax.Array       # f32[capacity]
    best_idx: jax.Array       # i32[capacity], -1 beyond n_valid
    n_valid: jax.Array = None  # i32[], live rows (defaults to capacity)

    def __post_init__(self):
        if self.n_valid is None:
            self.n_valid = jnp.asarray(self.sizes_sorted.shape[0], jnp.int32)

    def tree_flatten(self):
        return ((self.sizes_sorted, self.best_acc, self.best_idx,
                 self.n_valid), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def from_table(cls, table: CharacterizationTable, *,
                   capacity: int | None = None) -> "JaxControllerTables":
        a = table.as_arrays()
        n = a["sizes_sorted"].shape[0]
        cap = n if capacity is None else int(capacity)
        if cap < n:
            raise ValueError(f"capacity {cap} < {n} characterized settings")
        pad = cap - n
        sizes = np.concatenate([a["sizes_sorted"],
                                np.full(pad, np.inf, np.float32)])
        acc = np.concatenate([a["best_acc"], np.zeros(pad, np.float32)])
        idx = np.concatenate([a["best_idx"], np.full(pad, -1, np.int32)])
        return cls(jnp.asarray(sizes), jnp.asarray(acc), jnp.asarray(idx),
                   jnp.asarray(n, jnp.int32))


def swap_tables(live: JaxControllerTables | None,
                fresh: JaxControllerTables) -> JaxControllerTables:
    """Hot-swap refreshed tables into a running compiled consumer.

    With matching capacities the swap is shape-stable (no recompile of any
    jitted step consuming the tables); on accelerator backends the live
    tables' buffers are donated so XLA reuses them in place instead of
    allocating.  Shape mismatch (capacity changed) falls through to the
    fresh tables -- consumers recompile once, which is the correct cost.
    """
    if live is None:
        return fresh
    live_leaves = jax.tree_util.tree_leaves(live)
    fresh_leaves = jax.tree_util.tree_leaves(fresh)
    if any(l.shape != f.shape or l.dtype != f.dtype
           for l, f in zip(live_leaves, fresh_leaves)):
        return fresh
    if jax.default_backend() == "cpu":
        # donation is a no-op on CPU; skip the jit round-trip (and its
        # "donated buffers were not usable" warning)
        return fresh
    return _swap_tables_donated(live, fresh)


@functools.partial(jax.jit, donate_argnums=(0,))
def _swap_tables_donated(live: JaxControllerTables,
                         fresh: JaxControllerTables) -> JaxControllerTables:
    del live  # buffers reused by XLA for the identically-shaped output
    return fresh


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ControllerState:
    integral: jax.Array       # f32[]
    current_idx: jax.Array    # i32[]
    feasible: jax.Array       # bool[]
    last_error: jax.Array     # f32[]

    def tree_flatten(self):
        return ((self.integral, self.current_idx, self.feasible,
                 self.last_error), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def controller_init(tables: JaxControllerTables, *,
                    start_idx: int | jax.Array | None = None
                    ) -> ControllerState:
    """Initial state: the highest-fidelity characterized setting, or an
    explicit ``start_idx`` (e.g. the host controller's seeded operating
    point, for lockstep host/jit comparisons)."""
    if start_idx is None:
        start = jnp.take(tables.best_idx, tables.n_valid - 1)
    else:
        start = jnp.asarray(start_idx)
    return ControllerState(
        integral=jnp.zeros((), jnp.float32),
        current_idx=start.astype(jnp.int32),
        feasible=jnp.ones((), bool),
        last_error=jnp.zeros((), jnp.float32),
    )


def controller_step(state: ControllerState, latency_sampled: jax.Array,
                    tables: JaxControllerTables, *,
                    latency_target: float, accuracy_target: float,
                    slope: float, intercept: float,
                    error_threshold: float = 0.010, alpha_p: float = 0.8,
                    alpha_i: float = 0.25, integral_clip: float = 1.0,
                    relax: bool = True) -> tuple[ControllerState, jax.Array]:
    """One PI update, fully traceable.  Returns (new_state, knob_index).

    ``tables`` is a TRACED input: hot-swapped tables (same capacity, any
    ``n_valid``) flow through a compiled caller with no retrace -- see
    ``swap_tables`` / ``JaxControllerTables.from_table(capacity=)``.

    knob_index is an i32 scalar indexing the characterized settings; -1 when
    no feasible setting exists (the compiled consumer falls back to the
    highest-fidelity payload and flags infeasibility, matching the paper's
    "notify the application" semantics).
    """
    lat = jnp.asarray(latency_sampled, jnp.float32)
    error = lat - latency_target
    act = error > error_threshold
    if relax:
        act = act | (error < -error_threshold)

    k1 = -alpha_p / max(slope, 1e-12)
    k2 = -alpha_i / max(slope, 1e-12)
    nominal = max(0.0, (latency_target - intercept) / max(slope, 1e-12))

    new_integral = jnp.clip(state.integral + error, -integral_clip, integral_clip)
    integral = jnp.where(act, new_integral, state.integral)

    size = nominal + k1 * error + k2 * integral
    # clip into the LIVE size range (padding rows carry +inf)
    hi = jnp.take(tables.sizes_sorted, tables.n_valid - 1)
    size = jnp.clip(size, tables.sizes_sorted[0], hi)
    pos = jnp.searchsorted(tables.sizes_sorted, size, side="right") - 1
    pos = jnp.clip(pos, 0, tables.n_valid - 1)
    accuracy = tables.best_acc[pos]
    idx = tables.best_idx[pos]

    ok = accuracy >= accuracy_target
    new_idx = jnp.where(act, jnp.where(ok, idx, -1), state.current_idx)
    new_feasible = jnp.where(act, ok, state.feasible)
    new_state = ControllerState(
        integral=integral,
        current_idx=new_idx.astype(jnp.int32),
        feasible=new_feasible,
        last_error=error,
    )
    return new_state, new_state.current_idx
