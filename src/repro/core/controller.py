"""The Mez network latency controller (paper Section 4.2, Algorithm 1).

Two implementations with identical control law:

``LatencyController``  -- host-side, lives next to the CamBroker (the paper's
                          deployment: a microservice on the IoT camera node).
``controller_step``    -- pure-JAX, jittable (lax-only control flow).  This is
                          the paper's future-work item "integrating the
                          controller as a part of the CamBroker" taken to its
                          TPU-native conclusion: the controller can run inside
                          a compiled step, where it drives the approximate-
                          collective knob (core/approx_comm.py).

``JaxControllerTables`` are TRACED inputs of ``controller_step``: padded to a
fixed ``capacity`` with an ``n_valid`` row count, a freshly characterized
table (``grid_engine.refresh_tables``) hot-swaps into a compiled step with no
recompile -- ``swap_tables`` reuses the live tables' donated device buffers.
That closes the online re-characterization loop: ``Session.update_qos``
re-runs the batched sweep and the very next compiled step consumes the new
tables.

Control law (Algorithm 1):

    nominal   = Regression^-1(latency_target)              # bytes
    error     = latency_sampled - latency_target           # seconds
    size      = nominal + K1 * error + K2 * integral(error)
    accuracy, knob = Table.query(size)                     # BST + hash lookups
    if accuracy >= accuracy_target: apply knob
    else: report infeasible (application decides: relax or fail)

K1, K2 < 0: positive latency error shrinks the requested size.  Gains are
auto-scaled from the regression slope so they are expressed in natural units
("how many bytes does one second of error buy").
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque
from collections.abc import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.characterization import CharacterizationTable, LatencyRegression
from repro.core.drift import (DriftConfig, DriftParams, DriftState,
                              _drift_lane_step, drift_init)
from repro.core.knobs import KnobSetting

__all__ = ["ControllerConfig", "ControlDecision", "LatencyController",
           "JaxControllerTables", "ControllerState", "controller_init",
           "controller_step", "swap_tables", "ControllerParams", "StepAux",
           "stack_tables", "stack_params", "fleet_controller_init",
           "fleet_controller_step", "fleet_swap_tables", "FusedTickAux",
           "fused_fleet_tick", "FleetTickResult", "FleetController"]


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    latency_target: float            # seconds (paper: 100 ms)
    accuracy_target: float           # normalized F1 floor (paper: 0.95-0.96)
    error_threshold: float = 0.010   # seconds; inside the band = no action
    alpha_p: float = 0.8             # K1 = -alpha_p / slope
    alpha_i: float = 0.25            # K2 = -alpha_i / slope
    integral_clip: float = 1.0       # anti-windup, seconds*samples
    relax: bool = True               # also act when latency is far BELOW target
                                     # (paper's Alg. 1 is one-sided; relaxation
                                     # restores quality after interference ends)


@dataclasses.dataclass(frozen=True)
class ControlDecision:
    feasible: bool
    setting: KnobSetting | None
    setting_index: int
    predicted_accuracy: float
    requested_size: float
    error: float
    acted: bool


class LatencyController:
    """Host-side PI controller (one per IoT camera node; no central control,
    so camera nodes scale independently -- paper Section 4.2)."""

    def __init__(self, config: ControllerConfig, table: CharacterizationTable,
                 regression: LatencyRegression):
        self.config = config
        self.table = table
        self.regression = regression
        self.integral = 0.0
        self.k1 = -config.alpha_p / max(regression.slope, 1e-12)
        self.k2 = -config.alpha_i / max(regression.slope, 1e-12)
        self._nominal = regression.invert(config.latency_target)
        # Algorithm 1: the starting operating point is the nominal size the
        # regression model predicts for the latency target (not full quality).
        _, idx = self.table.query_size(
            float(np.clip(self._nominal, self.table.sizes_sorted[0],
                          self.table.sizes_sorted[-1])))
        self._current = int(idx)

    def set_target(self, latency_target: float, accuracy_target: float) -> None:
        """The CamBroker's internal SetTarget API (paper Fig. 9).

        Runtime retarget: callable mid-stream (v2 ``update_qos``).  Besides
        resetting the integral, the operating point is re-seeded from the new
        target's nominal size so the renegotiated bounds take effect on the
        very next fetch -- within one control interval -- instead of waiting
        for the error signal to walk the old setting over.
        """
        self.config = dataclasses.replace(
            self.config, latency_target=latency_target,
            accuracy_target=accuracy_target)
        self._nominal = self.regression.invert(latency_target)
        self.integral = 0.0
        _, idx = self.table.query_size(
            float(np.clip(self._nominal, self.table.sizes_sorted[0],
                          self.table.sizes_sorted[-1])))
        self._current = int(idx)

    def swap_table(self, table: CharacterizationTable) -> None:
        """Hot-swap a freshly characterized table (online
        re-characterization).  Unlike ``set_target`` this keeps the PI
        state: the integral carries over (network conditions did not reset
        just because the tables did) and only the operating point is
        re-seeded into the new table's size axis."""
        self.table = table
        _, idx = table.query_size(
            float(np.clip(self._nominal, table.sizes_sorted[0],
                          table.sizes_sorted[-1])))
        self._current = int(idx)

    def update(self, latency_sampled: float,
               budget_scale: float = 1.0) -> ControlDecision:
        """One PI step.  ``budget_scale`` caps the nominal operating size
        (fleet admission control's per-tenant degradation knob; 1.0 -- the
        single-tenant case -- is exact, so decisions are unchanged)."""
        cfg = self.config
        nominal = self._nominal * budget_scale
        error = latency_sampled - cfg.latency_target
        act = error > cfg.error_threshold or (
            cfg.relax and error < -cfg.error_threshold)
        if not act:
            # inside the band: hold the current setting
            idx = self._current
            acc = float(self.table.acc_by_setting[idx]) if idx >= 0 else 0.0
            return ControlDecision(idx >= 0, self.table.setting_for(idx) if idx >= 0
                                   else None, idx, acc, nominal, error, False)
        self.integral = float(np.clip(self.integral + error,
                                      -cfg.integral_clip, cfg.integral_clip))
        size = nominal + self.k1 * error + self.k2 * self.integral
        size = float(np.clip(size, self.table.sizes_sorted[0],
                             self.table.sizes_sorted[-1]))
        accuracy, idx = self.table.query_size(size)
        if accuracy >= cfg.accuracy_target and idx >= 0:
            self._current = idx
            return ControlDecision(True, self.table.setting_for(idx), idx,
                                   accuracy, size, error, True)
        # Paper: "If the application requested latency and accuracy are
        # infeasible, the application is notified.  At this point, the
        # application has to decide whether to continue operation with
        # relaxed latency/accuracy requirements, or notify the system
        # operator of failure."  We notify (feasible=False) AND return the
        # best-accuracy setting within the size budget so a subscriber that
        # chooses "continue relaxed" degrades gracefully instead of
        # reverting to raw frames.
        if idx >= 0:
            self._current = idx
        return ControlDecision(False,
                               self.table.setting_for(idx) if idx >= 0 else None,
                               idx, accuracy, size, error, True)

    @property
    def current_setting(self) -> KnobSetting | None:
        return self.table.setting_for(self._current) if self._current >= 0 else None


# =============================================================================
# Jittable controller
# =============================================================================


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class JaxControllerTables:
    """Characterization tables as device arrays (sorted by size).

    Every field is a pytree LEAF, so the whole object is a traced input of
    ``controller_step`` -- refreshed values flow into a compiled step
    without retracing.  ``from_table(capacity=)`` pads the row axis to a
    fixed size (``sizes_sorted`` with +inf so ``searchsorted`` never lands
    in the padding) and records the live row count in ``n_valid``; tables
    of any kept-set size then share ONE compiled step, which is what makes
    online re-characterization swap-in free.
    """
    sizes_sorted: jax.Array   # f32[capacity], +inf beyond n_valid
    best_acc: jax.Array       # f32[capacity]
    best_idx: jax.Array       # i32[capacity], -1 beyond n_valid
    n_valid: jax.Array = None  # i32[], live rows (defaults to capacity)
    codes: jax.Array = None   # i32[capacity, 5] knob codes per SETTING index
    #                           (resolution, colorspace, blur, artifact,
    #                           diff) -- what the fused fleet tick gathers so
    #                           the host rebuilds a KnobSetting without
    #                           touching the Python table on the poll path

    def __post_init__(self):
        if self.n_valid is None:
            self.n_valid = jnp.asarray(self.sizes_sorted.shape[0], jnp.int32)
        if self.codes is None:
            self.codes = jnp.zeros((self.sizes_sorted.shape[0], 5), jnp.int32)

    def tree_flatten(self):
        return ((self.sizes_sorted, self.best_acc, self.best_idx,
                 self.n_valid, self.codes), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def from_table(cls, table: CharacterizationTable, *,
                   capacity: int | None = None) -> "JaxControllerTables":
        a = table.as_arrays()
        n = a["sizes_sorted"].shape[0]
        cap = n if capacity is None else int(capacity)
        if cap < n:
            raise ValueError(f"capacity {cap} < {n} characterized settings")
        pad = cap - n
        sizes = np.concatenate([a["sizes_sorted"],
                                np.full(pad, np.inf, np.float32)])
        acc = np.concatenate([a["best_acc"], np.zeros(pad, np.float32)])
        idx = np.concatenate([a["best_idx"], np.full(pad, -1, np.int32)])
        codes = np.zeros((cap, 5), np.int32)
        codes[:len(table.settings)] = [
            (s.resolution, s.colorspace, s.blur, s.artifact, s.diff)
            for s in table.settings]
        return cls(jnp.asarray(sizes), jnp.asarray(acc), jnp.asarray(idx),
                   jnp.asarray(n, jnp.int32), jnp.asarray(codes))


def swap_tables(live: JaxControllerTables | None,
                fresh: JaxControllerTables) -> JaxControllerTables:
    """Hot-swap refreshed tables into a running compiled consumer.

    With matching capacities the swap is shape-stable (no recompile of any
    jitted step consuming the tables); on accelerator backends the live
    tables' buffers are donated so XLA reuses them in place instead of
    allocating.  Shape mismatch (capacity changed) falls through to the
    fresh tables -- consumers recompile once, which is the correct cost.
    """
    if live is None:
        return fresh
    live_leaves = jax.tree_util.tree_leaves(live)
    fresh_leaves = jax.tree_util.tree_leaves(fresh)
    if any(l.shape != f.shape or l.dtype != f.dtype
           for l, f in zip(live_leaves, fresh_leaves)):
        return fresh
    if jax.default_backend() == "cpu":
        # donation is a no-op on CPU; skip the jit round-trip (and its
        # "donated buffers were not usable" warning)
        return fresh
    return _swap_tables_donated(live, fresh)


@functools.partial(jax.jit, donate_argnums=(0,))
def _swap_tables_donated(live: JaxControllerTables,
                         fresh: JaxControllerTables) -> JaxControllerTables:
    del live  # buffers reused by XLA for the identically-shaped output
    return fresh


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ControllerState:
    integral: jax.Array       # f32[]
    current_idx: jax.Array    # i32[]
    feasible: jax.Array       # bool[]
    last_error: jax.Array     # f32[]

    def tree_flatten(self):
        return ((self.integral, self.current_idx, self.feasible,
                 self.last_error), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def controller_init(tables: JaxControllerTables, *,
                    start_idx: int | jax.Array | None = None
                    ) -> ControllerState:
    """Initial state: the highest-fidelity characterized setting, or an
    explicit ``start_idx`` (e.g. the host controller's seeded operating
    point, for lockstep host/jit comparisons)."""
    if start_idx is None:
        start = jnp.take(tables.best_idx, tables.n_valid - 1)
    else:
        start = jnp.asarray(start_idx)
    return ControllerState(
        integral=jnp.zeros((), jnp.float32),
        current_idx=start.astype(jnp.int32),
        feasible=jnp.ones((), bool),
        last_error=jnp.zeros((), jnp.float32),
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ControllerParams:
    """The control-law constants of Algorithm 1 as TRACED leaves.

    For one camera every leaf is a scalar; ``stack_params`` stacks N of
    them into ``f32[N]`` lanes for the vmapped fleet step.  The gains are
    precomputed host-side in float64 (``k1``/``k2``/``nominal``) exactly as
    ``LatencyController`` does, so a compiled step fed these params is
    numerically identical to the scalar-kwarg ``controller_step`` -- and a
    per-camera retarget (new targets, same shapes) flows into a compiled
    consumer without retracing.
    """
    latency_target: jax.Array    # f32
    accuracy_target: jax.Array   # f32
    error_threshold: jax.Array   # f32
    k1: jax.Array                # f32, -alpha_p / slope (bytes per second)
    k2: jax.Array                # f32, -alpha_i / slope
    nominal: jax.Array           # f32, Regression^-1(latency_target), bytes
    integral_clip: jax.Array     # f32
    relax: jax.Array             # bool
    # multi-tenant axes: admission control reallocates the shared wire
    # budget by writing these leaves (values, not shapes -- no retrace).
    budget_scale: jax.Array = None  # f32, cap on nominal (1.0 = full budget)
    tier: jax.Array = None          # i32, tenant SLO preemption priority

    def __post_init__(self):
        if self.budget_scale is None:
            self.budget_scale = jnp.float32(1.0)
        if self.tier is None:
            self.tier = jnp.int32(0)

    def tree_flatten(self):
        return ((self.latency_target, self.accuracy_target,
                 self.error_threshold, self.k1, self.k2, self.nominal,
                 self.integral_clip, self.relax, self.budget_scale,
                 self.tier), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def from_scalars(cls, *, latency_target: float, accuracy_target: float,
                     slope: float, intercept: float,
                     error_threshold: float = 0.010, alpha_p: float = 0.8,
                     alpha_i: float = 0.25, integral_clip: float = 1.0,
                     relax: bool = True, budget_scale: float = 1.0,
                     tier: int = 0) -> "ControllerParams":
        k1 = -alpha_p / max(slope, 1e-12)
        k2 = -alpha_i / max(slope, 1e-12)
        nominal = max(0.0, (latency_target - intercept) / max(slope, 1e-12))
        return cls(jnp.float32(latency_target), jnp.float32(accuracy_target),
                   jnp.float32(error_threshold), jnp.float32(k1),
                   jnp.float32(k2), jnp.float32(nominal),
                   jnp.float32(integral_clip), jnp.asarray(relax),
                   jnp.float32(budget_scale), jnp.int32(tier))

    @classmethod
    def from_controller(cls, host: "LatencyController", *,
                        budget_scale: float = 1.0,
                        tier: int = 0) -> "ControllerParams":
        """Mirror a live host controller's law (gains/nominal copied verbatim
        from the float64 host state, so fleet decisions track host decisions).
        ``budget_scale``/``tier`` carry the owning subscription's admission
        cap and SLO class -- per-subscription state the host controller
        (shared across tenants) does not own."""
        cfg = host.config
        return cls(jnp.float32(cfg.latency_target),
                   jnp.float32(cfg.accuracy_target),
                   jnp.float32(cfg.error_threshold), jnp.float32(host.k1),
                   jnp.float32(host.k2), jnp.float32(host._nominal),
                   jnp.float32(cfg.integral_clip), jnp.asarray(cfg.relax),
                   jnp.float32(budget_scale), jnp.int32(tier))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StepAux:
    """Per-step decision detail (everything ``CamBroker.fetch`` needs to act
    on a decision without re-running the host control law)."""
    idx: jax.Array             # i32, chosen setting (-1 = none / raw frames)
    feasible: jax.Array        # bool, accuracy floor met at the size budget
    acted: jax.Array           # bool, outside the error band this step
    error: jax.Array           # f32, latency error (seconds)
    requested_size: jax.Array  # f32, PI output (bytes), nominal when holding
    accuracy: jax.Array        # f32, best accuracy at the size budget

    def tree_flatten(self):
        return ((self.idx, self.feasible, self.acted, self.error,
                 self.requested_size, self.accuracy), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _controller_step_core(state: ControllerState, latency_sampled: jax.Array,
                          tables: JaxControllerTables,
                          params: ControllerParams, *,
                          best_effort: bool = False
                          ) -> tuple[ControllerState, StepAux]:
    """One PI update with traced params -- the shared scalar/fleet core.

    ``best_effort`` selects the infeasible-step semantics: False keeps the
    raw jittable contract (knob index -> -1, consumer falls back to raw
    frames); True mirrors the host ``LatencyController`` (serve the
    best-accuracy setting within budget, notify via the feasible flag) --
    what the fleet-backed broker path uses.
    """
    lat = jnp.asarray(latency_sampled, jnp.float32)
    error = lat - params.latency_target
    act = (error > params.error_threshold) | (
        params.relax & (error < -params.error_threshold))

    new_integral = jnp.clip(state.integral + error,
                            -params.integral_clip, params.integral_clip)
    integral = jnp.where(act, new_integral, state.integral)

    nominal = params.nominal * params.budget_scale
    size = nominal + params.k1 * error + params.k2 * integral
    # clip into the LIVE size range (padding rows carry +inf)
    hi = jnp.take(tables.sizes_sorted, tables.n_valid - 1)
    size = jnp.clip(size, tables.sizes_sorted[0], hi)
    pos = jnp.searchsorted(tables.sizes_sorted, size, side="right") - 1
    pos = jnp.clip(pos, 0, tables.n_valid - 1)
    accuracy = tables.best_acc[pos]
    idx = tables.best_idx[pos]

    ok = accuracy >= params.accuracy_target
    if best_effort:
        # host semantics: _current moves to the best-effort setting even on
        # an infeasible step (idx >= 0 guard matches the host's)
        chosen = jnp.where(idx >= 0, idx, state.current_idx)
    else:
        chosen = jnp.where(ok, idx, -1)
    new_idx = jnp.where(act, chosen, state.current_idx)
    new_feasible = jnp.where(act, ok, state.feasible)
    new_state = ControllerState(
        integral=integral,
        current_idx=new_idx.astype(jnp.int32),
        feasible=new_feasible,
        last_error=error,
    )
    # decision-shaped feasibility mirrors the host: an acted step reports
    # whether the floor was met, a hold reports whether a live setting is
    # being served (the STATE keeps the sticky flag for jit consumers)
    aux = StepAux(idx=new_state.current_idx,
                  feasible=jnp.where(act, ok, new_state.current_idx >= 0),
                  acted=act, error=error,
                  requested_size=jnp.where(act, size, nominal),
                  accuracy=accuracy)
    return new_state, aux


# mezlint: jit-entry
def controller_step(state: ControllerState, latency_sampled: jax.Array,
                    tables: JaxControllerTables, *,
                    latency_target: float, accuracy_target: float,
                    slope: float, intercept: float,
                    error_threshold: float = 0.010, alpha_p: float = 0.8,
                    alpha_i: float = 0.25, integral_clip: float = 1.0,
                    relax: bool = True) -> tuple[ControllerState, jax.Array]:
    """One PI update, fully traceable.  Returns (new_state, knob_index).

    ``tables`` is a TRACED input: hot-swapped tables (same capacity, any
    ``n_valid``) flow through a compiled caller with no retrace -- see
    ``swap_tables`` / ``JaxControllerTables.from_table(capacity=)``.

    knob_index is an i32 scalar indexing the characterized settings; -1 when
    no feasible setting exists (the compiled consumer falls back to the
    highest-fidelity payload and flags infeasibility, matching the paper's
    "notify the application" semantics).
    """
    params = ControllerParams.from_scalars(
        latency_target=latency_target, accuracy_target=accuracy_target,
        slope=slope, intercept=intercept, error_threshold=error_threshold,
        alpha_p=alpha_p, alpha_i=alpha_i, integral_clip=integral_clip,
        relax=relax)
    new_state, _ = _controller_step_core(state, latency_sampled, tables,
                                         params)
    return new_state, new_state.current_idx


# =============================================================================
# Fleet control plane: all cameras of a session in ONE compiled step
# =============================================================================


def stack_tables(tables: "Sequence[JaxControllerTables]"
                 ) -> JaxControllerTables:
    """Stack per-camera tables along a leading fleet axis.

    Every table must share one capacity (``JaxControllerTables.from_table``
    with a common ``capacity=``); per-camera ``n_valid`` row counts may
    differ freely -- that is what makes a per-camera hot-swap free.
    """
    caps = {t.sizes_sorted.shape[-1] for t in tables}
    if len(caps) != 1:
        raise ValueError(f"stack_tables needs one shared capacity, got {caps}")
    return jax.tree_util.tree_map(lambda *leaves: jnp.stack(leaves), *tables)


def stack_params(params: "Sequence[ControllerParams]") -> ControllerParams:
    """Stack per-camera control-law params along a leading fleet axis."""
    return jax.tree_util.tree_map(lambda *leaves: jnp.stack(leaves), *params)


def fleet_controller_init(tables: JaxControllerTables, *,
                          start_idx=None) -> ControllerState:
    """Stacked initial state for a fleet of N cameras (tables stacked along
    the leading axis).  ``start_idx`` seeds per-camera operating points
    (i32[N]); default is each camera's highest-fidelity setting."""
    n = tables.sizes_sorted.shape[0]
    if start_idx is None:
        start = jax.vmap(lambda t: jnp.take(t.best_idx, t.n_valid - 1))(tables)
    else:
        start = jnp.asarray(start_idx)
    return ControllerState(
        integral=jnp.zeros((n,), jnp.float32),
        current_idx=start.astype(jnp.int32),
        feasible=jnp.ones((n,), bool),
        last_error=jnp.zeros((n,), jnp.float32),
    )


def fleet_controller_step(states: ControllerState, latencies: jax.Array,
                          tables: JaxControllerTables,
                          params: ControllerParams
                          ) -> tuple[ControllerState, StepAux]:
    """One PI update for a WHOLE fleet: ``controller_step`` vmapped over the
    leading camera axis of every input, so N cameras cost one compiled
    dispatch instead of N (per-step Python overhead is ~flat in N).

    Uses host (best-effort) infeasible semantics -- this is the step the
    fleet-backed ``EdgeBroker.poll_subscription`` drives, and the broker's
    contract is the paper's "notify the application AND keep serving the
    best-accuracy setting within budget".
    """
    lats = jnp.asarray(latencies, jnp.float32)
    return jax.vmap(
        functools.partial(_controller_step_core, best_effort=True)
    )(states, lats, tables, params)


def fleet_swap_tables(live: JaxControllerTables, index,
                      fresh: JaxControllerTables) -> JaxControllerTables:
    """Hot-swap a SUBSET of per-camera tables inside a stacked fleet.

    ``index`` is an int (one camera) or int sequence; ``fresh`` is one
    table (capacity matching the stack) or a stack of ``len(index)`` tables.
    Shapes are unchanged, so every compiled consumer of the stack keeps its
    cache -- re-characterizing camera 17 of 256 never recompiles the fleet
    step.  Capacity mismatch is an error (grow the stack deliberately via
    ``FleetController`` instead)."""
    idx = jnp.atleast_1d(jnp.asarray(index, jnp.int32))
    cap_live = live.sizes_sorted.shape[-1]
    cap_fresh = fresh.sizes_sorted.shape[-1]
    if cap_live != cap_fresh:
        raise ValueError(f"fleet_swap_tables: capacity mismatch "
                         f"(stack {cap_live}, fresh {cap_fresh})")

    def put(leaf_live, leaf_fresh):
        leaf_fresh = jnp.asarray(leaf_fresh)
        if leaf_fresh.ndim == leaf_live.ndim - 1:      # single row
            leaf_fresh = leaf_fresh[None]
        return leaf_live.at[idx].set(leaf_fresh)

    return jax.tree_util.tree_map(put, live, fresh)


def _set_lane(tree, i: int, row):
    """Write one fleet lane of a stacked pytree (state/params row update)."""
    return jax.tree_util.tree_map(
        lambda stacked, v: stacked.at[i].set(v), tree, row)


# =============================================================================
# Fused fleet tick: drift + control + decision application, ONE dispatch
# =============================================================================


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FusedTickAux:
    """Everything the host needs from one fused tick, in one transfer:
    the per-lane controller decision detail, the chosen setting's knob
    codes (so ``KnobSetting`` is rebuilt without touching the Python
    table), and the drift fire-set."""
    step: StepAux              # per-lane controller decision detail
    codes: jax.Array           # i32[..., 5], chosen setting's knob codes
    #                            (-1 rows when no live setting is served)
    fired: jax.Array           # bool[...], drift lane fired this tick
    score: jax.Array           # f32[...], drift windowed score

    def tree_flatten(self):
        return ((self.step, self.codes, self.fired, self.score), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _fused_lane_core(ctrl_state: ControllerState, drift_state: DriftState,
                     latency: jax.Array, drift_err: jax.Array,
                     drift_valid: jax.Array, tables: JaxControllerTables,
                     params: ControllerParams, drift_params: DriftParams
                     ) -> tuple[ControllerState, DriftState, FusedTickAux]:
    """One camera's whole per-poll control plane, fused.

    Built on the SAME cores as the unfused path (``_drift_lane_step`` then
    ``_controller_step_core(best_effort=True)``), so fused decisions are
    bit-identical to the three-dispatch path -- the parity tests hold this
    lane by lane.  The drift observation is the residual the host
    aggregated at the END of the previous poll; a fire is reported in the
    aux for the host to act on (recharacterize + table swap + re-tick).
    """
    new_drift, fired, score = _drift_lane_step(drift_state, drift_err,
                                               drift_valid, drift_params)
    new_ctrl, aux = _controller_step_core(ctrl_state, latency, tables,
                                          params, best_effort=True)
    # decision application on device: gather the chosen setting's knob codes
    safe = jnp.clip(aux.idx, 0, tables.codes.shape[0] - 1)
    codes = jnp.where(aux.idx >= 0, jnp.take(tables.codes, safe, axis=0),
                      jnp.full((5,), -1, jnp.int32))
    return new_ctrl, new_drift, FusedTickAux(step=aux, codes=codes,
                                             fired=fired, score=score)


def fused_fleet_tick(ctrl_states: ControllerState, drift_states: DriftState,
                     latencies: jax.Array, drift_errs: jax.Array,
                     drift_valid: jax.Array, tables: JaxControllerTables,
                     params: ControllerParams, drift_params: DriftParams
                     ) -> tuple[ControllerState, DriftState, FusedTickAux]:
    """The whole fleet's per-poll control plane as ONE compiled dispatch:
    drift tick + PI step + decision->knob-code application, vmapped over
    the leading camera axis.  This is the function ``FleetController``
    jits (and, with a mesh, ``shard_map``s over the camera axis -- every
    lane is independent, so lane sharding cannot change numerics)."""
    lats = jnp.asarray(latencies, jnp.float32)
    errs = jnp.asarray(drift_errs, jnp.float32)
    valid = jnp.asarray(drift_valid, bool)
    return jax.vmap(_fused_lane_core)(ctrl_states, drift_states, lats, errs,
                                      valid, tables, params, drift_params)


class FleetTickResult(Mapping):
    """Lazy ``camera_id -> ControlDecision`` view over one fused tick.

    ``poll_subscription`` only materializes decisions for the cameras it
    actually fetches this poll (O(fetched), not O(N)); iterating the
    mapping (the dict-compat ``FleetController.decide`` path) materializes
    every lane.  ``setting`` is rebuilt from the tick's gathered knob codes
    -- ``KnobSetting`` is a frozen value type, so this equals the host
    table's ``setting_for(idx)`` bit for bit.
    """

    __slots__ = ("fired_cams", "_cam_ids", "_lane", "_aux", "_cache")

    def __init__(self, cam_ids, lane_map, aux_host, fired_cams):
        self._cam_ids = cam_ids
        self._lane = lane_map
        self._aux = aux_host            # device_get'd FusedTickAux (padded)
        self._cache: dict[int, ControlDecision] = {}
        self.fired_cams = fired_cams    # drift fire-set, lane order

    def _materialize(self, i: int) -> ControlDecision:
        d = self._cache.get(i)
        if d is None:
            a = self._aux
            idx = int(a.step.idx[i])
            setting = (KnobSetting(*(int(c) for c in a.codes[i]))
                       if idx >= 0 else None)
            d = ControlDecision(
                feasible=bool(a.step.feasible[i]), setting=setting,
                setting_index=idx,
                predicted_accuracy=float(a.step.accuracy[i]),
                requested_size=float(a.step.requested_size[i]),
                error=float(a.step.error[i]), acted=bool(a.step.acted[i]))
            self._cache[i] = d
        return d

    def get(self, cid, default=None):
        i = self._lane.get(cid)
        return default if i is None else self._materialize(i)

    def __getitem__(self, cid) -> ControlDecision:
        i = self._lane.get(cid)
        if i is None:
            raise KeyError(cid)
        return self._materialize(i)

    def __iter__(self):
        return iter(self._cam_ids)

    def __len__(self) -> int:
        return len(self._cam_ids)


class FleetController:
    """Host-side orchestrator: N per-camera control planes as ONE jitted
    ``fused_fleet_tick`` (PI step + drift tick + decision application).

    Built over live ``CamBroker``-like objects (anything carrying
    ``camera_id``, ``controller``, ``table_version``, ``qos_version``); the
    brokers' host controllers stay the source of truth for tables, targets
    and law constants, while the PI *state* (integral, operating point)
    lives here on device.  ``sync()`` diffs the brokers' version counters
    and hot-swaps changed lanes (tables via ``fleet_swap_tables``, targets
    via a params-row write) without recompiling; only a table that outgrows
    the shared capacity rebuilds the stack, which recompiles once -- the
    correct cost.

    ``mesh`` partitions the tick over the camera axis with ``shard_map``
    (``repro.sharding.partition.fleet_mesh``): an int selects that many
    host devices, a ``jax.sharding.Mesh`` is used as given, ``None`` stays
    single-device.  Lanes are padded up to a device multiple (padding lanes
    replicate lane 0 and are fed hold inputs; their outputs are never
    read), and every lane is independent, so sharding never changes
    numerics -- the 8-device parity test holds fused==host bit for bit.
    """

    HISTORY_LIMIT = 4096

    def __init__(self, cams, *, capacity: int | None = None,
                 record_history: bool = False, mesh=None, tier: int = 0):
        cams = list(cams)
        # multi-tenant axes: the owning subscription's SLO class rides as a
        # per-lane i32 leaf, and admission control caps the fleet's wire
        # budget by writing the per-lane budget_scale leaf (set_budget_scale)
        self._tier = int(tier)
        self._budget_scale = 1.0
        if not cams:
            raise ValueError("FleetController needs at least one camera")
        for cam in cams:
            if cam.controller is None:
                raise ValueError(
                    f"camera {cam.camera_id!r} has no controller installed")
        self._cams = cams
        self.cam_ids = [c.camera_id for c in cams]
        self.lane_of = {cid: i for i, cid in enumerate(self.cam_ids)}
        need = max(len(c.controller.table.settings) for c in cams)
        self.capacity = max(need, capacity or 0)
        self.record_history = record_history
        self.history: "deque" = deque(maxlen=self.HISTORY_LIMIT)
        self.mesh = None
        tick_fn = fused_fleet_tick
        if mesh is not None:
            from repro.sharding import partition
            self.mesh = partition.fleet_mesh(mesh)
            tick_fn = partition.shard_fleet_tick(fused_fleet_tick, self.mesh)
        n = len(cams)
        lanes_mult = self.mesh.devices.size if self.mesh is not None else 1
        self.n_lanes = n
        self._n_padded = -(-n // lanes_mult) * lanes_mult
        # wrap in a per-instance function object: jax.jit keys its tracing
        # cache on the callable, so each fleet gets its own cache and
        # ``cache_size()`` counts THIS fleet's compiled variants only.  On a
        # mesh the lane sharding is pinned AND every dispatch normalizes its
        # operands onto it (``device_put`` below): poll T feeds back poll
        # T-1's sharded outputs while poll 0 sees host arrays, and without
        # the normalization that placement split registers as a second
        # cache entry even though the traced program is identical.
        self._sharding = None
        jit_kwargs = {}
        if self.mesh is not None:
            self._sharding = partition.fleet_sharding(self.mesh)
            jit_kwargs = dict(in_shardings=self._sharding,
                              out_shardings=self._sharding)
        self._tick_jit = jax.jit(
            lambda cs, ds, lat, de, dv, tb, pr, dp: tick_fn(
                cs, ds, lat, de, dv, tb, pr, dp), **jit_kwargs)
        # drift lanes: a bound DriftMonitor's state rides in the fused tick;
        # without one, a window-1 placeholder holds forever (valid=False,
        # count pinned at 0 < min_samples, so it can never fire)
        self._drift = None
        self._drift_window = 1
        self._drift_state = drift_init(self._n_padded, 1)
        self._drift_params = DriftParams.from_config(
            DriftConfig(window=1), self._n_padded)
        self._pre_state = None
        self._build_stack()

    # -- stack assembly ------------------------------------------------------
    def _pad_rows(self, values, pad):
        return list(values) + [values[0]] * pad

    def _build_stack(self) -> None:
        pad = self._n_padded - self.n_lanes
        rows = [JaxControllerTables.from_table(c.controller.table,
                                               capacity=self.capacity)
                for c in self._cams]
        self.tables = stack_tables(self._pad_rows(rows, pad))
        self.params = stack_params(self._pad_rows(
            [ControllerParams.from_controller(c.controller,
                                              budget_scale=self._budget_scale,
                                              tier=self._tier)
             for c in self._cams], pad))
        start = np.asarray(self._pad_rows(
            [c.controller._current for c in self._cams], pad), np.int32)
        state = fleet_controller_init(self.tables, start_idx=start)
        self.state = ControllerState(
            integral=jnp.asarray(self._pad_rows(
                [c.controller.integral for c in self._cams], pad),
                jnp.float32),
            current_idx=state.current_idx,
            feasible=state.feasible,
            last_error=state.last_error)
        self._table_versions = [c.table_version for c in self._cams]
        self._qos_versions = [c.qos_version for c in self._cams]
        self._targets = np.asarray(self._pad_rows(
            [c.controller.config.latency_target for c in self._cams], pad),
            np.float32)

    def attach_drift(self, monitor) -> None:
        """Fuse a ``DriftMonitor``'s per-poll tick into this fleet's
        dispatch.  The monitor must share this fleet's lane order; its
        state/params ride as traced tick inputs (mesh padding added here),
        and post-tick lanes flow back via ``monitor.absorb_fused``."""
        if list(monitor.cam_ids) != self.cam_ids:
            raise ValueError("drift monitor lane order != fleet lane order")
        monitor.bind_fused(self)
        self._drift = monitor
        self._drift_window = monitor.config.window
        pad = self._n_padded - self.n_lanes
        if pad:
            pad_state = drift_init(pad, monitor.config.window)
            pad_params = DriftParams.from_config(monitor.config, pad)
            self._drift_params = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b]),
                monitor.params, pad_params)
            self._drift_pad_state = pad_state
        else:
            self._drift_params = monitor.params
            self._drift_pad_state = None

    def _drift_inputs(self, errs, valid):
        """(state, errs, valid) for the tick, mesh-padded when needed."""
        pad = self._n_padded - self.n_lanes
        if self._drift is None:
            return (self._drift_state,
                    np.zeros(self._n_padded, np.float32),
                    np.zeros(self._n_padded, bool))
        state = self._drift.state
        if errs is None:
            errs = np.zeros(self.n_lanes, np.float32)
            valid = np.zeros(self.n_lanes, bool)
        if pad:
            state = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b]),
                state, self._drift_pad_state)
            errs = np.concatenate(
                [np.asarray(errs, np.float32), np.zeros(pad, np.float32)])
            valid = np.concatenate([np.asarray(valid, bool),
                                    np.zeros(pad, bool)])
        return state, errs, valid

    def cache_size(self) -> int:
        """Compiled-variant count of the fused tick (1 = no recompiles)."""
        return self._tick_jit._cache_size()

    @property
    def budget_scale(self) -> float:
        """The admission-control cap currently applied to every lane."""
        return self._budget_scale

    def set_budget_scale(self, scale: float) -> None:
        """Fleet-level wire-budget reallocation (admission control's
        degradation knob): cap every lane's nominal operating size at
        ``scale`` x the regression nominal.  A pure params-LEAF write --
        values change, shapes don't -- so the compiled tick's cache stays
        at one; degrading (or restoring) a tenant under oversubscription
        costs the same single dispatch as a quiet poll."""
        s = float(np.float32(scale))
        if not 0.0 < s <= 1.0:
            raise ValueError(f"budget_scale must be in (0, 1], got {scale}")
        if s == self._budget_scale:
            return
        self._budget_scale = s
        self.params.budget_scale = jnp.full_like(self.params.budget_scale, s)

    def __len__(self) -> int:
        return len(self._cams)

    def export_lane(self, camera_id: str) -> tuple[float, int]:
        """Write one lane's live PI state back into the camera's host
        controller and return it.

        In fleet mode the stacked lanes -- not the host fields -- own the
        live integral/operating point, so a camera leaving this fleet (herd
        migration hands it to another broker) must carry its lane state out
        through the host controller: the receiving fleet's ``_build_stack``
        seeds from exactly these fields, so the PI integral survives the
        hand-off with no retrace on either side (this is a host-side array
        read + two float writes; the compiled tick is untouched)."""
        i = self.lane_of[camera_id]
        ctl = self._cams[i].controller
        integral = float(self.state.integral[i])
        current = int(self.state.current_idx[i])
        ctl.integral = integral
        ctl._current = current
        return integral, current

    # -- live reconfiguration ------------------------------------------------
    def sync(self) -> tuple[list[int], list[int]]:
        """Fold per-camera retargets / table refreshes into the stack.

        Called at the top of every ``decide``; O(N) integer compares when
        nothing changed.  A retarget rewrites the camera's params lane and
        mirrors the host's state reset (integral, re-seeded operating
        point); a table refresh hot-swaps the camera's table lane and
        re-seeds the operating point while the integral carries over --
        exactly the host-side ``set_target`` / ``swap_table`` contracts.

        Returns ``(table_swapped, retargeted)`` lane indices -- the exact
        set of lanes rewritten this sync (empty when nothing changed),
        which is how the drift-refresh tests assert that an
        auto-recharacterization touched precisely the fired cameras.
        """
        table_swapped = [cam.table_version != self._table_versions[i]
                         for i, cam in enumerate(self._cams)]
        retargeted = [cam.qos_version != self._qos_versions[i]
                      for i, cam in enumerate(self._cams)]
        need = max(len(c.controller.table.settings) for c in self._cams)
        if need > self.capacity:
            # at least one refreshed table outgrew the shared padding: grow
            # to the whole fleet's requirement at once and rebuild the
            # stack -- ONE deliberate recompile.  The fleet lanes, not the
            # (stale in fleet mode) host fields, own the live PI state, so
            # it is carried across the rebuild; changed lanes re-seed below.
            self.capacity = need
            state = self.state
            self._build_stack()
            self.state = state
        else:
            for i, cam in enumerate(self._cams):
                ctl = cam.controller
                if table_swapped[i]:
                    fresh = JaxControllerTables.from_table(
                        ctl.table, capacity=self.capacity)
                    self.tables = fleet_swap_tables(self.tables, i, fresh)
                    self._table_versions[i] = cam.table_version
                if retargeted[i]:
                    self.params = _set_lane(
                        self.params, i, ControllerParams.from_controller(
                            ctl, budget_scale=self._budget_scale,
                            tier=self._tier))
                    self._qos_versions[i] = cam.qos_version
                    self._targets[i] = ctl.config.latency_target
        for i, cam in enumerate(self._cams):
            if not (table_swapped[i] or retargeted[i]):
                continue
            ctl = cam.controller
            # mirror the host contracts: both paths re-seed the operating
            # point; only a RETARGET resets the integral (``set_target``)
            # -- a bare table swap carries it (``swap_table``: the network
            # didn't reset with the tables)
            integral = (self.state.integral.at[i].set(ctl.integral)
                        if retargeted[i] else self.state.integral)
            self.state = ControllerState(
                integral=integral,
                current_idx=self.state.current_idx.at[i].set(ctl._current),
                feasible=self.state.feasible,
                last_error=self.state.last_error)
        return ([i for i, s in enumerate(table_swapped) if s],
                [i for i, r in enumerate(retargeted) if r])

    # -- the fused fleet tick ------------------------------------------------
    def _dispatch(self, lat_eff, drift_errs, drift_valid):
        """Run the ONE compiled dispatch and absorb its state."""
        dstate, derrs, dvalid = self._drift_inputs(drift_errs, drift_valid)
        operands = (self.state, dstate, lat_eff, derrs, dvalid,
                    self.tables, self.params, self._drift_params)
        if self._sharding is not None:
            # normalize operand placement onto the lane sharding: a no-op
            # for the fed-back sharded state, a cheap host->device transfer
            # (which jit would pay anyway) for per-poll numpy inputs --
            # keeps the dispatch signature, and so cache_size(), at one.
            # The placed stacks are kept so later polls skip the transfer.
            operands = jax.device_put(operands, self._sharding)
            (self.state, _, _, _, _, self.tables, self.params,
             self._drift_params) = operands
        new_ctrl, new_drift, aux = self._tick_jit(*operands)
        self.state = new_ctrl
        aux = jax.device_get(aux)
        fired_cams: list[str] = []
        if self._drift is not None:
            fired_cams = self._drift.absorb_fused(
                new_drift, aux.fired, aux.score)
        return aux, fired_cams

    # mezlint: poll-path
    def tick(self, lat, valid, drift_errs=None, drift_valid=None, *,
             record: bool = True) -> FleetTickResult:
        """One fused control+drift tick for the whole fleet.

        ``lat``/``valid`` are lane-ordered arrays: observed p95 latency
        (seconds) and whether the lane actually has samples this poll.
        Invalid lanes are fed their own latency target (zero error ->
        in-band hold, state untouched), so a single compiled dispatch
        still covers every camera.  ``drift_errs``/``drift_valid`` feed the
        fused drift tick when a monitor is attached (None -> no drift
        observation this poll).

        Returns a lazy :class:`FleetTickResult`; its ``fired_cams`` lists
        the drift lanes that crossed ``hi`` this tick, in lane order.  The
        host recharacterizes those, then calls :meth:`retick` to re-decide
        against the refreshed tables -- same compiled callable, cache
        stays at one.
        """
        self.sync()
        lat = np.asarray(lat, np.float32)
        valid = np.asarray(valid, bool)
        pad = self._n_padded - self.n_lanes
        if pad:
            lat = np.concatenate([lat, np.zeros(pad, np.float32)])
            valid = np.concatenate([valid, np.zeros(pad, bool)])
        lat_eff = np.where(valid, lat, self._targets)
        self._pre_state = self.state
        aux, fired_cams = self._dispatch(lat_eff, drift_errs, drift_valid)
        self._last_lat_eff = lat_eff
        if record and self.record_history:
            n = self.n_lanes
            self.history.append({
                "lat": lat_eff[:n].tolist(), "fed": valid[:n].tolist(),
                "idx": np.asarray(aux.step.idx)[:n].tolist(),
                "acted": np.asarray(aux.step.acted)[:n].tolist(),
                "feasible": np.asarray(aux.step.feasible)[:n].tolist(),
                "table_versions": list(self._table_versions),
            })
        return FleetTickResult(self.cam_ids, self.lane_of, aux, fired_cams)

    def retick(self) -> FleetTickResult:
        """Re-decide the tick just taken, against freshly swapped tables.

        Restores the pre-tick controller state, folds the host-side
        refreshes in via ``sync()`` (which re-seeds the swapped lanes,
        mirroring the unfused refresh-before-decide ordering), and
        re-dispatches the SAME compiled tick with a no-op drift
        observation: fired lanes were cleared+disarmed by the first
        dispatch (cannot refire on an empty window) and rearmed lanes are
        already armed, so the drift state is provably unchanged.
        """
        if self._pre_state is None:
            raise RuntimeError("retick() without a preceding tick()")
        self.state = self._pre_state
        self.sync()
        aux, _ = self._dispatch(self._last_lat_eff, None, None)
        if self.record_history and self.history:
            n = self.n_lanes
            row = self.history[-1]
            row["idx"] = np.asarray(aux.step.idx)[:n].tolist()
            row["acted"] = np.asarray(aux.step.acted)[:n].tolist()
            row["feasible"] = np.asarray(aux.step.feasible)[:n].tolist()
            row["table_versions"] = list(self._table_versions)
        return FleetTickResult(self.cam_ids, self.lane_of, aux, [])

    def decide(self, feedback) -> dict[str, ControlDecision]:
        """Dict-compat wrapper over :meth:`tick`.

        ``feedback`` maps camera_id -> observed p95 latency (seconds), or
        None for cameras with no samples yet.  Returns one host-shaped
        ``ControlDecision`` per camera (every lane materialized).
        """
        n = self.n_lanes
        lat = np.zeros(n, np.float32)
        valid = np.zeros(n, bool)
        for i, cid in enumerate(self.cam_ids):
            f = feedback.get(cid)
            if f is not None:
                valid[i] = True
                lat[i] = f
        return dict(self.tick(lat, valid))
