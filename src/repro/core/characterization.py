"""Offline characterization (paper Sections 2.3-2.4) -> controller tables.

The controller (Algorithm 1) consumes three artifacts, all built here:

  1. ``LatencyRegression``   latency ~= a * wire_size + b   (paper Fig. 5:
     "approximately linear variation with video frame size").
  2. size -> best achievable accuracy   (paper: Binary Search Tree keyed by
     image size).  TPU/NumPy adaptation: a sorted size array + prefix-max of
     accuracy, queried with searchsorted -- the same O(log n) point query,
     vectorizable, and usable inside jit.
  3. accuracy -> knob setting           (paper: hash table).  Here: the argmax
     index carried alongside the prefix-max, so lookup 2 is O(1).

``characterize()`` sweeps the knob grid over a calibration clip from a
``SyntheticCamera``, measuring wire sizes and normalized F1 (blob detector
vs. ground truth), mirroring the paper's offline measurement campaign
("assumed to be available from prior characterization").  Settings with
normalized F1 < min_accuracy are excluded, as the paper excludes combos
under 90%.

Two engines share the semantics:

``engine="batched"`` (default)  the device-resident grid sweep in
    ``core.grid_engine``: transforms + detector scoring batched over the
    settings dimension, wire sizes from the calibrated byte-delta proxy
    (zlib runs once per transform combo instead of once per setting-frame).
    Minutes -> seconds: cheap enough to re-run on live QoS renegotiation.
    Covers knob4 (``include_artifact=True``) device-side; only non-BGR or
    odd-geometry cameras need the reference engine.

``engine="reference"``  the seed per-frame NumPy path, kept verbatim as the
    oracle (exact zlib sizes, host detector).  Also the fallback for
    non-BGR or odd-geometry cameras, which the device grid does not cover.

``table_from_grid`` scores an already-run ``GridCharacterization`` into a
table -- the shared back half of the batched engine, also driven by
``grid_engine.refresh_tables`` for online re-characterization (where the
full-quality detections stand in for ground truth).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING

import numpy as np

from repro.core import detector as det
from repro.core import knobs as K

if TYPE_CHECKING:
    from repro.core.grid_engine import GridCharacterization, WireSizeProxy

__all__ = ["LatencyRegression", "CharacterizationTable", "characterize",
           "table_from_grid", "fit_latency_regression"]


@dataclasses.dataclass(frozen=True)
class LatencyRegression:
    """latency_seconds = slope * wire_bytes + intercept."""
    slope: float
    intercept: float

    def predict(self, wire_bytes: float) -> float:
        return self.slope * wire_bytes + self.intercept

    def invert(self, latency_s: float) -> float:
        """The paper's ``RegressionModel(latencyTarget)`` -> nominal size."""
        return max(0.0, (latency_s - self.intercept) / max(self.slope, 1e-12))


def fit_latency_regression(sizes: np.ndarray, latencies: np.ndarray
                           ) -> LatencyRegression:
    sizes = np.asarray(sizes, np.float64)
    lats = np.asarray(latencies, np.float64)
    a, b = np.polyfit(sizes, lats, 1)
    return LatencyRegression(float(a), float(b))


@dataclasses.dataclass
class CharacterizationTable:
    """The two lookup tables of Algorithm 1, in sorted-array form.

    sizes_sorted[i]   : wire size of the i-th smallest characterized setting
    best_acc[i]       : best accuracy achievable with wire size <= sizes_sorted[i]
    best_idx[i]       : index into ``settings`` achieving best_acc[i]
    settings          : the characterized knob settings (knob4 excluded by default)
    acc_by_setting    : accuracy of each setting
    size_by_setting   : median wire size of each setting
    proxy             : the batched engine's calibrated wire-size proxy
                        (None for reference-engine tables) -- lets
                        ``CamBroker.fetch`` pre-screen candidate settings
                        against the controller's size budget without
                        paying deflate per candidate
    min_accuracy      : the accuracy floor this table was filtered at --
                        online re-characterization re-applies the SAME
                        floor so the trade space doesn't silently shrink
                        or grow across a refresh
    source            : provenance tag ("offline" for a calibration-time
                        sweep, "online-refresh" for tables re-swept live
                        by ``grid_engine.refresh_tables``, "stale-injected"
                        for fault-injected tables) -- lets the drift tests
                        and the fig12 benchmark assert WHICH tables a
                        controller is actually trading on
    activity          : mean changed-pixel fraction between consecutive
                        calibration-clip frames (knob5's dissimilarity
                        metric) -- the scene-dynamics statistic these
                        measurements were taken under.  The drift monitor
                        compares the LIVE stream's change fractions
                        against it: a regime shift that barely moves wire
                        sizes (e.g. more movers over the same background)
                        still multiplies scene activity.  None for
                        synthetic / pre-drift tables (channel disabled)
    residual_spread   : q95 of the calibration clip's own per-frame wire-
                        size residuals (|frame - setting median| / median,
                        the drift monitor's residual unit) across the kept
                        settings -- how noisy this scene/codec regime is
                        even when NOTHING has drifted.  The drift monitor's
                        hysteresis thresholds are learned from it
                        (``drift.learned_thresholds``); None (synthetic /
                        legacy tables) falls back to the hand-set constants
    """
    settings: tuple[K.KnobSetting, ...]
    sizes_sorted: np.ndarray
    best_acc: np.ndarray
    best_idx: np.ndarray
    acc_by_setting: np.ndarray
    size_by_setting: np.ndarray
    proxy: "WireSizeProxy | None" = None
    min_accuracy: float = 0.90
    source: str = "offline"
    activity: float | None = None
    residual_spread: float | None = None

    @property
    def includes_artifact(self) -> bool:
        """Whether knob4 settings survived into this table.  Online
        re-characterization keys its sweep breadth on this: a live table
        trading on knob4 must not lose that axis across a refresh (a table
        that kept none re-sweeps without knob4, the cheaper default)."""
        return any(s.artifact > 0 for s in self.settings)

    def query_size(self, wire_bytes: float) -> tuple[float, int]:
        """size -> (best achievable accuracy, knob-setting index).

        Paper step 2: BST search keyed by image size.  Returns the best
        accuracy among settings whose size fits within ``wire_bytes``.
        """
        pos = int(np.searchsorted(self.sizes_sorted, wire_bytes, side="right")) - 1
        if pos < 0:
            return 0.0, -1
        return float(self.best_acc[pos]), int(self.best_idx[pos])

    def setting_for(self, idx: int) -> K.KnobSetting:
        return self.settings[idx]

    def step_down(self, idx: int, accuracy_floor: float, *,
                  diff: int | None = None) -> int:
        """The next-smaller-size characterized setting that still clears
        ``accuracy_floor`` -- the candidate walk of ``CamBroker.fetch``'s
        wire-size pre-screen.  ``diff`` pins the knob5 axis: the pre-screen
        trades transform fidelity for bytes, it must NOT change the drop
        semantics the controller decided on mid-walk.  Returns -1 when no
        smaller setting qualifies."""
        size = self.size_by_setting[idx]
        best = -1
        best_size = -1.0
        for j, (s, a) in enumerate(zip(self.size_by_setting,
                                       self.acc_by_setting)):
            if diff is not None and self.settings[j].diff != diff:
                continue
            if s < size and a >= accuracy_floor and s > best_size:
                best, best_size = j, float(s)
        return best

    # -- jit-ready views ---------------------------------------------------------
    def as_arrays(self) -> dict[str, np.ndarray]:
        return {
            "sizes_sorted": self.sizes_sorted.astype(np.float32),
            "best_acc": self.best_acc.astype(np.float32),
            "best_idx": self.best_idx.astype(np.int32),
        }


def _build_table(settings, sizes: np.ndarray, accs: np.ndarray,
                 min_accuracy: float,
                 proxy=None, activity: float | None = None,
                 residuals: list | None = None
                 ) -> CharacterizationTable:
    """keep/sort/prefix-max assembly, shared by both engines.

    ``residuals`` (optional, aligned with ``settings``) holds each
    setting's per-frame relative wire-size residuals against its own clip
    median; the q95 over the KEPT settings becomes ``residual_spread`` --
    the monitor only ever observes settings the controller can choose.
    """
    keep = (accs >= min_accuracy) & (sizes > 0)
    settings_kept = tuple(s for s, k in zip(settings, keep) if k)
    sizes_k = sizes[keep]
    accs_k = accs[keep]

    order = np.argsort(sizes_k, kind="stable")
    sizes_sorted = sizes_k[order]
    accs_sorted = accs_k[order]
    idx_sorted = np.arange(len(settings_kept))[order]

    # prefix max of accuracy + the setting achieving it
    best_acc = np.empty_like(accs_sorted)
    best_idx = np.empty(len(accs_sorted), np.int64)
    run_best, run_idx = -1.0, -1
    for i, (a, j) in enumerate(zip(accs_sorted, idx_sorted)):
        if a > run_best:
            run_best, run_idx = a, j
        best_acc[i] = run_best
        best_idx[i] = run_idx

    spread = None
    if residuals is not None:
        pool = [r for r, k in zip(residuals, keep)
                if k and r is not None and len(r)]
        if pool:
            spread = float(np.quantile(np.concatenate(pool), 0.95))

    return CharacterizationTable(
        settings=settings_kept,
        sizes_sorted=sizes_sorted,
        best_acc=best_acc,
        best_idx=best_idx,
        acc_by_setting=accs_k,
        size_by_setting=sizes_k,
        proxy=proxy,
        min_accuracy=min_accuracy,
        activity=activity,
        residual_spread=spread,
    )


def characterize(camera_factory, *, clip_len: int = 24,
                 min_accuracy: float = 0.90,
                 include_artifact: bool = False,
                 detector_thresh: float = 28.0,
                 engine: str = "auto") -> CharacterizationTable:
    """Sweep the knob grid on a calibration clip; build the tables.

    ``camera_factory()`` must return a fresh, identically-seeded
    ``SyntheticCamera`` so every knob setting sees the same clip.

    ``engine`` selects the sweep implementation: ``"batched"`` (the
    device-resident grid engine, knob4 included when asked), ``"reference"``
    (the per-frame NumPy oracle), or ``"auto"`` (batched whenever the camera
    geometry supports it -- non-BGR and odd-geometry cameras fall back to
    reference).  ``engine="batched"`` raises ``ValueError`` on unsupported
    geometry instead of silently degrading.
    """
    cam = camera_factory()
    bg = cam.background
    clip = [cam.next_frame() for _ in range(clip_len)]

    batched_ok = (bg.ndim == 3 and bg.shape[2] == 3
                  and bg.shape[0] % 2 == 0 and bg.shape[1] % 2 == 0)
    if engine == "auto":
        engine = "batched" if batched_ok else "reference"
    if engine == "batched":
        if not batched_ok:
            raise ValueError(
                f"engine='batched' needs an even-dimension 3-channel "
                f"background (4:2:0-subsample-able planes); got shape "
                f"{bg.shape}.  Use engine='reference' for odd geometries, "
                f"or engine='auto' to fall back automatically.")
        from repro.core import grid_engine
        grid = grid_engine.run_grid(bg, [f for _, f, _ in clip],
                                    detector_thresh=detector_thresh,
                                    include_artifact=include_artifact)
        return table_from_grid(grid, [gt for _, _, gt in clip],
                               min_accuracy=min_accuracy,
                               include_artifact=include_artifact)
    elif engine == "reference":
        settings, sizes, accs, residuals = _sweep_reference(
            bg, clip, include_artifact=include_artifact,
            detector_thresh=detector_thresh)
    else:
        raise ValueError(f"unknown characterization engine {engine!r}")
    fracs = [K.change_fraction(clip[i][1], clip[i - 1][1])
             for i in range(1, clip_len)]
    activity = float(np.mean([f for f in fracs if f is not None])) \
        if fracs else None
    return _build_table(settings, sizes, accs, min_accuracy,
                        activity=activity, residuals=residuals)


# =============================================================================
# Batched engine (device grid sweep + wire-size proxy)
# =============================================================================


def table_from_grid(grid: "GridCharacterization", gts: list[np.ndarray], *,
                    min_accuracy: float = 0.90,
                    include_artifact: bool = False) -> CharacterizationTable:
    """Score a batched grid sweep into a ``CharacterizationTable``.

    ``gts`` is one ground-truth box array per clip frame.  Online
    re-characterization (``grid_engine.refresh_tables``) passes the
    full-quality combo's own detections here, making accuracies normalized
    F1 against the unmodified stream -- the controller's actual trade
    currency -- without needing labels at runtime.
    """
    clip_len = len(gts)
    if include_artifact and not grid.include_artifact:
        raise ValueError("grid was run without include_artifact; re-run "
                         "run_grid(include_artifact=True)")
    settings = K.enumerate_settings(include_artifact=include_artifact)

    # per-frame match counts per transform combo, computed once and summed
    # per setting according to its drop pattern (knob5 never changes
    # surviving pixels, so detections are shared across diff thresholds)
    counts: dict[tuple[int, int, int, int], np.ndarray] = {}
    for combo, boxes in grid.dets.items():
        counts[combo] = np.asarray(
            [det.match_f1(gts[fi], boxes[fi]) for fi in range(clip_len)],
            np.int64)
    gt_sizes = np.asarray([len(gt) for gt in gts], np.int64)
    base = counts[(0, 0, 0, 0)].sum(axis=0)
    base_f1 = det.f1_from_counts(*base)

    drop_patterns = {di: grid.drop_pattern(thresh)
                     for di, thresh in enumerate(K.DIFF_THRESHOLDS)}

    sizes = np.zeros(len(settings))
    accs = np.zeros(len(settings))
    residuals: list = [None] * len(settings)
    for si, s in enumerate(settings):
        combo = (s.resolution, s.colorspace, s.blur, s.artifact)
        drops = drop_patterns[s.diff]
        kept = ~drops
        c = counts[combo][kept].sum(axis=0)
        # dropped frames: the application never saw them -> all GT becomes FN
        tp, fp, fn = int(c[0]), int(c[1]), int(c[2] + gt_sizes[drops].sum())
        f1 = det.f1_from_counts(tp, fp, fn)
        accs[si] = f1 / base_f1 if base_f1 > 0 else 0.0
        kept_sizes = grid.sizes[combo][kept[:clip_len]]
        sizes[si] = float(np.median(kept_sizes)) if kept_sizes.size else 0.0
        if kept_sizes.size:
            # per-frame residuals in the drift monitor's own unit
            # (drift.relative_size_error: denominator floored at 1 byte)
            p = max(sizes[si], 1.0)
            residuals[si] = np.abs(kept_sizes - p) / p
    # scene-activity statistic: mean consecutive-frame change fraction of
    # the calibration clip (the grid's knob5 matrix holds exactly these
    # counts) -- the drift monitor's reference point for this table
    activity = None
    if clip_len > 1:
        consec = [grid.change_fraction(i, i - 1) for i in range(1, clip_len)]
        activity = float(np.mean(consec))
    return _build_table(settings, sizes, accs, min_accuracy,
                        proxy=grid.proxy, activity=activity,
                        residuals=residuals)


# =============================================================================
# Reference engine (the seed per-frame NumPy path, kept as the oracle)
# =============================================================================


def _sweep_reference(bg, clip, *, include_artifact: bool,
                     detector_thresh: float):
    """Per-frame sweep with exact zlib wire sizes and the host detector.

    Fast path: knob5 (frame differencing) only *drops* frames -- it never
    changes surviving pixels -- so per-frame detections are computed once per
    (resolution, colorspace, blur[, artifact]) combo and reused across all
    diff thresholds; per-threshold drop patterns are computed once on the raw
    stream.  This turns an O(|grid| * clip) detector sweep into
    O(|grid|/n_diff * clip), matching how the paper's own campaign would be
    run (differencing is a transport decision, not an image transform).
    """
    clip_len = len(clip)
    h, w = bg.shape[:2]
    baseline = []
    for _, frame, gt in clip:
        boxes = det.detect(frame, bg, thresh=detector_thresh, scale_to=(h, w))
        baseline.append((gt, boxes))

    settings = K.enumerate_settings(include_artifact=include_artifact)

    # -- drop patterns per diff threshold (depends only on the raw stream) ----
    drop_patterns: dict[int, np.ndarray] = {}
    for di, thresh in enumerate(K.DIFF_THRESHOLDS):
        drops = np.zeros(clip_len, bool)
        last_sent = None
        for fi, (_, frame, _) in enumerate(clip):
            if K.frame_difference(frame, last_sent, thresh):
                drops[fi] = True
            else:
                last_sent = frame
        drop_patterns[di] = drops

    # -- per-transform detections (diff dimension factored out) ---------------
    cache: dict[tuple[int, int, int, int], tuple[list[np.ndarray], np.ndarray]] = {}
    bg_memo = K.TransformMemo(bg)

    def transform_results(s: K.KnobSetting):
        key = (s.resolution, s.colorspace, s.blur, s.artifact)
        if key in cache:
            return cache[key]
        tkey = K.KnobSetting(s.resolution, s.colorspace, s.blur, s.artifact, 0)
        bg_t = bg_memo.get(tkey)             # subscriber's degraded background
        dets: list[np.ndarray] = []
        wires = np.zeros(clip_len)
        for fi, (_, frame, _) in enumerate(clip):
            r = K.apply_knobs(frame, dataclasses.replace(tkey, diff=0),
                              background=bg, last_sent=None)
            assert r.frame is not None
            wires[fi] = r.wire_bytes
            dets.append(det.detect(r.frame, bg_t, thresh=detector_thresh,
                                   scale_to=(h, w)))
        cache[key] = (dets, wires)
        return cache[key]

    sizes = np.zeros(len(settings))
    accs = np.zeros(len(settings))
    residuals: list = [None] * len(settings)
    for si, setting in enumerate(settings):
        dets, wires = transform_results(setting)
        drops = drop_patterns[setting.diff]
        results = []
        kept_wires = []
        for fi, (_, _, gt) in enumerate(clip):
            if drops[fi]:
                results.append((gt, np.zeros((0, 4), np.float32)))
            else:
                results.append((gt, dets[fi]))
                kept_wires.append(wires[fi])
        sizes[si] = float(np.median(kept_wires)) if kept_wires else 0.0
        if kept_wires:
            p = max(sizes[si], 1.0)
            residuals[si] = np.abs(np.asarray(kept_wires) - p) / p
        accs[si] = det.normalized_f1(results, baseline)
    return settings, sizes, accs, residuals
