"""The Mez API (paper Section 3.1, Fig. 7) - five calls:

    Connect(url) -> ID
    Publish(imageStream)
    GetCameraInfo() -> list[cameraIDs]
    Subscribe(applicationID, cameraID, tStart, tStop, latency, accuracy)
        -> imageStream
    Unsubscribe(applicationID, cameraID) -> status

Data model (Section 3.2): key-value pairs, key = frame timestamp, value =
frame, chronological order, at-most-once delivery (resend is an application-
level decision).

This module defines the wire-level records and the abstract interface both
Mez and the NATS-like baseline implement, so benchmarks can swap systems.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterator, Protocol

import numpy as np

__all__ = ["DeliveredFrame", "SubscribeSpec", "RPCTimeout", "BrokerDown",
           "MessagingSystem", "Status"]


class RPCTimeout(TimeoutError):
    """An RPC exceeded its deadline (the paper's failure-detection signal)."""


class BrokerDown(RuntimeError):
    """Raised by a crashed component when invoked (manifests as RPCTimeout at
    the caller after the deadline)."""


class Status(enum.Enum):
    OK = "ok"
    FAIL = "fail"
    INFEASIBLE = "infeasible"     # latency/accuracy bounds can't both be met


@dataclasses.dataclass(frozen=True)
class LatencyBreakdown:
    """Per-frame component latencies, seconds (paper Fig. 16)."""
    publish_api: float = 0.0
    controller: float = 0.0        # knob decision + frame modification
    log_copy: float = 0.0          # camera-node log -> transmit buffer
    network: float = 0.0           # wireless transfer
    broker_processing: float = 0.0 # edge-side append + dispatch
    subscribe_api: float = 0.0

    @property
    def total(self) -> float:
        return (self.publish_api + self.controller + self.log_copy
                + self.network + self.broker_processing + self.subscribe_api)


@dataclasses.dataclass(frozen=True)
class DeliveredFrame:
    camera_id: str
    timestamp: float
    frame: np.ndarray | None       # None => dropped (at-most-once + knob5)
    wire_bytes: int
    latency: LatencyBreakdown
    knob_index: int                # -1 = unmodified
    infeasible: bool = False


@dataclasses.dataclass(frozen=True)
class SubscribeSpec:
    application_id: str
    camera_id: str
    t_start: float
    t_stop: float                  # may be in the future (paper Section 3.1)
    latency: float                 # upper bound, seconds
    accuracy: float                # lower bound, normalized F1


class MessagingSystem(Protocol):
    def connect(self, url: str) -> str: ...
    def get_camera_info(self) -> list[str]: ...
    def subscribe(self, spec: SubscribeSpec) -> Iterator[DeliveredFrame]: ...
    def unsubscribe(self, application_id: str, camera_id: str) -> Status: ...
