"""The Mez API (paper Section 3.1, Fig. 7) - five calls:

    Connect(url) -> ID
    Publish(imageStream)
    GetCameraInfo() -> list[cameraIDs]
    Subscribe(applicationID, cameraID, tStart, tStop, latency, accuracy)
        -> imageStream
    Unsubscribe(applicationID, cameraID) -> status

Data model (Section 3.2): key-value pairs, key = frame timestamp, value =
frame, chronological order, at-most-once delivery (resend is an application-
level decision).

This module defines the wire-level records and the abstract interfaces the
Mez implementations and the NATS-like baseline share, so benchmarks can swap
systems.  Two client surfaces exist:

v1 (the paper's five calls): ``MessagingSystem`` -- blocking single-camera
pull iterators.  Kept working as a compat shim on top of v2.

v2 (session API): ``SessionedMessagingSystem`` -- a client opens a session,
subscribes one-or-many cameras per ``Subscription``, and drains frames in
timestamp-merged ``FrameBatch`` units sized for jitted detector batches.
QoS bounds renegotiate live via ``QosUpdate`` (no teardown/resubscribe), and
failures (``INFEASIBLE``, crashed brokers) surface on an event stream
instead of per-frame flags.  See ``repro.core.session`` for the handle
classes (``MezClient`` / ``Session`` / ``Subscription``).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterator, Protocol, Sequence

import numpy as np

__all__ = ["DeliveredFrame", "SubscribeSpec", "RPCTimeout", "BrokerDown",
           "MessagingSystem", "Status", "FrameBatch", "QosUpdate",
           "SubscriptionState", "SessionEvent", "EventKind",
           "SessionedMessagingSystem", "SloClass", "SLO_CLASSES",
           "resolve_slo", "QosBounds", "SubscriptionOptions",
           "AdmissionRejected", "CameraQosResult", "BoundedEventBuffer"]


class RPCTimeout(TimeoutError):
    """An RPC exceeded its deadline (the paper's failure-detection signal)."""


class BrokerDown(RuntimeError):
    """Raised by a crashed component when invoked (manifests as RPCTimeout at
    the caller after the deadline)."""


class Status(enum.Enum):
    OK = "ok"
    FAIL = "fail"
    INFEASIBLE = "infeasible"     # latency/accuracy bounds can't both be met


class AdmissionRejected(RuntimeError):
    """Fleet-wide admission control rejected a subscription: the aggregate
    wire budget cannot fit the newcomer's accuracy-floor demand even after
    degrading every lower-priority tenant to its floor (raised only under
    ``SubscriptionOptions(admission="reject")``; the default ``"degrade"``
    policy admits at a capped budget instead)."""

    def __init__(self, message: str, *, demand_bps: float = 0.0,
                 budget_bps: float = 0.0) -> None:
        super().__init__(message)
        self.demand_bps = demand_bps
        self.budget_bps = budget_bps


# =============================================================================
# Multi-tenant SLO classes + subscription configuration
# =============================================================================


@dataclasses.dataclass(frozen=True)
class SloClass:
    """A per-tenant service class: default QoS bounds plus a preemption
    priority.  Under fleet-wide oversubscription, admission control degrades
    lower-priority classes first (``best_effort`` before ``silver`` before
    ``gold``); a class is never degraded to make room for a lower or equal
    priority newcomer."""
    name: str
    max_latency: float             # default latency upper bound, seconds
    min_accuracy: float            # default accuracy floor, normalized F1
    priority: int                  # higher = preempted later


SLO_CLASSES: dict[str, SloClass] = {
    "gold": SloClass("gold", max_latency=0.050, min_accuracy=0.95,
                     priority=2),
    "silver": SloClass("silver", max_latency=0.100, min_accuracy=0.92,
                       priority=1),
    "best_effort": SloClass("best_effort", max_latency=0.250,
                            min_accuracy=0.80, priority=0),
}


def resolve_slo(slo: "SloClass | str | None") -> "SloClass | None":
    """Accept a class name (``"gold"``), an ``SloClass``, or ``None``."""
    if slo is None or isinstance(slo, SloClass):
        return slo
    try:
        return SLO_CLASSES[slo]
    except KeyError:
        raise ValueError(f"unknown SLO class {slo!r}; expected one of "
                         f"{sorted(SLO_CLASSES)} or an SloClass") from None


@dataclasses.dataclass(frozen=True)
class QosBounds:
    """The (latency upper bound, accuracy lower bound) pair of a
    subscription -- the paper's two Subscribe() QoS arguments."""
    latency: float                 # seconds
    accuracy: float                # normalized F1


@dataclasses.dataclass(frozen=True)
class SubscriptionOptions:
    """Everything about a subscription that is not a QoS bound.

    Replaces the kwarg sprawl on ``Session.subscribe`` /
    ``EdgeBroker.create_subscription`` (the legacy kwargs keep working for
    one release behind a ``DeprecationWarning``).  Frozen so a spec can be
    shared across scenario runs and threads without defensive copies.
    """
    controlled: bool = True        # run the latency controller
    feedback_window: int = 8       # latency samples fed back per poll
    credit_limit: int = 2          # per-camera in-flight frame credits
    fleet: bool = False            # one fused compiled tick for all lanes
    mesh: object = None            # device mesh / axis size for shard_map
    auto_recharacterize: bool = False  # drift-triggered table re-sweeps
    drift_config: object = None    # DriftConfig override
    tenant: str | None = None      # tenant identity (defaults to session's)
    slo: "SloClass | str | None" = None  # service class (name or instance)
    admission: str = "degrade"     # oversubscription policy:
                                   #   "degrade" -> cap budgets, admit
                                   #   "reject"  -> raise AdmissionRejected


@dataclasses.dataclass(frozen=True)
class LatencyBreakdown:
    """Per-frame component latencies, seconds (paper Fig. 16)."""
    publish_api: float = 0.0
    controller: float = 0.0        # knob decision + frame modification
    log_copy: float = 0.0          # camera-node log -> transmit buffer
    network: float = 0.0           # wireless transfer
    broker_processing: float = 0.0 # edge-side append + dispatch
    subscribe_api: float = 0.0

    @property
    def total(self) -> float:
        return (self.publish_api + self.controller + self.log_copy
                + self.network + self.broker_processing + self.subscribe_api)


@dataclasses.dataclass(frozen=True)
class DeliveredFrame:
    camera_id: str
    timestamp: float
    frame: np.ndarray | None       # None => dropped (at-most-once + knob5)
    wire_bytes: int
    latency: LatencyBreakdown
    knob_index: int                # -1 = unmodified
    infeasible: bool = False


@dataclasses.dataclass(frozen=True)
class SubscribeSpec:
    application_id: str
    camera_id: str
    t_start: float
    t_stop: float                  # may be in the future (paper Section 3.1)
    latency: float                 # upper bound, seconds
    accuracy: float                # lower bound, normalized F1


class MessagingSystem(Protocol):
    def connect(self, url: str) -> str: ...
    def get_camera_info(self) -> list[str]: ...
    def subscribe(self, spec: SubscribeSpec) -> Iterator[DeliveredFrame]: ...
    def unsubscribe(self, application_id: str, camera_id: str) -> Status: ...


# =============================================================================
# v2 session API records
# =============================================================================


class SubscriptionState(enum.Enum):
    ACTIVE = "active"       # at least one camera still serving frames
    DRAINED = "drained"     # every camera exhausted its [t_start, t_stop]
    FAILED = "failed"       # no camera active and at least one crashed
    CLOSED = "closed"       # explicitly closed (idempotent)


class EventKind(enum.Enum):
    INFEASIBLE = "infeasible"      # controller: bounds can't both be met
    RPC_TIMEOUT = "rpc_timeout"    # camera node crashed / unreachable
    TABLE_REFRESH = "table_refresh"  # drift monitor auto-recharacterized a
                                     # camera's knob tables (detail says
                                     # whether the re-sweep succeeded)
    ADMISSION_REJECTED = "admission_rejected"  # fleet wire budget can't fit
                                               # the subscription (session-
                                               # level event)
    TENANT_DEGRADED = "tenant_degraded"  # admission control capped this
                                         # subscription's wire budget below
                                         # its nominal demand
    EVENTS_DROPPED = "events_dropped"  # the bounded event buffer evicted
                                       # undrained events since the last
                                       # drain (detail carries the count)
    BROKER_OVERLOAD = "broker_overload"  # a federated broker crossed its
                                         # wire-budget / poll-latency
                                         # watermark; the herd is shedding
                                         # lanes off it (detail names the
                                         # broker and the trigger)
    CAMERA_MIGRATED = "camera_migrated"  # the herd moved this camera to
                                         # another broker (detail carries
                                         # "broker i -> j"); polling
                                         # continues transparently


@dataclasses.dataclass(frozen=True)
class SessionEvent:
    """Out-of-band notification on a subscription's event stream (v2 replaces
    the v1 pattern of burying failures in per-frame flags / raised mid-
    iteration exceptions)."""
    kind: EventKind
    camera_id: str
    subscription_id: str
    timestamp: float               # stream position when the event fired
    detail: str = ""


class BoundedEventBuffer:
    """Bounded event queue for a subscription's / session's out-of-band
    notifications.

    Mirrors ``HostLog``'s evict-before-overwrite contract: at capacity the
    OLDEST undrained event is evicted first -- never silently overwritten in
    place -- and every eviction is counted.  A client that polls forever but
    never drains ``events()`` therefore costs O(capacity) memory, not O(run
    length), and the loss is *observable*: the next ``drain()`` call returns
    one ``EVENTS_DROPPED`` marker event ahead of the surviving events, with
    the eviction count since the previous drain in ``detail``.

    ``owner`` is the subscription/session id stamped on marker events (set
    by the broker right after the owning record is created).
    """

    def __init__(self, capacity: int = 256, owner: str = ""):
        self.capacity = int(capacity)
        self.owner = owner
        self._events: list[SessionEvent] = []
        self.dropped = 0             # lifetime evictions
        self._dropped_pending = 0    # evictions since the last drain
        self._last_evicted_ts = 0.0

    def append(self, event: SessionEvent) -> None:
        if len(self._events) >= self.capacity:
            evicted = self._events.pop(0)
            self._last_evicted_ts = evicted.timestamp
            self.dropped += 1
            self._dropped_pending += 1
        self._events.append(event)

    def drain(self) -> list[SessionEvent]:
        """Hand over (and clear) the pending events; when evictions happened
        since the last drain, the first returned event is an
        ``EVENTS_DROPPED`` marker accounting for them."""
        out, self._events = self._events, []
        if self._dropped_pending:
            out.insert(0, SessionEvent(
                EventKind.EVENTS_DROPPED, "", self.owner,
                self._last_evicted_ts,
                f"{self._dropped_pending} events evicted before drain "
                f"(buffer capacity {self.capacity})"))
            self._dropped_pending = 0
        return out

    def clear(self) -> None:
        self._events.clear()

    def __len__(self) -> int:
        return len(self._events)

    def __iter__(self):
        return iter(self._events)


@dataclasses.dataclass(frozen=True)
class CameraQosResult:
    """Per-camera outcome of one QoS renegotiation."""
    camera_id: str
    status: Status
    recharacterized: bool = False


@dataclasses.dataclass(frozen=True)
class QosUpdate:
    """Result of a live QoS renegotiation.

    One shape for both surfaces: ``Subscription.update_qos`` returns an
    update covering one subscription, ``Session.update_qos`` returns ONE
    merged update covering every subscription in the session (it used to
    return a list).  ``per_camera`` carries the per-camera results,
    ``subscription_ids`` the subscriptions touched, and ``tenant`` /
    ``slo_class`` the tenant identity the renegotiation ran under.
    """
    latency: float                 # new upper bound, seconds
    accuracy: float                # new lower bound, normalized F1
    status: Status
    applied_cameras: tuple[str, ...]
    subscription_id: str = ""
    # cameras whose characterization tables were re-swept online as part of
    # this renegotiation (``update_qos(recharacterize=True)``)
    recharacterized: tuple[str, ...] = ()
    per_camera: tuple[CameraQosResult, ...] = ()
    tenant: str = ""
    slo_class: str = ""
    subscription_ids: tuple[str, ...] = ()

    @classmethod
    def merge(cls, updates: "Sequence[QosUpdate]") -> "QosUpdate":
        """Fold per-subscription updates into one session-level update."""
        if not updates:
            return cls(0.0, 0.0, Status.FAIL, (), subscription_ids=())
        applied: list[str] = []
        rechar: list[str] = []
        per_cam: list[CameraQosResult] = []
        for u in updates:
            applied.extend(c for c in u.applied_cameras if c not in applied)
            rechar.extend(c for c in u.recharacterized if c not in rechar)
            per_cam.extend(u.per_camera)
        status = (Status.OK if any(u.status is Status.OK for u in updates)
                  else updates[0].status)
        head = updates[0]
        return cls(head.latency, head.accuracy, status, tuple(applied),
                   subscription_id=head.subscription_id,
                   recharacterized=tuple(rechar),
                   per_camera=tuple(per_cam),
                   tenant=head.tenant, slo_class=head.slo_class,
                   subscription_ids=tuple(u.subscription_id
                                          for u in updates))


@dataclasses.dataclass(frozen=True)
class FrameBatch:
    """One ``poll()`` result: timestamp-merged, at-most-once frames from all
    cameras of a subscription.

    ``frames`` is sorted by (timestamp, camera_id) and may include dropped
    frames (``frame is None`` -- knob5 / at-most-once).  ``stack()`` produces
    a dense float32 payload suitable for a jitted batched detector.
    """
    frames: tuple[DeliveredFrame, ...]
    subscription_id: str = ""

    def __len__(self) -> int:
        return len(self.frames)

    def __bool__(self) -> bool:
        return bool(self.frames)

    def __iter__(self) -> Iterator[DeliveredFrame]:
        return iter(self.frames)

    @property
    def delivered(self) -> tuple[DeliveredFrame, ...]:
        """Frames that carry a payload (dropped frames excluded)."""
        return tuple(f for f in self.frames if f.frame is not None)

    @property
    def dropped(self) -> tuple[DeliveredFrame, ...]:
        return tuple(f for f in self.frames if f.frame is None)

    @property
    def timestamps(self) -> np.ndarray:
        return np.asarray([f.timestamp for f in self.frames], np.float64)

    @property
    def camera_ids(self) -> tuple[str, ...]:
        return tuple(f.camera_id for f in self.frames)

    @property
    def shapes(self) -> tuple[tuple[int, int], ...]:
        """True (H, W) of each delivered payload (pre-padding)."""
        return tuple(np.asarray(f.frame).shape[:2] for f in self.delivered)

    def stack(self, *, batch_size: int | None = None,
              dtype=np.float32) -> tuple[np.ndarray, np.ndarray]:
        """Stack delivered payloads into ``(payload, valid)``.

        ``payload`` is ``[B, Hmax, Wmax, Cmax]`` zero-padded (ragged knob-
        resized frames are padded to the batch max; grayscale is promoted to
        one channel); ``valid`` is a ``[B]`` bool mask.  ``batch_size`` pads
        the batch dimension to a fixed size so a jitted detector sees a
        stable shape across polls (no recompiles).
        """
        frames = [np.atleast_3d(np.asarray(f.frame)) for f in self.delivered]
        n = len(frames)
        b = batch_size if batch_size is not None else n
        if n > b:
            raise ValueError(f"batch_size={b} < {n} delivered frames; "
                             "poll with a smaller max_frames")
        if n == 0:
            return (np.zeros((b, 0, 0, 0), dtype), np.zeros((b,), bool))
        hmax = max(f.shape[0] for f in frames)
        wmax = max(f.shape[1] for f in frames)
        cmax = max(f.shape[2] for f in frames)
        out = np.zeros((b, hmax, wmax, cmax), dtype)
        for i, f in enumerate(frames):
            out[i, : f.shape[0], : f.shape[1], : f.shape[2]] = f
        valid = np.zeros((b,), bool)
        valid[:n] = True
        return out, valid


class SessionedMessagingSystem(Protocol):
    """v2 broker-side surface (what ``repro.core.session.MezClient`` wraps)."""
    def connect(self, url: str) -> str: ...
    def get_camera_info(self) -> list[str]: ...
    def open_session(self, application_id: str) -> str: ...
    def close_session(self, session_id: str) -> Status: ...
    def create_subscription(self, session_id: str,
                            specs: Sequence[SubscribeSpec]) -> str: ...
    def poll_subscription(self, subscription_id: str, *,
                          max_frames: int = 16,
                          deadline: float | None = None) -> FrameBatch: ...
    def update_subscription_qos(self, subscription_id: str, *,
                                latency: float | None = None,
                                accuracy: float | None = None,
                                recharacterize: bool = False) -> QosUpdate: ...
    def close_subscription(self, subscription_id: str) -> Status: ...
    def subscription_events(self, subscription_id: str) -> list[SessionEvent]: ...
    def subscription_state(self, subscription_id: str) -> SubscriptionState: ...
