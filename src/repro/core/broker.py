"""Mez brokers (paper Section 4.1) + the NATS-like baseline (Section 5.2).

Topology (paper Fig. 8): one ``CamBroker`` per IoT camera node (owns the
node's in-memory log and the latency controller), one ``EdgeBroker`` on the
edge server (owns one replicated log per registered camera and implements the
subscriber-facing API).  Frames move camera-log -> edge-log *on demand* --
nothing crosses the wireless channel until a subscriber asks (this limits
channel interference and saves camera-node power).

Simulation model: the system runs single-process on a virtual clock.  Network
latency comes from ``WirelessChannel`` (calibrated to the paper's testbed);
controller/knob overheads are the *measured* knob pipeline cost models; broker
processing costs are small constants.  All components are deterministic given
seeds, which makes the controller's step response (paper Fig. 11) exactly
reproducible.

Fault tolerance (Section 4.4): crash flags on each component; RPCs against a
crashed component raise ``RPCTimeout`` after their deadline (detection is
piggybacked on streaming traffic -- no separate heartbeats); recovery
reconstructs logs from the CRC-checked ``LogSegmentStore``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator

import numpy as np

from repro.core.api import (BrokerDown, DeliveredFrame, LatencyBreakdown,
                            RPCTimeout, Status, SubscribeSpec)
from repro.core.channel import WirelessChannel
from repro.core.characterization import CharacterizationTable, LatencyRegression
from repro.core.controller import ControllerConfig, LatencyController
from repro.core.knobs import apply_knobs, wire_size
from repro.core.log import HostLog, LogSegmentStore

__all__ = ["CamBroker", "EdgeBroker", "NatsLikeSystem", "MezSystem"]

# Broker-side fixed costs (seconds) -- small constants in the paper's Fig. 16
# breakdown ("all processing delays inside the messaging system").
PUBLISH_API_COST = 0.4e-3
SUBSCRIBE_API_COST = 0.6e-3
BROKER_PROC_COST = 0.9e-3
LOG_COPY_COST_PER_MB = 8.0e-3      # frame copy between logs, per
                                   # workload-equivalent MB (paper
                                   # Fig. 16: ~half the controller
                                   # time is the log copy)
RPC_DEADLINE = 0.5                 # seconds of virtual time


class CamBroker:
    """Broker + log + controller on one IoT camera node."""

    def __init__(self, camera_id: str, channel: WirelessChannel, *,
                 log_capacity: int = 2048, distance_m: float = 6.0,
                 fps: float = 5.0, store: LogSegmentStore | None = None):
        self.camera_id = camera_id
        self.channel = channel
        self.distance_m = distance_m
        self.fps = fps
        self.log = HostLog(log_capacity, topic=camera_id)
        self.controller: LatencyController | None = None
        self.store = store
        self.crashed = False
        self._last_sent: np.ndarray | None = None
        self.background: np.ndarray | None = None
        self.infeasible_reported = 0

    # -- internal APIs (paper Fig. 9) -------------------------------------------
    def set_target(self, latency: float, accuracy: float,
                   table: CharacterizationTable,
                   regression: LatencyRegression,
                   config: ControllerConfig | None = None) -> None:
        if self.crashed:
            raise BrokerDown(self.camera_id)
        cfg = config or ControllerConfig(latency_target=latency,
                                         accuracy_target=accuracy)
        cfg = dataclasses.replace(cfg, latency_target=latency,
                                  accuracy_target=accuracy)
        self.controller = LatencyController(cfg, table, regression)

    # -- Publish (camera -> camera-node log) -------------------------------------
    def publish(self, timestamp: float, frame: np.ndarray) -> bool:
        if self.crashed:
            raise BrokerDown(self.camera_id)
        return self.log.append(timestamp, frame)

    # -- on-demand transfer (camera log -> edge, through controller + channel) ---
    def fetch(self, t_start: float, t_stop: float, *,
              latency_feedback: float | None = None,
              controlled: bool = True,
              max_frames: int | None = None) -> list[DeliveredFrame]:
        """Serve the frames in [t_start, t_stop] across the wireless channel.

        ``latency_feedback`` is the subscriber-observed p95 latency of the
        previous window -- the controller's sensor input.  ``max_frames``
        bounds the batch so the subscriber's control loop samples latency at
        its configured interval (paper: "the network latency is measured
        again at the next sampling interval").
        """
        if self.crashed:
            raise BrokerDown(self.camera_id)
        out: list[DeliveredFrame] = []
        knob_idx = -1
        controller_cost = 0.0
        setting = None
        infeasible = False
        if controlled and self.controller is not None and latency_feedback is not None:
            decision = self.controller.update(latency_feedback)
            infeasible = not decision.feasible
            if infeasible:
                self.infeasible_reported += 1
            setting = decision.setting
            knob_idx = decision.setting_index
        elif controlled and self.controller is not None:
            setting = self.controller.current_setting
            knob_idx = self.controller._current

        for ts, frame in self.log.range_query(t_start, t_stop):
            if max_frames is not None and len(out) >= max_frames:
                break
            if setting is not None:
                r = apply_knobs(frame, setting, background=self.background,
                                last_sent=self._last_sent)
                controller_cost = r.overhead_ms * 1e-3
                if r.frame is None:
                    out.append(DeliveredFrame(
                        self.camera_id, ts, None, 0,
                        LatencyBreakdown(controller=controller_cost),
                        knob_idx, infeasible))
                    continue
                self._last_sent = frame
                payload, nbytes = r.frame, r.wire_bytes
            else:
                payload, nbytes = frame, wire_size(frame)
            net = self.channel.transfer(nbytes, fps=self.fps,
                                        distance_m=self.distance_m)
            copy = LOG_COPY_COST_PER_MB * (
                self.channel.scaled_bytes(payload.nbytes) / 1e6)
            out.append(DeliveredFrame(
                self.camera_id, ts, payload, nbytes,
                LatencyBreakdown(publish_api=PUBLISH_API_COST,
                                 controller=controller_cost,
                                 log_copy=copy, network=net),
                knob_idx, infeasible))
        return out

    # -- fault tolerance -----------------------------------------------------------
    def crash(self) -> None:
        self.crashed = True

    def persist(self) -> None:
        if self.store is not None:
            self.store.persist(self.log)

    def recover(self) -> None:
        """Reboot: reconstruct the log from CRC-valid on-disk segments."""
        if self.store is not None:
            restored = self.store.recover(self.camera_id)
            if restored is not None:
                self.log = restored
        self.crashed = False
        self._last_sent = None


class EdgeBroker:
    """Edge-server broker: camera registry + replicated logs + subscriptions."""

    def __init__(self, *, log_capacity: int = 4096,
                 store: LogSegmentStore | None = None):
        self._cams: dict[str, CamBroker] = {}
        self.replicas: dict[str, HostLog] = {}
        self._subs: dict[tuple[str, str], SubscribeSpec] = {}
        self._ids = itertools.count()
        self.log_capacity = log_capacity
        self.store = store
        self.crashed = False

    # -- Mez API -------------------------------------------------------------------
    def connect(self, url: str) -> str:
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        return f"client-{next(self._ids)}"

    def register(self, cam: CamBroker) -> None:
        """Internal API for IoT camera nodes (paper Section 4.1)."""
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        self._cams[cam.camera_id] = cam
        self.replicas[cam.camera_id] = HostLog(self.log_capacity,
                                               topic=cam.camera_id)
        cam.channel.activate(cam.camera_id)

    def unregister(self, camera_id: str) -> None:
        cam = self._cams.pop(camera_id, None)
        if cam is not None:
            cam.channel.deactivate(camera_id)

    def get_camera_info(self) -> list[str]:
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        return sorted(self._cams)

    def subscribe(self, spec: SubscribeSpec, *,
                  controlled: bool = True,
                  feedback_window: int = 8,
                  fetch_window: int = 2) -> Iterator[DeliveredFrame]:
        """Streaming subscription: on-demand transfer + controller feedback.

        Yields frames as they become available in [t_start, t_stop].  The
        subscriber-observed p95 latency over the last ``feedback_window``
        frames is fed back to the camera node's controller each fetch; each
        fetch is capped at ``fetch_window`` frames so the control loop
        samples at its interval rather than bulk-draining the camera log.
        """
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        cam = self._cams.get(spec.camera_id)
        if cam is None:
            raise RPCTimeout(f"unknown camera {spec.camera_id}")
        self._subs[(spec.application_id, spec.camera_id)] = spec
        replica = self.replicas[spec.camera_id]
        window: list[float] = []
        cursor = spec.t_start
        while (spec.application_id, spec.camera_id) in self._subs:
            feedback = (float(np.percentile(window, 95)) if window else None)
            try:
                frames = cam.fetch(cursor, spec.t_stop,
                                   latency_feedback=feedback,
                                   controlled=controlled,
                                   max_frames=fetch_window)
            except BrokerDown as e:
                raise RPCTimeout(str(e)) from e
            if not frames:
                break
            for f in frames:
                cursor = max(cursor, np.nextafter(f.timestamp, np.inf))
                lat = dataclasses.replace(
                    f.latency,
                    broker_processing=BROKER_PROC_COST,
                    subscribe_api=SUBSCRIBE_API_COST)
                g = dataclasses.replace(f, latency=lat)
                if g.frame is not None:
                    replica.append(g.timestamp, g.frame)
                    window.append(g.latency.total)
                    window[:] = window[-feedback_window:]
                yield g
            if cursor > spec.t_stop:
                break

    def unsubscribe(self, application_id: str, camera_id: str) -> Status:
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        return (Status.OK if self._subs.pop((application_id, camera_id), None)
                else Status.FAIL)

    # -- fault tolerance --------------------------------------------------------------
    def crash(self) -> None:
        self.crashed = True

    def persist(self) -> None:
        if self.store is not None:
            for log in self.replicas.values():
                self.store.persist(log)

    def recover(self) -> None:
        if self.store is not None:
            for cid in list(self.replicas):
                restored = self.store.recover(cid)
                if restored is not None:
                    self.replicas[cid] = restored
        self.crashed = False


class MezSystem:
    """Convenience facade wiring cameras + brokers + controller (the thing
    benchmarks instantiate)."""

    def __init__(self, channel: WirelessChannel, *,
                 store: LogSegmentStore | None = None):
        self.channel = channel
        self.edge = EdgeBroker(store=store)
        self.cams: dict[str, CamBroker] = {}

    def add_camera(self, camera_id: str, *, distance_m: float = 6.0,
                   fps: float = 5.0) -> CamBroker:
        cam = CamBroker(camera_id, self.channel, distance_m=distance_m,
                        fps=fps, store=self.edge.store)
        self.cams[camera_id] = cam
        self.edge.register(cam)
        return cam


class NatsLikeSystem:
    """The NATS baseline (paper Section 5.2): low-latency general pub-sub,
    NO latency control, NO storage layer, 1 MB message size limit."""

    MESSAGE_LIMIT = 1_000_000  # bytes

    def __init__(self, channel: WirelessChannel):
        self.channel = channel
        self._cams: dict[str, dict] = {}
        self.rejected_oversize = 0

    def add_camera(self, camera_id: str, *, distance_m: float = 6.0,
                   fps: float = 5.0) -> None:
        self._cams[camera_id] = {"distance": distance_m, "fps": fps}
        self.channel.activate(camera_id)

    def get_camera_info(self) -> list[str]:
        return sorted(self._cams)

    def deliver(self, camera_id: str, timestamp: float, frame: np.ndarray
                ) -> DeliveredFrame:
        """Publish + fan out one frame, unmodified."""
        info = self._cams[camera_id]
        nbytes = wire_size(frame)
        if self.channel.scaled_bytes(nbytes) > self.MESSAGE_LIMIT:
            # Paper: "Since NATS has a 1MB message size limit, DukeMTMC frames
            # cannot be sent/received using NATS."
            self.rejected_oversize += 1
            raise ValueError(
                f"NATS message size limit exceeded: {nbytes} > 1MB")
        net = self.channel.transfer(nbytes, fps=info["fps"],
                                    distance_m=info["distance"])
        lat = LatencyBreakdown(publish_api=PUBLISH_API_COST * 0.5,
                               network=net,
                               broker_processing=BROKER_PROC_COST * 0.4,
                               subscribe_api=SUBSCRIBE_API_COST * 0.5)
        return DeliveredFrame(camera_id, timestamp, frame, nbytes, lat, -1)
