"""Mez brokers (paper Section 4.1) + the NATS-like baseline (Section 5.2).

Topology (paper Fig. 8): one ``CamBroker`` per IoT camera node (owns the
node's in-memory log and the latency controller), one ``EdgeBroker`` on the
edge server (owns one replicated log per registered camera and implements the
subscriber-facing API).  Frames move camera-log -> edge-log *on demand* --
nothing crosses the wireless channel until a subscriber asks (this limits
channel interference and saves camera-node power).

Simulation model: the system runs single-process on a virtual clock.  Network
latency comes from ``WirelessChannel`` (calibrated to the paper's testbed);
controller/knob overheads are the *measured* knob pipeline cost models; broker
processing costs are small constants.  All components are deterministic given
seeds, which makes the controller's step response (paper Fig. 11) exactly
reproducible.

Fault tolerance (Section 4.4): crash flags on each component; RPCs against a
crashed component raise ``RPCTimeout`` after their deadline (detection is
piggybacked on streaming traffic -- no separate heartbeats); recovery
reconstructs logs from the CRC-checked ``LogSegmentStore``.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
import warnings
from collections import OrderedDict
from typing import Iterator, Sequence

import numpy as np

from repro.core.api import (AdmissionRejected, BoundedEventBuffer, BrokerDown,
                            CameraQosResult, DeliveredFrame, EventKind,
                            FrameBatch, LatencyBreakdown, QosUpdate,
                            RPCTimeout, SessionEvent, SloClass, Status,
                            SubscribeSpec, SubscriptionOptions,
                            SubscriptionState, resolve_slo)
from repro.core.channel import WirelessChannel
from repro.core.characterization import CharacterizationTable, LatencyRegression
from repro.core.controller import (ControlDecision, ControllerConfig,
                                   FleetController, FleetTickResult,
                                   JaxControllerTables, LatencyController,
                                   swap_tables)
from repro.core.drift import DriftConfig, DriftMonitor, relative_size_error
from repro.core import knobs as K
from repro.core.knobs import wire_size
from repro.core.log import HostLog, LogSegmentStore
from repro.kernels import frame_knobs as FK

__all__ = ["CamBroker", "EdgeBroker", "NatsLikeSystem", "MezSystem",
           "SharedFrameCache"]

# sentinel for deprecated create_subscription kwargs (None is meaningful)
_UNSET = object()

# Broker-side fixed costs (seconds) -- small constants in the paper's Fig. 16
# breakdown ("all processing delays inside the messaging system").
PUBLISH_API_COST = 0.4e-3
SUBSCRIBE_API_COST = 0.6e-3
BROKER_PROC_COST = 0.9e-3
LOG_COPY_COST_PER_MB = 8.0e-3      # frame copy between logs, per
                                   # workload-equivalent MB (paper
                                   # Fig. 16: ~half the controller
                                   # time is the log copy)
RPC_DEADLINE = 0.5                 # seconds of virtual time

# Online re-characterization / pre-screen knobs.
TABLE_CAPACITY = 512               # padded JaxControllerTables rows: tables
                                   # of any kept-set size share one compiled
                                   # controller step (no recompile on swap)
RECHAR_CLIP_LEN = 16               # log-tail frames per online re-sweep
PRESCREEN_SLACK = 1.25             # proxy overshoot tolerance vs the size
                                   # budget before stepping a setting down
PRESCREEN_MAX_CANDIDATES = 3       # bounded candidate walk per frame
DRIFT_ACTIVITY_FLOOR = 0.01        # activity-residual denominator floor
                                   # (fraction of pixels): sub-point
                                   # differences in changed-pixel fraction
                                   # are mover jitter, not a regime change
                                   # -- without the floor a near-static
                                   # calibration clip makes the RELATIVE
                                   # residual ill-conditioned


class SharedFrameCache:
    """Fleet-shared degraded-frame cache, keyed ``(camera, timestamp,
    transform key)``.

    Promotion of ``CamBroker``'s per-camera payload cache to the edge: N
    tenants subscribed to the same camera at the same operating point pay
    ONE knob transform + deflate instead of N.  Entries are the same
    mutable ``[payload, wire_bytes|None]`` pairs the per-camera cache used
    (deflate still fills in lazily, only for frames actually shipped), so
    promotion changes cost accounting only -- never payload bytes.

    One instance lives on the ``EdgeBroker`` and is attached to every
    ``CamBroker`` at ``register()``; a camera invalidates exactly its own
    keys on background change / recovery / re-characterization.  Hit/miss
    counters feed the multi-tenant benchmark's hit-rate gate.

    Eviction is LRU: a ``get`` hit refreshes the entry's recency, so under
    sustained tenant churn the entries every still-subscribed tenant reuses
    each poll outlive the one-shot entries of departed tenants.  (Insertion-
    order eviction here made the hit rate dip during churn floods: the
    oldest-*inserted* entry is usually the hottest one.)
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._entries: OrderedDict[tuple, list] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: tuple) -> list | None:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
        else:
            self.hits += 1
            self._entries.move_to_end(key)         # LRU: a hit is a use
        return entry

    def put(self, key: tuple, entry: list) -> None:
        if key in self._entries:
            self._entries.move_to_end(key)
        elif len(self._entries) >= self.capacity:  # bounded: LRU evict
            self._entries.popitem(last=False)
            self.evictions += 1
        self._entries[key] = entry

    def invalidate(self, camera_id: str) -> None:
        """Drop every entry of one camera (its transform inputs changed)."""
        stale = [k for k in self._entries if k[0] == camera_id]
        for k in stale:
            del self._entries[k]

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self) -> int:
        return len(self._entries)


class CamBroker:
    """Broker + log + controller on one IoT camera node."""

    def __init__(self, camera_id: str, channel: WirelessChannel, *,
                 log_capacity: int = 2048, distance_m: float = 6.0,
                 fps: float = 5.0, store: LogSegmentStore | None = None):
        self.camera_id = camera_id
        self.channel = channel
        self.distance_m = distance_m
        self.fps = fps
        self.log = HostLog(log_capacity, topic=camera_id)
        self.controller: LatencyController | None = None
        # device-array twin of the controller's tables, padded to
        # TABLE_CAPACITY: a jitted controller_step consumer reads these and
        # survives online re-characterization without recompiling
        self.jax_tables: JaxControllerTables | None = None
        # version counters are read by FleetController.sync from the poll
        # thread while re-characterization bumps them; one mutex covers both
        self._version_lock = threading.Lock()
        self.table_version = 0  # guarded-by: _version_lock
        # bumped on every retarget/set_target: a FleetController diffing
        # this counter knows when to rewrite the camera's params lane
        self.qos_version = 0    # guarded-by: _version_lock
        self.store = store
        self.crashed = False
        self._last_sent: np.ndarray | None = None
        self._background: np.ndarray | None = None
        self._bg_memo: K.TransformMemo | None = None
        # (timestamp, transform key) -> [payload, wire_bytes|None]: fan-out
        # of one camera to several subscriptions reuses the knob transform +
        # deflate instead of recomputing them per fetch (simulated latency
        # numbers are untouched -- the cost model still charges the camera's
        # per-frame modification overhead).  wire_bytes stays None until a
        # frame is actually shipped: the pre-screen only ever needs the
        # payload + proxy features, never exact deflate.
        self._payload_cache: dict[tuple, list] = {}
        # edge-attached shared degraded-frame cache (multi-tenant fan-out);
        # None until EdgeBroker.register(), then transforms are shared
        # across every camera/subscription of the edge
        self.shared_cache: SharedFrameCache | None = None
        # per-frame scene-activity fractions (knob5's change metric)
        # observed by fetch since the last drain -- the drift monitor's
        # second channel (bounded; drained per poll by _drift_tick).
        # _prev_frame tracks the last frame fetch PROCESSED (shipped or
        # dropped): an observation is recorded only when the comparison
        # base was the immediately preceding frame, so the statistic
        # matches the table's CONSECUTIVE-frame activity -- comparing
        # against an older last-sent frame (motion accumulated across
        # knob5 drops) would bias the residual upward on a quiet scene
        self._activity_obs: list[float] = []
        self._prev_frame: np.ndarray | None = None
        # last successful re-sweep's (log state, sweep params): a repeat
        # request over the SAME published frames (e.g. a session-level
        # update_qos fanning out over subscriptions sharing this camera)
        # is a no-op instead of a redundant grid sweep
        self._rechar_memo: tuple | None = None
        self.payload_cache_hits = 0
        self.infeasible_reported = 0
        self.prescreen_evals = 0
        self.prescreen_stepdowns = 0

    # -- background model (knob4 + subscriber-side degradation) ------------------
    @property
    def background(self) -> np.ndarray | None:
        return self._background

    @background.setter
    def background(self, bg: np.ndarray | None) -> None:
        self._background = bg
        self._bg_memo = K.TransformMemo(bg) if bg is not None else None
        self._clear_payload_cache()
        self._rechar_memo = None           # sweeps keyed the old background

    def _clear_payload_cache(self) -> None:
        """Invalidate this camera's cached transforms (private dict AND its
        keys in the edge-shared cache): the transform inputs changed."""
        self._payload_cache.clear()
        if self.shared_cache is not None:
            self.shared_cache.invalidate(self.camera_id)

    def degraded_background(self, setting: K.KnobSetting) -> np.ndarray | None:
        """The camera's background model pushed through ``setting``'s
        transform pipeline, memoized per (resolution, colorspace, blur).

        Subscribers run background subtraction against the received
        stream's statistics, so they need the background degraded exactly
        like the frames -- computing that once per knob setting instead of
        once per frame is the point of the memo (the paper's knob pipeline
        budget is <10 ms/frame; a redundant background transform alone
        costs ~2 ms)."""
        if self._bg_memo is None:
            return None
        return self._bg_memo.get(setting)

    # -- internal APIs (paper Fig. 9) -------------------------------------------
    def set_target(self, latency: float, accuracy: float,
                   table: CharacterizationTable,
                   regression: LatencyRegression,
                   config: ControllerConfig | None = None) -> None:
        if self.crashed:
            raise BrokerDown(self.camera_id)
        cfg = config or ControllerConfig(latency_target=latency,
                                         accuracy_target=accuracy)
        cfg = dataclasses.replace(cfg, latency_target=latency,
                                  accuracy_target=accuracy)
        self.controller = LatencyController(cfg, table, regression)
        self._install_jax_tables(table)
        with self._version_lock:
            self.qos_version += 1
        self._rechar_memo = None           # externally supplied tables

    def _install_jax_tables(self, table: CharacterizationTable) -> None:
        fresh = JaxControllerTables.from_table(
            table, capacity=max(TABLE_CAPACITY, len(table.settings)))
        self.jax_tables = swap_tables(self.jax_tables, fresh)
        with self._version_lock:
            self.table_version += 1
        # payloads cached under the superseded table are stale: a hot-swap
        # (set_target / staleness injection / recharacterize) may recalibrate
        # what a given (camera, ts, setting) key should serve, so a post-swap
        # hit must never return a pre-swap transform
        self._clear_payload_cache()

    def recharacterize(self, *, clip_len: int = RECHAR_CLIP_LEN,
                       min_accuracy: float | None = None,
                       include_artifact: bool | None = None,
                       detector_thresh: float = 28.0) -> bool:
        """Re-sweep the knob grid over this camera's OWN recent frames and
        hot-swap the result into the live controller (host + jit twin).

        The clip is the log tail (what the camera actually published just
        now), the background is the installed model, and accuracies are
        normalized against the full-quality stream's detections -- no
        labels needed.  ``min_accuracy`` and ``include_artifact`` default
        to the LIVE table's own floor and knob4 coverage, so a routine
        ``update_qos(recharacterize=True)`` refreshes measurements without
        silently reshaping the controller's trade space.  Returns False
        (leaving the stale tables serving) when the broker has no
        controller/background yet, the log is too short, the camera
        geometry is outside the batched engine's coverage, or the re-sweep
        kept no settings.
        """
        if self.crashed:
            raise BrokerDown(self.camera_id)
        if self.controller is None or self._background is None:
            return False
        live = self.controller.table
        if min_accuracy is None:
            min_accuracy = getattr(live, "min_accuracy", 0.90)
        if include_artifact is None:
            include_artifact = getattr(live, "includes_artifact", False)
        memo_key = (self.log.appends, clip_len, min_accuracy,
                    include_artifact, detector_thresh)
        if memo_key == self._rechar_memo:
            return True          # tables already fresh for this log state
        clip = [f for _, f in self.log.tail(clip_len)]
        if len(clip) < 4:
            return False
        from repro.core import grid_engine
        try:
            table, jt = grid_engine.refresh_tables(
                self._background, clip, min_accuracy=min_accuracy,
                include_artifact=include_artifact,
                detector_thresh=detector_thresh, capacity=TABLE_CAPACITY)
        except ValueError:
            return False         # odd geometry etc: keep the stale tables
        if not table.settings:
            return False
        self.controller.swap_table(table)
        self.jax_tables = swap_tables(self.jax_tables, jt)
        with self._version_lock:
            self.table_version += 1
        self._clear_payload_cache()
        self._rechar_memo = memo_key
        return True

    def inject_table_staleness(self, factor: float = 0.5) -> bool:
        """Fault injection: make the LIVE tables stale in place.

        Rescales the size axis of the installed characterization table by
        ``factor`` while keeping the accuracy claims -- exactly what a scene
        regime change does to a table characterized on the old regime (the
        recorded clip-median wire sizes stop predicting what the camera now
        ships).  The swap follows the online-refresh contract verbatim
        (``swap_table`` host-side + jitted twin + ``table_version`` bump, PI
        integral carried), so a fleet lane hot-swaps without recompiling.
        The stale table drops its wire-size proxy (a stale proxy would
        silently fight the pre-screen) and clears the re-characterization
        memo so a drift-triggered refresh really re-sweeps.

        Used by the scenario DSL's ``TableStaleness`` event to exercise the
        drift monitor deterministically without a full scene change.
        Returns False when no controller is installed yet.
        """
        if self.crashed:
            raise BrokerDown(self.camera_id)
        if self.controller is None:
            return False
        live = self.controller.table
        stale = dataclasses.replace(
            live,
            sizes_sorted=live.sizes_sorted * factor,
            size_by_setting=live.size_by_setting * factor,
            proxy=None,
            source="stale-injected",
        )
        self.controller.swap_table(stale)
        self._install_jax_tables(stale)
        self._rechar_memo = None
        return True

    def retarget(self, latency: float, accuracy: float) -> bool:
        """Renegotiate bounds on the LIVE controller (v2 ``update_qos``):
        no teardown, no resubscribe -- the PI loop keeps its tables and
        regression and re-seeds its operating point for the new targets.
        Returns False when no controller is installed yet."""
        if self.crashed:
            raise BrokerDown(self.camera_id)
        if self.controller is None:
            return False
        self.controller.set_target(latency, accuracy)
        with self._version_lock:
            self.qos_version += 1
        return True

    # -- Publish (camera -> camera-node log) -------------------------------------
    def publish(self, timestamp: float, frame: np.ndarray) -> bool:
        if self.crashed:
            raise BrokerDown(self.camera_id)
        return self.log.append(timestamp, frame)

    # -- on-demand transfer (camera log -> edge, through controller + channel) ---
    def fetch(self, t_start: float, t_stop: float, *,
              latency_feedback: float | None = None,
              controlled: bool = True,
              max_frames: int | None = None,
              decision: ControlDecision | None = None,
              budget_scale: float = 1.0
              ) -> list[DeliveredFrame]:
        """Serve the frames in [t_start, t_stop] across the wireless channel.

        ``latency_feedback`` is the subscriber-observed p95 latency of the
        previous window -- the controller's sensor input.  ``max_frames``
        bounds the batch so the subscriber's control loop samples latency at
        its configured interval (paper: "the network latency is measured
        again at the next sampling interval").  ``decision`` injects a
        pre-made control decision (the fleet-backed ``EdgeBroker`` computes
        decisions for ALL cameras of a session in one compiled vmapped step
        and hands each camera its lane) -- the host controller is then not
        consulted for this fetch.  ``budget_scale`` is the owning
        subscription's admission-control cap on the nominal operating size
        (1.0 outside multi-tenant oversubscription; the fleet path carries
        the same cap inside its params, so host/fleet parity holds).
        """
        if self.crashed:
            raise BrokerDown(self.camera_id)
        out: list[DeliveredFrame] = []
        knob_idx = -1
        controller_cost = 0.0
        setting = None
        infeasible = False
        if controlled and self.controller is not None and decision is not None:
            infeasible = decision.acted and not decision.feasible
            if infeasible:
                self.infeasible_reported += 1
            setting = decision.setting
            knob_idx = decision.setting_index
        elif controlled and self.controller is not None and latency_feedback is not None:
            decision = self.controller.update(latency_feedback, budget_scale)
            infeasible = not decision.feasible
            if infeasible:
                self.infeasible_reported += 1
            setting = decision.setting
            knob_idx = decision.setting_index
        elif controlled and self.controller is not None:
            setting = self.controller.current_setting
            knob_idx = self.controller._current

        for ts, frame in self.log.range_query(t_start, t_stop):
            if max_frames is not None and len(out) >= max_frames:
                break
            if setting is not None:
                eff_setting, eff_idx, entry = setting, knob_idx, None
                # one change-fraction pass serves both knob5's drop
                # decision and the drift monitor's activity observation --
                # the latter only when last-sent IS the preceding frame
                # (a consecutive-frame fraction, the table's statistic)
                frac = K.change_fraction(frame, self._last_sent)
                if frac is not None and self._last_sent is self._prev_frame:
                    self._activity_obs.append(frac)
                    if len(self._activity_obs) > 256:
                        del self._activity_obs[:-256]
                self._prev_frame = frame
                thresh = K.DIFF_THRESHOLDS[setting.diff]
                drop = thresh >= 0.0 and frac is not None and frac <= thresh
                if decision is not None and not drop:
                    # knob5 short-circuit: a frame the decision drops never
                    # pays the transform/pre-screen pipeline; the walk is
                    # pinned to the decision's diff axis, so `drop` stays
                    # valid for whatever setting the pre-screen picks
                    eff_setting, eff_idx, entry = self._prescreen(
                        ts, frame, decision)
                r = self._apply_knobs_cached(ts, frame, eff_setting,
                                             entry=entry, drop=drop)
                controller_cost = r.overhead_ms * 1e-3
                if r.frame is None:
                    out.append(DeliveredFrame(
                        self.camera_id, ts, None, 0,
                        LatencyBreakdown(controller=controller_cost),
                        eff_idx, infeasible))
                    continue
                self._last_sent = frame
                payload, nbytes, idx = r.frame, r.wire_bytes, eff_idx
            else:
                payload, nbytes, idx = frame, wire_size(frame), knob_idx
            net = self.channel.transfer(nbytes, fps=self.fps,
                                        distance_m=self.distance_m)
            copy = LOG_COPY_COST_PER_MB * (
                self.channel.scaled_bytes(payload.nbytes) / 1e6)
            out.append(DeliveredFrame(
                self.camera_id, ts, payload, nbytes,
                LatencyBreakdown(publish_api=PUBLISH_API_COST,
                                 controller=controller_cost,
                                 log_copy=copy, network=net),
                idx, infeasible))
        return out

    def _prescreen(self, ts: float, frame: np.ndarray,
                   decision) -> tuple[K.KnobSetting, int, list | None]:
        """Per-frame wire-size pre-screen of the controller's candidate.

        The characterization table's per-setting sizes are CLIP MEDIANS; the
        frame about to ship can compress far worse (a busy scene after a
        calm calibration clip) and blow the controller's size budget.  With
        a proxy-calibrated table (batched engine), the candidate payload's
        byte-delta features predict its deflate size for free, and an
        overshooting candidate steps down the table (largest smaller-size
        setting still above the accuracy bound) BEFORE exact deflate runs --
        the same CANS-style pre-selection the characterization sweep uses,
        now on the stream hot path.  Bounded walk; falls back to the
        controller's own choice when no proxy is installed.  Returns
        (setting, index, cache entry of the accepted payload) so the
        caller never re-walks the cache for the frame it ships.
        """
        table = self.controller.table
        # getattr: tables unpickled from pre-proxy benchmark caches lack
        # the field entirely -- treat them like reference-engine tables
        proxy = getattr(table, "proxy", None)
        setting, idx = decision.setting, decision.setting_index
        if (proxy is None or setting is None or idx < 0
                or not decision.acted or not decision.feasible):
            return setting, idx, None
        budget = float(decision.requested_size)
        floor = self.controller.config.accuracy_target
        entry = None
        for walk in range(PRESCREEN_MAX_CANDIDATES):
            entry = self._transform_cached(ts, frame, setting)
            payload = entry[0]
            self.prescreen_evals += 1
            if entry[1] is not None:
                est = float(entry[1])       # exact deflate already known
            else:
                feats = FK.proxy_features_host(payload)
                est = float(proxy.predict(setting.colorspace, payload.nbytes,
                                          feats, art=setting.artifact > 0))
            # stop on a fitting candidate, or ship the last-evaluated one
            # (never step to a setting we won't evaluate: the returned
            # entry must be the returned setting's payload)
            if (est <= budget * PRESCREEN_SLACK
                    or walk == PRESCREEN_MAX_CANDIDATES - 1):
                break
            down = table.step_down(idx, floor, diff=setting.diff)
            if down < 0:
                break
            idx = down
            setting = table.setting_for(idx)
            self.prescreen_stepdowns += 1
        return setting, idx, entry

    def _transform_cached(self, ts: float, frame: np.ndarray,
                          setting: K.KnobSetting) -> list:
        """The pure knob transform (knob4 -> colorspace -> resize -> blur)
        memoized per (timestamp, transform key); returns the mutable
        ``[payload, wire_bytes|None]`` cache entry.  Deflate is filled in
        lazily by ``_apply_knobs_cached`` only for frames actually shipped,
        so the pre-screen never pays zlib for rejected candidates."""
        key = (ts, setting.resolution, setting.colorspace, setting.blur,
               setting.artifact)
        if self.shared_cache is not None:
            entry = self.shared_cache.get((self.camera_id,) + key)
        else:
            entry = self._payload_cache.get(key)
        if entry is not None:
            self.payload_cache_hits += 1
            return entry
        out = frame
        mode = K.ARTIFACT_MODES[setting.artifact]
        if mode != "off":
            bg = (self.background if self.background is not None
                  else np.zeros_like(frame))
            out = K._artifact_removal(out, bg, mode)
        out = K.transform_frame(out, setting)
        entry = [out, None]
        if self.shared_cache is not None:
            self.shared_cache.put((self.camera_id,) + key, entry)
        else:
            if len(self._payload_cache) >= 512:       # bounded: ring-ish evict
                self._payload_cache.pop(next(iter(self._payload_cache)))
            self._payload_cache[key] = entry
        return entry

    def _apply_knobs_cached(self, ts: float, frame: np.ndarray,
                            setting: K.KnobSetting, *,
                            entry: list | None = None,
                            drop: bool | None = None) -> K.KnobResult:
        """``apply_knobs`` with the transformed payload memoized per
        (timestamp, transform key).

        The knob5 drop decision is stateful (it compares against this
        camera's last *sent* frame) and stays per-call; only the pure
        transform + deflate of a surviving frame is reused, so several
        subscriptions fanning out from one camera pay the image pipeline
        once.  ``fetch`` passes the ``drop`` decision it already computed
        for this (frame, diff threshold) so the O(H*W) differencing never
        runs twice, and ``entry`` lets the pre-screen hand over the cache
        entry it already resolved for ``setting`` (no second lookup, no
        inflated hit counter).  Numerically identical to calling
        ``apply_knobs`` directly.
        """
        if drop is None:
            drop = K.frame_difference(frame, self._last_sent,
                                      K.DIFF_THRESHOLDS[setting.diff])
        if drop:
            return K.KnobResult(None, 0, setting.overhead_ms)
        if entry is None:
            entry = self._transform_cached(ts, frame, setting)
        if entry[1] is None:
            entry[1] = wire_size(entry[0])
        return K.KnobResult(entry[0], entry[1], setting.overhead_ms)

    def drain_activity(self) -> list[float]:
        """Per-frame scene-activity fractions observed by ``fetch`` since
        the last drain (knob5's change metric on the RAW stream, so the
        signal survives even when every frame is knob5-dropped).  The drift
        monitor compares their mean against the live table's
        ``activity`` statistic; a camera fanned out to several
        subscriptions shares one observation stream (first drainer wins)."""
        out = self._activity_obs
        self._activity_obs = []
        return out

    # -- fault tolerance -----------------------------------------------------------
    def crash(self) -> None:
        self.crashed = True

    def persist(self) -> None:
        if self.store is not None:
            self.store.persist(self.log)

    def recover(self) -> None:
        """Reboot: reconstruct the log from CRC-valid on-disk segments."""
        if self.store is not None:
            restored = self.store.recover(self.camera_id)
            if restored is not None:
                self.log = restored
        self.crashed = False
        self._last_sent = None
        self._prev_frame = None
        self._clear_payload_cache()
        self._activity_obs.clear()


@dataclasses.dataclass
class _CamCursor:
    """Per-camera streaming state inside one subscription."""
    spec: SubscribeSpec
    cursor: float
    window: list[float] = dataclasses.field(default_factory=list)
    failed: bool = False
    drained: bool = False
    detached: bool = False
    # credits granted to an in-flight fetch and not yet handed back; stays
    # non-zero across a crash (the dead camera holds them) until
    # ``reattach_camera`` returns them or teardown writes them off
    credits_held: int = 0

    @property
    def active(self) -> bool:
        return not (self.failed or self.drained or self.detached)


@dataclasses.dataclass
class _Subscription:
    """Broker-side subscription record: one or many cameras, fan-in merged."""
    sub_id: str
    session_id: str
    application_id: str
    cameras: dict[str, _CamCursor]
    controlled: bool
    feedback_window: int
    credit_limit: int
    rr_offset: int = 0
    # bounded (evict-before-overwrite + dropped counter, surfacing an
    # EVENTS_DROPPED marker on drain); owner id is stamped at create time
    events: BoundedEventBuffer = dataclasses.field(
        default_factory=BoundedEventBuffer)
    # credit ledger: every fetch credit granted / handed back / written off
    # over this subscription's lifetime (held credits live on the cursors)
    credits_granted: int = 0
    credits_returned: int = 0
    credits_dropped: int = 0
    # fleet control plane: one vmapped compiled controller step drives all
    # cameras of the subscription (built lazily once every camera has a
    # live controller; None until then / when not requested)
    want_fleet: bool = False
    fleet: FleetController | None = None
    # drift-aware auto-recharacterization: one vectorized staleness monitor
    # per subscription, fed once per poll with each camera's observed
    # wire-size residuals; fired lanes re-sweep their own tables with no
    # operator call (None when not requested)
    drift: DriftMonitor | None = None
    # lanes that fired at the END of a poll; the re-sweep applies at the
    # START of the next poll so a batch the subscriber is still holding
    # never references a table swapped out from under it
    pending_refresh: list = dataclasses.field(default_factory=list)
    # device mesh for the fleet control plane (None | int | jax Mesh,
    # resolved by FleetController via repro.sharding.partition.fleet_mesh)
    mesh: object = None
    # cached round-robin order over active cameras, invalidated whenever a
    # camera's active flag flips (crash/fail, drain, detach, reattach) --
    # poll no longer re-sorts the registry every call
    active_order: list | None = None
    # fleet fast path: lane-ordered incremental feedback (per-fetch p95,
    # identical to the per-poll recomputation since feedback windows only
    # mutate inside ``_fetch_into``) and the previous poll's aggregated
    # drift residuals, consumed by the fused tick at the next poll's start
    lat_lane: np.ndarray | None = None
    lat_valid: np.ndarray | None = None
    drift_pending: tuple | None = None
    # multi-tenant serving: tenant identity + SLO class (None = untenanted,
    # exempt from admission control), the admission-control cap currently
    # applied to this subscription's wire budget, the full options record,
    # and a monotonic creation sequence (within one class, newer
    # subscriptions degrade before incumbents)
    tenant: str | None = None
    slo: SloClass | None = None
    budget_scale: float = 1.0
    options: SubscriptionOptions | None = None
    seq: int = 0

    def invalidate_active(self) -> None:
        self.active_order = None


@dataclasses.dataclass
class _Session:
    session_id: str
    application_id: str
    sub_ids: list[str] = dataclasses.field(default_factory=list)
    # session-level tenant identity / SLO class: the default for every
    # subscription the session opens (SubscriptionOptions can override)
    tenant: str | None = None
    slo: SloClass | None = None
    # session-level events (e.g. ADMISSION_REJECTED fires before the
    # subscription exists); drained by session_events alongside the
    # per-subscription streams; bounded like the per-subscription buffers
    events: BoundedEventBuffer = dataclasses.field(
        default_factory=BoundedEventBuffer)


class EdgeBroker:
    """Edge-server broker: camera registry + replicated logs + session-backed
    subscriptions.

    v2 surface (``SessionedMessagingSystem``): applications open a session,
    create subscriptions spanning one or many cameras, and drain frames with
    ``poll_subscription`` -- a timestamp-merged ``FrameBatch`` per call.
    Fan-in uses credit-based backpressure: each poll grants every camera a
    credit window of at most ``credit_limit`` frames, so no camera can have
    more than ``credit_limit`` frames in flight per poll -- one chatty
    camera can't starve the rest of the batch or flood the wireless channel.
    The next credit window opens only when the subscriber polls again,
    i.e. after it has consumed the previous batch.

    The v1 blocking iterator (``subscribe``) is a thin compat shim over the
    same machinery, with identical per-fetch feedback numerics.
    """

    def __init__(self, *, log_capacity: int = 4096,
                 store: LogSegmentStore | None = None,
                 wire_budget: float | None = None):
        self._cams: dict[str, CamBroker] = {}
        self.replicas: dict[str, HostLog] = {}
        self._ids = itertools.count()
        self._sessions: dict[str, _Session] = {}
        self._subscriptions: dict[str, _Subscription] = {}
        # legacy (application_id, camera_id) -> sub_ids, for v1 unsubscribe
        self._sub_index: dict[tuple[str, str], list[str]] = {}
        self.log_capacity = log_capacity
        self.store = store
        self.crashed = False
        # multi-tenant serving: the shared degraded-frame cache every
        # registered camera transforms through, the aggregate wire budget
        # admission control allocates (None -> the shared channel's base
        # rate), and the mutex serializing admission decisions (two joins
        # racing one budget must not both be admitted against it)
        self.frame_cache = SharedFrameCache()
        self._wire_budget = wire_budget
        self._admission_lock = threading.Lock()
        # credit ledger of subscriptions already torn down (live ones carry
        # their own counters); credit_report() folds both together
        self._credit_totals = {"granted": 0, "returned": 0, "dropped": 0}

    # -- Mez API -------------------------------------------------------------------
    def connect(self, url: str) -> str:
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        return f"client-{next(self._ids)}"

    def register(self, cam: CamBroker) -> None:
        """Internal API for IoT camera nodes (paper Section 4.1)."""
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        self._cams[cam.camera_id] = cam
        self.replicas[cam.camera_id] = HostLog(self.log_capacity,
                                               topic=cam.camera_id)
        cam.shared_cache = self.frame_cache
        cam.channel.activate(cam.camera_id)

    def unregister(self, camera_id: str) -> None:
        cam = self._cams.pop(camera_id, None)
        if cam is not None:
            cam.channel.deactivate(camera_id)

    def get_camera_info(self) -> list[str]:
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        return sorted(self._cams)

    # -- v2 session API ------------------------------------------------------------
    def open_session(self, application_id: str, *,
                     tenant: str | None = None,
                     slo: SloClass | str | None = None) -> str:
        """Open a session, optionally under a tenant identity + SLO class.

        ``tenant``/``slo`` become the defaults for every subscription the
        session creates (``SubscriptionOptions`` can override per
        subscription).  A session with an SLO class participates in
        fleet-wide admission control; untenanted sessions keep the exact
        pre-multi-tenant behavior."""
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        sid = f"sess-{next(self._ids)}"
        self._sessions[sid] = _Session(sid, application_id, tenant=tenant,
                                       slo=resolve_slo(slo))
        self._sessions[sid].events.owner = sid
        return sid

    def close_session(self, session_id: str) -> Status:
        """Evict the session and every subscription it owns from the
        registry (a long-lived broker must not accumulate dead records);
        closing an unknown/already-closed session returns FAIL."""
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        sess = self._sessions.pop(session_id, None)
        if sess is None:
            return Status.FAIL
        for sub_id in sess.sub_ids:
            self.close_subscription(sub_id)
        return Status.OK

    def create_subscription(self, session_id: str,
                            specs: Sequence[SubscribeSpec], *,
                            options: SubscriptionOptions | None = None,
                            retarget: bool = True,
                            controlled=_UNSET,
                            feedback_window=_UNSET,
                            credit_limit=_UNSET,
                            fleet=_UNSET,
                            mesh=_UNSET,
                            auto_recharacterize=_UNSET,
                            drift_config=_UNSET) -> str:
        """Register a (possibly multi-camera) subscription on a session.

        Configuration lives in a frozen ``SubscriptionOptions``; the
        individual kwargs (``controlled``, ``feedback_window``, ...) are
        deprecated and accepted for one release, folding into ``options``
        with a ``DeprecationWarning``.

        With ``retarget`` (the default), each spec's (latency, accuracy)
        bounds are pushed to the camera's live controller -- the paper's
        Subscribe call carries the QoS bounds, it doesn't just record them.
        The v1 shim opts out to preserve the seed API's exact behavior
        (bounds there are set out-of-band via ``CamBroker.set_target``).
        A camera that is crashed at create time is marked failed and
        surfaces on the event stream at the first poll.

        With ``options.fleet``, every poll drives ALL cameras of the
        subscription through ONE compiled vmapped controller step
        (``FleetController``) instead of one host PI update per camera --
        per-poll control-plane cost is ~flat in camera count.  Requires
        ``controlled``; cameras whose controllers are installed later join
        the fleet lazily at the first poll where every camera is ready.

        With ``options.auto_recharacterize``, a per-subscription
        ``DriftMonitor`` watches every camera's observed wire sizes against
        its live table's predictions; a camera whose windowed drift score
        crosses the hysteresis threshold is re-characterized from its own
        recent frames automatically (``CamBroker.recharacterize``) and the
        fresh tables hot-swap into the live controller -- and, in fleet
        mode, into exactly that camera's stacked lane -- with no operator
        call and no recompile.  ``options.drift_config`` tunes the monitor;
        requires ``controlled``.  Each refresh (or failed re-sweep attempt)
        surfaces as a ``TABLE_REFRESH`` event on the subscription's event
        stream.

        A subscription whose effective SLO class (``options.slo``, falling
        back to the session's) is set enters fleet-wide admission control:
        its aggregate wire demand -- ``Regression^-1(latency)`` bytes/frame
        x fps summed over cameras, from the live characterization tables --
        is checked against ``wire_budget()``.  When the fleet is
        oversubscribed, lower SLO classes are degraded first
        (``TENANT_DEGRADED`` events, ``budget_scale`` < 1 on their control
        lanes); if even fully-degraded lanes cannot fit, the new
        subscription is rejected (``ADMISSION_REJECTED`` event +
        ``AdmissionRejected``) under ``options.admission == "reject"``, or
        admitted maximally degraded under ``"degrade"`` (the default).
        Subscriptions with no SLO class never degrade and never enter
        admission -- their behavior is byte-identical to the
        single-tenant system.
        """
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        sess = self._sessions.get(session_id)
        if sess is None:
            raise RPCTimeout(f"unknown session {session_id}")
        opts = options if options is not None else SubscriptionOptions()
        legacy = {k: v for k, v in [("controlled", controlled),
                                    ("feedback_window", feedback_window),
                                    ("credit_limit", credit_limit),
                                    ("fleet", fleet),
                                    ("mesh", mesh),
                                    ("auto_recharacterize", auto_recharacterize),
                                    ("drift_config", drift_config)]
                  if v is not _UNSET}
        if legacy:
            warnings.warn(
                "passing {} to create_subscription is deprecated; use "
                "options=SubscriptionOptions(...)".format(
                    ", ".join(sorted(legacy))),
                DeprecationWarning, stacklevel=2)
            opts = dataclasses.replace(opts, **legacy)
        if not specs:
            raise ValueError("subscription needs at least one camera spec")
        if opts.fleet and not opts.controlled:
            raise ValueError("fleet control plane requires controlled=True")
        if opts.mesh is not None and not opts.fleet:
            raise ValueError("mesh partitioning requires fleet=True")
        if opts.auto_recharacterize and not opts.controlled:
            raise ValueError("auto_recharacterize requires controlled=True")
        if opts.admission not in ("degrade", "reject"):
            raise ValueError(f"unknown admission policy {opts.admission!r}")
        for spec in specs:
            if spec.camera_id not in self._cams:
                raise RPCTimeout(f"unknown camera {spec.camera_id}")
        tenant = opts.tenant if opts.tenant is not None else sess.tenant
        slo = resolve_slo(opts.slo) if opts.slo is not None else sess.slo
        num = next(self._ids)
        sub_id = f"sub-{num}"
        cameras = {spec.camera_id: _CamCursor(spec, spec.t_start)
                   for spec in specs}
        rec = _Subscription(sub_id, session_id, sess.application_id, cameras,
                            opts.controlled, opts.feedback_window,
                            opts.credit_limit, want_fleet=opts.fleet,
                            mesh=opts.mesh, tenant=tenant, slo=slo,
                            options=opts, seq=num)
        rec.events.owner = sub_id
        if opts.auto_recharacterize:
            # lane order is the sorted camera-id order, matching the fleet
            # stack, so drift telemetry and fleet lanes line up.  With no
            # explicit config, each lane's hysteresis thresholds are
            # learned from its calibration clip's own residual spread
            # (``drift.learned_thresholds``; hand-set constants floor it).
            spreads = None
            if opts.drift_config is None:
                spreads = {}
                for cid in cameras:
                    ctl = self._cams[cid].controller
                    tbl = ctl.table if ctl is not None else None
                    spreads[cid] = getattr(tbl, "residual_spread", None)
            rec.drift = DriftMonitor(sorted(cameras), opts.drift_config,
                                     spreads=spreads)
        with self._admission_lock:
            admitting = slo is not None or any(
                r.slo is not None for r in self._subscriptions.values())
            if admitting and slo is not None:
                self._admission_check(rec, sess, opts.admission)
            if retarget:
                for spec in specs:
                    try:
                        self._cams[spec.camera_id].retarget(spec.latency,
                                                            spec.accuracy)
                    except BrokerDown as e:
                        cameras[spec.camera_id].failed = True
                        rec.events.append(SessionEvent(
                            EventKind.RPC_TIMEOUT, spec.camera_id, sub_id,
                            spec.t_start, str(e)))
            self._subscriptions[sub_id] = rec
            sess.sub_ids.append(sub_id)
            for spec in specs:
                self._sub_index.setdefault(
                    (sess.application_id, spec.camera_id), []).append(sub_id)
            if admitting:
                self._reallocate(at=min(s.t_start for s in specs))
        if opts.fleet:
            self._ensure_fleet(rec)      # build now if controllers are live
        return sub_id

    # -- fleet-wide admission control (multi-tenant serving) ---------------------
    def wire_budget(self) -> float:
        """Aggregate bytes/s the shared fleet may offer the wireless
        channel: an explicit ``EdgeBroker(wire_budget=...)`` override, else
        the shared channel's base rate."""
        if self._wire_budget is not None:
            return self._wire_budget
        for cam in self._cams.values():
            return cam.channel.config.base_rate
        return float("inf")

    def _lane_load(self, cam: CamBroker,
                   spec: SubscribeSpec) -> tuple[float, float] | None:
        """(demand_bps, floor_bps) for one camera lane of a subscription,
        from the camera's live characterization.

        demand: the wire rate the lane wants at full QoS -- the nominal
        operating size ``Regression^-1(latency)`` (clipped to the table's
        characterized range) x the camera's fps, workload-scaled like the
        channel's own cost model.  floor: the cheapest rate that still
        meets the spec's accuracy bound (the smallest characterized setting
        with ``acc >= accuracy``); a lane can be degraded down to its floor
        but never below.  None when the camera has no live controller yet
        (an uncharacterized lane cannot be costed -- it joins admission
        accounting at its first retarget/poll)."""
        ctl = cam.controller
        if ctl is None:
            return None
        tbl = ctl.table
        nominal = float(np.clip(ctl.regression.invert(spec.latency),
                                tbl.sizes_sorted[0], tbl.sizes_sorted[-1]))
        ok = tbl.size_by_setting[tbl.acc_by_setting >= spec.accuracy]
        floor = float(ok.min()) if ok.size else float(tbl.sizes_sorted[0])
        floor = min(floor, nominal)
        return (cam.channel.scaled_bytes(nominal) * cam.fps,
                cam.channel.scaled_bytes(floor) * cam.fps)

    def _sub_load(self, rec: _Subscription) -> tuple[float, float]:
        """Aggregate (demand_bps, floor_bps) over a subscription's active
        cameras."""
        demand = floor = 0.0
        for cid, cur in rec.cameras.items():
            if not cur.active or cur.failed:
                continue
            cam = self._cams.get(cid)
            if cam is None or cam.crashed:
                continue
            load = self._lane_load(cam, cur.spec)
            if load is not None:
                demand += load[0]
                floor += load[1]
        return demand, floor

    def _slo_subs(self) -> list[_Subscription]:
        return [r for r in self._subscriptions.values() if r.slo is not None]

    def _admission_check(self, rec: _Subscription, sess: _Session,
                         policy: str) -> None:
        """Reject ``rec`` if even the maximally-degraded fleet cannot fit
        it: its own floor + the demand admission may NOT touch (untenanted
        subscriptions, higher-priority classes at full rate is not
        required -- they too can degrade to floor, so only their floors are
        protected) must fit the wire budget."""
        budget = self.wire_budget()
        if not np.isfinite(budget):
            return
        _, floor_new = self._sub_load(rec)
        protected = 0.0
        for other in self._subscriptions.values():
            d, f = self._sub_load(other)
            # untenanted subscriptions never degrade: full demand protected
            protected += d if other.slo is None else f
        if floor_new + protected > budget:
            at = min(c.spec.t_start for c in rec.cameras.values())
            if policy == "reject":
                sess.events.append(SessionEvent(
                    EventKind.ADMISSION_REJECTED, "", rec.sub_id, at,
                    f"demand floor {floor_new + protected:.0f} B/s exceeds "
                    f"wire budget {budget:.0f} B/s"))
                raise AdmissionRejected(
                    f"subscription {rec.sub_id} (tenant={rec.tenant!r}, "
                    f"slo={rec.slo.name}) infeasible: floor "
                    f"{floor_new + protected:.0f} B/s > budget {budget:.0f} B/s",
                    demand_bps=floor_new + protected, budget_bps=budget)
            rec.events.append(SessionEvent(
                EventKind.TENANT_DEGRADED, "", rec.sub_id, at,
                "admitted over budget: fleet remains oversubscribed even "
                "fully degraded"))

    def _reallocate(self, at: float = 0.0) -> None:
        """Re-divide the wire budget across all SLO-classed subscriptions.

        Lower-priority classes absorb the shortfall first (``best_effort``
        before ``silver`` before ``gold``; newest-first within a class), by
        scaling each victim's nominal operating point
        (``budget_scale = (demand - cut) / demand``) down toward -- never
        below -- its accuracy floor.  Untenanted subscriptions are never
        touched; their demand is simply subtracted from the budget.  Scales
        are quantized to f32 so the host PI path and the fleet's
        params-lane path compute identical operating points.  Restores
        (scale moving back up, e.g. after a tenant leaves) are silent;
        decreases emit one ``TENANT_DEGRADED`` event per subscription.
        Caller holds ``_admission_lock``."""
        slo_subs = self._slo_subs()
        if not slo_subs:
            return
        budget = self.wire_budget()
        if not np.isfinite(budget):
            for r in slo_subs:
                self._apply_budget_scale(r, 1.0, at)
            return
        protected = sum(self._sub_load(r)[0]
                        for r in self._subscriptions.values()
                        if r.slo is None)
        loads = {r.sub_id: self._sub_load(r) for r in slo_subs}
        offered = protected + sum(d for d, _ in loads.values())
        excess = offered - budget
        # victims in ascending (priority, newest-first) order
        order = sorted(slo_subs, key=lambda r: (r.slo.priority, -r.seq))
        scales = {r.sub_id: 1.0 for r in slo_subs}
        for r in order:
            d, f = loads[r.sub_id]
            if d <= 0.0:
                # a dark subscription (every lane failed/crashed/detached)
                # offers nothing right now, but restoring it to full rate
                # here would leapfrog the reverse-degradation restore order:
                # when its cameras reattach it would run at scale 1.0 while
                # later-degraded higher classes are still cut.  Hold its
                # current scale; reattach_camera re-runs allocation.
                scales[r.sub_id] = r.budget_scale
                continue
            if excess <= 1e-9:
                continue
            cut = min(excess, d - f)
            if cut <= 0.0:
                continue
            scales[r.sub_id] = float(np.float32((d - cut) / d))
            excess -= cut
        for r in slo_subs:
            self._apply_budget_scale(r, scales[r.sub_id], at)

    def _apply_budget_scale(self, rec: _Subscription, scale: float,
                            at: float) -> None:
        """Install a budget scale on a subscription's control plane (host
        PI path via the per-poll ``budget_scale`` argument, fleet path via
        one params-leaf write -- no retrace either way)."""
        if scale == rec.budget_scale:
            return
        decreased = scale < rec.budget_scale
        rec.budget_scale = scale
        if rec.fleet is not None:
            rec.fleet.set_budget_scale(scale)
        if decreased:
            rec.events.append(SessionEvent(
                EventKind.TENANT_DEGRADED, "", rec.sub_id, at,
                f"tenant={rec.tenant!r} slo={rec.slo.name} "
                f"budget_scale={scale:.4f}"))

    def wire_report(self) -> dict:
        """Introspection: the admission controller's current allocation."""
        budget = self.wire_budget()
        subs = {}
        offered = 0.0
        for r in self._subscriptions.values():
            d, f = self._sub_load(r)
            offered += d * (r.budget_scale if r.slo is not None else 1.0)
            subs[r.sub_id] = {
                "tenant": r.tenant,
                "slo": r.slo.name if r.slo is not None else None,
                "priority": r.slo.priority if r.slo is not None else None,
                "demand_bps": d,
                "floor_bps": f,
                "scale": r.budget_scale if r.slo is not None else 1.0,
                "allocated_bps": d * (r.budget_scale
                                      if r.slo is not None else 1.0),
            }
        return {"budget_bps": budget, "offered_bps": offered,
                "subscriptions": subs}

    def credit_report(self) -> dict:
        """Introspection: the fleet-wide credit ledger (live subscriptions
        plus everything already torn down).

        ``in_flight`` is what crashed-but-not-reattached cameras currently
        hold; ``dropped`` is what teardown/detach wrote off; ``leaked`` is
        the conservation residual ``granted - returned - in_flight -
        dropped`` and must be 0 -- the gauntlet gates on it."""
        granted = self._credit_totals["granted"]
        returned = self._credit_totals["returned"]
        dropped = self._credit_totals["dropped"]
        in_flight = 0
        for r in self._subscriptions.values():
            granted += r.credits_granted
            returned += r.credits_returned
            dropped += r.credits_dropped
            in_flight += sum(c.credits_held for c in r.cameras.values())
        return {"granted": granted, "returned": returned,
                "in_flight": in_flight, "dropped": dropped,
                "leaked": granted - returned - in_flight - dropped}

    def _ensure_fleet(self, rec: _Subscription) -> FleetController | None:
        """Build the subscription's fleet control plane once every camera
        has a live controller; until then polls fall back to the per-camera
        host path.  Lane order is the sorted camera-id order (stable across
        polls and restarts)."""
        if rec.fleet is not None or not rec.want_fleet:
            return rec.fleet
        cams = []
        for cid in sorted(rec.cameras):
            cam = self._cams.get(cid)
            if cam is None or cam.controller is None:
                return None
            cams.append(cam)
        rec.fleet = FleetController(cams, capacity=TABLE_CAPACITY,
                                    mesh=rec.mesh,
                                    tier=rec.slo.priority if rec.slo else 0)
        if rec.budget_scale != 1.0:
            rec.fleet.set_budget_scale(rec.budget_scale)
        if rec.drift is not None:
            rec.fleet.attach_drift(rec.drift)
        # lane-ordered incremental feedback, seeded from whatever the host
        # path accumulated before the fleet went live (lazy join)
        n = len(cams)
        rec.lat_lane = np.zeros(n, np.float32)
        rec.lat_valid = np.zeros(n, bool)
        for i, cid in enumerate(rec.fleet.cam_ids):
            w = rec.cameras[cid].window
            if w:
                rec.lat_lane[i] = np.percentile(w, 95)
                rec.lat_valid[i] = True
        return rec.fleet

    def _active_order(self, rec: _Subscription) -> list:
        """The sorted active-camera round-robin base order, cached until a
        camera's active flag flips (``_Subscription.invalidate_active``)."""
        if rec.active_order is None:
            rec.active_order = [cid for cid in sorted(rec.cameras)
                                if rec.cameras[cid].active]
        return rec.active_order

    # mezlint: poll-path
    def poll_subscription(self, subscription_id: str, *,
                          max_frames: int = 16,
                          deadline: float | None = None) -> FrameBatch:
        """Drain up to ``max_frames`` timestamp-merged frames from all active
        cameras of the subscription (at-most-once: a fetched frame is never
        re-fetched).

        Each active camera is visited once per poll (round-robin rotated for
        fairness), fetching at most ``min(credits, share)`` frames where
        share divides ``max_frames`` across cameras; per-fetch the camera's
        own p95-latency window is fed back to its controller, exactly as the
        v1 single-camera loop did.  ``deadline`` bounds the poll's wall-clock
        time.  A crashed camera is marked failed and surfaces as an
        RPC_TIMEOUT event while the remaining cameras keep streaming; only
        when every camera has failed does poll raise ``RPCTimeout``.
        An empty batch means the subscription is drained (or closed).
        """
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        rec = self._subscriptions.get(subscription_id)
        if rec is None:
            return FrameBatch((), subscription_id)
        fleet = self._ensure_fleet(rec) if rec.controlled else None
        self._apply_pending_refreshes(rec)
        t0 = time.monotonic()
        active = self._active_order(rec)
        out: list[DeliveredFrame] = []
        decisions = None
        if fleet is not None and (active or rec.drift is not None):
            # ONE fused compiled dispatch per poll: the controller step for
            # every serving camera, the drift-monitor tick on the residuals
            # aggregated at the END of the previous poll, and the
            # decision->knob-code application, in a single jitted (and,
            # with a mesh, camera-sharded) call.  Fired drift lanes
            # re-characterize on the host and the SAME compiled tick
            # re-decides against the fresh tables -- so the host side does
            # I/O and bookkeeping only.  Note the tick covers every serving
            # camera even when a saturated ``max_frames`` ends the fetch
            # loop early; with the default share/credit sizing every camera
            # is fetched each poll and fused decisions match the host path
            # exactly.
            decisions = self._fleet_tick(rec, fleet, active)
        if active:
            k = rec.rr_offset % len(active)
            rec.rr_offset += 1
            order = active[k:] + active[:k]
            share = max(1, max_frames // len(order))
            for cid in order:
                if len(out) >= max_frames:
                    break
                # the deadline never forges an end-of-stream: an empty batch
                # must mean drained, so expiry only stops a poll that has
                # already made progress
                if (out and deadline is not None
                        and time.monotonic() - t0 > deadline):
                    break
                self._fetch_into(rec, cid, min(share, max_frames - len(out)),
                                 out,
                                 decision=(decisions.get(cid)
                                           if decisions is not None else None))
        out.sort(key=lambda d: (d.timestamp, d.camera_id))
        self._drift_tick(rec, out, fused=fleet is not None)
        if not out:
            cams = rec.cameras.values()
            if any(c.failed for c in cams) and all(
                    c.failed or c.detached for c in cams):
                raise RPCTimeout(
                    f"all cameras of {subscription_id} unreachable")
        return FrameBatch(tuple(out), subscription_id)

    # mezlint: poll-path
    def _fleet_tick(self, rec: _Subscription, fleet: FleetController,
                    active: list) -> "FleetTickResult":
        """The fused per-poll dispatch: build the lane validity mask from
        the cached feedback arrays (a camera counts only while active,
        reachable, and holding samples -- crashed-but-not-yet-failed
        cameras hold, exactly as the host path never consults their
        controller), hand last poll's drift residuals to the tick, and
        route fired lanes through recharacterize + ``retick``."""
        valid = np.zeros(fleet.n_lanes, bool)
        for cid in active:
            cam = self._cams.get(cid)
            if cam is None or cam.crashed:
                continue
            lane = fleet.lane_of[cid]
            valid[lane] = rec.lat_valid[lane]
        errs = dvalid = None
        if rec.drift_pending is not None:
            errs, dvalid = rec.drift_pending
            rec.drift_pending = None
        # an all-drained poll still ticks when drift is armed (the monitor
        # observes every poll, fused or not) but records no history row --
        # the unfused path never decided on empty polls either
        result = fleet.tick(rec.lat_lane, valid, errs, dvalid,
                            record=bool(active))
        if result.fired_cams:
            self._refresh_cameras(rec, result.fired_cams)
            if active:
                result = fleet.retick()
        return result

    def _drift_tick(self, rec: _Subscription,
                    frames: list[DeliveredFrame], *,
                    fused: bool = False) -> None:
        """One staleness-monitor tick: aggregate this poll's observed
        wire-size residuals per camera, flag drifted lanes, and
        re-characterize exactly those lanes.

        Two residual channels feed each lane, combined by max:

        * **wire size** -- ``|observed - predicted| / predicted`` per
          delivered frame, where predicted is the live table's clip-median
          wire size for the setting the frame shipped under.  A regime that
          compresses differently (or a fault-injected stale size axis)
          steps this signal.
        * **scene activity** -- the live stream's mean knob5 change
          fraction (observed on the RAW frames by ``fetch``, so it survives
          knob5 drops) against the table's calibration-clip ``activity``
          statistic.  More/faster movers over the same background barely
          move wire sizes but multiply this signal.

        A fired lane re-sweeps via ``CamBroker.recharacterize`` (log-tail
        clip, pseudo-GT scoring); the host controller swaps immediately and
        a fleet-backed subscription's ``FleetController.sync`` hot-swaps
        the lane at the next poll's decide -- identical one-poll-later
        semantics on both control paths, which is what keeps host and
        fleet traces byte-identical.  Both successful and unavailable
        re-sweeps surface as TABLE_REFRESH events.

        With ``fused`` (a live fleet), the monitor step itself rides in the
        next poll's fused dispatch: this method only aggregates the
        residuals into lane arrays (O(cameras fetched this poll), not
        O(N)); ``_fleet_tick`` consumes them at the next poll's start --
        the same poll position where the unfused path applied its
        ``pending_refresh`` queue, so fire counts, refresh timing and
        events are identical.
        """
        if rec.drift is None:
            return
        size_res: dict[str, list[float]] = {}
        for f in frames:
            if f.frame is None or f.knob_index < 0:
                continue
            cam = self._cams.get(f.camera_id)
            if cam is None or cam.controller is None:
                continue
            table = cam.controller.table
            if f.knob_index >= len(table.size_by_setting):
                continue
            size_res.setdefault(f.camera_id, []).append(
                relative_size_error(
                    float(table.size_by_setting[f.knob_index]),
                    float(f.wire_bytes)))
        samples: dict[str, float] = {}
        # only cameras fetched this poll can carry residuals: wire sizes
        # come from delivered frames and the activity accumulator fills
        # during ``fetch`` and drains every poll, so the sweep is bounded
        # by the batch, not the fleet
        for cid in {f.camera_id for f in frames}:
            cam = self._cams.get(cid)
            if cam is None or cam.crashed or cam.controller is None:
                continue
            channels: list[float] = []
            if cid in size_res:
                channels.append(float(np.mean(size_res[cid])))
            acts = cam.drain_activity()
            ref_act = getattr(cam.controller.table, "activity", None)
            if acts and ref_act is not None:
                channels.append(abs(float(np.mean(acts)) - ref_act)
                                / max(ref_act, DRIFT_ACTIVITY_FLOOR))
            if channels:
                samples[cid] = max(channels)
        if fused and rec.fleet is not None:
            if samples:
                n = len(rec.drift.cam_ids)
                errs = np.zeros(n, np.float32)
                valid = np.zeros(n, bool)
                for cid, v in samples.items():
                    lane = rec.fleet.lane_of[cid]
                    errs[lane] = v
                    valid[lane] = True
                rec.drift_pending = (errs, valid)
            return
        for cid in rec.drift.observe(samples):
            if cid not in rec.pending_refresh:
                rec.pending_refresh.append(cid)

    def _apply_pending_refreshes(self, rec: _Subscription) -> None:
        """Re-characterize the lanes the drift monitor fired last poll.

        Runs at the top of the poll, BEFORE any control decision: the host
        controller and (via ``FleetController.sync`` inside ``decide``) the
        fleet lane both trade on the fresh tables for this poll's fetches,
        and every batch already handed to the subscriber keeps referencing
        the table its decisions were made against."""
        if not rec.pending_refresh:
            return
        fired, rec.pending_refresh = rec.pending_refresh, []
        self._refresh_cameras(rec, fired)

    def _refresh_cameras(self, rec: _Subscription, fired) -> None:
        """Re-sweep the given lanes' tables from their own recent frames,
        emitting one TABLE_REFRESH event per lane either way.  Shared by
        the host queue (``_apply_pending_refreshes``) and the fused tick's
        fire-set (``_fleet_tick``)."""
        for cid in fired:
            cam = self._cams.get(cid)
            cur = rec.cameras.get(cid)
            at = cur.cursor if cur is not None else 0.0
            if cam is None or cam.crashed:
                rec.events.append(SessionEvent(
                    EventKind.TABLE_REFRESH, cid, rec.sub_id, at,
                    "drift: camera unreachable; stale tables kept"))
                continue
            try:
                refreshed = cam.recharacterize()
            except BrokerDown:
                rec.events.append(SessionEvent(
                    EventKind.TABLE_REFRESH, cid, rec.sub_id, at,
                    "drift: camera unreachable; stale tables kept"))
                continue
            rec.events.append(SessionEvent(
                EventKind.TABLE_REFRESH, cid, rec.sub_id, at,
                "drift: tables re-swept from live frames" if refreshed
                else "drift: re-sweep unavailable; stale tables kept"))

    def _fetch_into(self, rec: _Subscription, camera_id: str, budget: int,
                    out: list[DeliveredFrame], *,
                    decision: ControlDecision | None = None) -> None:
        """One on-demand fetch round for one camera of a subscription.
        ``decision`` carries the camera's lane of a fleet control tick; the
        host controller is then bypassed for this fetch."""
        cur = rec.cameras[camera_id]
        budget = min(budget, rec.credit_limit)
        if budget <= 0:
            return
        cam = self._cams.get(camera_id)
        if cam is None:
            cur.failed = True
            rec.invalidate_active()
            rec.events.append(SessionEvent(
                EventKind.RPC_TIMEOUT, camera_id, rec.sub_id, cur.cursor,
                "camera unregistered"))
            return
        feedback = None
        if decision is None:
            feedback = (float(np.percentile(cur.window, 95))
                        if cur.window else None)
        # credit ledger: the window is granted to the camera for the
        # duration of the fetch RPC and handed back when it returns.  A
        # crash mid-fetch leaves the credits held by the dead camera; they
        # come back at reattach_camera (or are written off at teardown),
        # never silently -- credit_report()'s leaked term must stay 0.
        cur.credits_held += budget
        rec.credits_granted += budget
        try:
            frames = cam.fetch(cur.cursor, cur.spec.t_stop,
                               latency_feedback=feedback,
                               controlled=rec.controlled,
                               max_frames=budget,
                               decision=decision,
                               budget_scale=rec.budget_scale)
        except BrokerDown as e:
            cur.failed = True
            rec.invalidate_active()
            rec.events.append(SessionEvent(
                EventKind.RPC_TIMEOUT, camera_id, rec.sub_id, cur.cursor,
                str(e)))
            return
        cur.credits_held -= budget
        rec.credits_returned += budget
        if not frames:
            cur.drained = True
            rec.invalidate_active()
            return
        replica = self.replicas[camera_id]
        infeasible_seen = False
        window_touched = False
        for f in frames:
            cur.cursor = max(cur.cursor, float(np.nextafter(f.timestamp,
                                                            np.inf)))
            lat = dataclasses.replace(
                f.latency,
                broker_processing=BROKER_PROC_COST,
                subscribe_api=SUBSCRIBE_API_COST)
            g = dataclasses.replace(f, latency=lat)
            if g.infeasible:
                infeasible_seen = True
            if g.frame is not None:
                replica.append(g.timestamp, g.frame)
                cur.window.append(g.latency.total)
                cur.window[:] = cur.window[-rec.feedback_window:]
                window_touched = True
            out.append(g)
        if window_touched and rec.lat_valid is not None \
                and rec.fleet is not None:
            # feedback windows only mutate here, so refreshing the lane's
            # p95 per fetch is value-identical to the per-poll recompute
            # the unfused path did -- and drops it from the poll hot loop
            lane = rec.fleet.lane_of[camera_id]
            rec.lat_lane[lane] = np.percentile(cur.window, 95)
            rec.lat_valid[lane] = True
        if infeasible_seen:
            rec.events.append(SessionEvent(
                EventKind.INFEASIBLE, camera_id, rec.sub_id,
                frames[-1].timestamp,
                "latency/accuracy bounds infeasible; serving best effort"))
        if cur.cursor > cur.spec.t_stop:
            cur.drained = True
            rec.invalidate_active()

    def update_subscription_qos(self, subscription_id: str, *,
                                latency: float | None = None,
                                accuracy: float | None = None,
                                recharacterize: bool = False) -> QosUpdate:
        """Renegotiate (latency, accuracy) bounds on a LIVE subscription.

        The per-camera ``LatencyController`` is retargeted in place (paper
        Fig. 9 SetTarget at runtime): no teardown, no resubscribe, cursors
        and feedback windows survive.  With ``recharacterize``, each
        camera's knob tables are first re-swept over its own recent frames
        (``CamBroker.recharacterize``) and hot-swapped into the live
        controller -- host and jitted twin alike -- so the renegotiated
        bounds bind against CURRENT scene/network statistics, not the
        startup calibration clip.  Cameras that are crashed fail the update
        individually (RPC_TIMEOUT event) without aborting the rest.
        """
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        rec = self._subscriptions.get(subscription_id)
        if rec is None:
            return QosUpdate(latency or 0.0, accuracy or 0.0, Status.FAIL,
                             (), subscription_id, subscription_ids=())
        applied: list[str] = []
        recharacterized: list[str] = []
        per_camera: list[CameraQosResult] = []
        new_lat = new_acc = 0.0
        for cid, cur in rec.cameras.items():
            if cur.detached or cur.failed:
                continue
            new_lat = latency if latency is not None else cur.spec.latency
            new_acc = accuracy if accuracy is not None else cur.spec.accuracy
            cur.spec = dataclasses.replace(cur.spec, latency=new_lat,
                                           accuracy=new_acc)
            cam = self._cams.get(cid)
            if cam is None:
                continue
            try:
                did_rechar = bool(recharacterize and cam.recharacterize())
                if did_rechar:
                    recharacterized.append(cid)
                # retarget AFTER the table swap: the operating point
                # re-seeds into the freshly characterized size axis
                if cam.retarget(new_lat, new_acc):
                    applied.append(cid)
                    per_camera.append(CameraQosResult(
                        cid, Status.OK, recharacterized=did_rechar))
                else:
                    per_camera.append(CameraQosResult(
                        cid, Status.FAIL, recharacterized=did_rechar))
            except BrokerDown as e:
                cur.failed = True
                rec.invalidate_active()
                rec.events.append(SessionEvent(
                    EventKind.RPC_TIMEOUT, cid, rec.sub_id, cur.cursor,
                    str(e)))
                per_camera.append(CameraQosResult(cid, Status.FAIL))
        if rec.slo is not None or any(r.slo is not None
                                      for r in self._subscriptions.values()):
            # new bounds move the subscription's wire demand: re-divide
            with self._admission_lock:
                self._reallocate(at=max((c.cursor
                                         for c in rec.cameras.values()),
                                        default=0.0))
        return QosUpdate(new_lat, new_acc,
                         Status.OK if applied else Status.FAIL,
                         tuple(applied), subscription_id,
                         recharacterized=tuple(recharacterized),
                         per_camera=tuple(per_camera),
                         tenant=rec.tenant or "",
                         slo_class=rec.slo.name if rec.slo else "",
                         subscription_ids=(subscription_id,))

    def reattach_camera(self, subscription_id: str, camera_id: str) -> Status:
        """Re-admit a recovered camera into a live subscription.

        A camera that crashed mid-stream is marked failed and stops being
        polled; after the node reboots (``CamBroker.recover``) the scenario
        /operator re-attaches it here.  The cursor resumes exactly where it
        stopped -- frames published while the camera was down are still in
        its log and are delivered late rather than lost (at-most-once is
        preserved; nothing is re-fetched).  Credits held by a fetch that was
        in flight at crash time are returned here -- the crashed node can
        never hand them back itself, and leaving them on the cursor leaks
        the subscription's credit window a little more on every
        crash/recover cycle.  FAIL when the subscription or camera is
        unknown, or the camera is still crashed; OK (idempotent) when the
        camera was never failed.
        """
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        rec = self._subscriptions.get(subscription_id)
        if rec is None:
            return Status.FAIL
        cur = rec.cameras.get(camera_id)
        cam = self._cams.get(camera_id)
        if cur is None or cam is None or cam.crashed:
            return Status.FAIL
        cur.failed = False
        if cur.credits_held:
            rec.credits_returned += cur.credits_held
            cur.credits_held = 0
        rec.invalidate_active()
        # the returning lane re-enters the wire-budget accounting: without
        # this, a subscription that went dark mid-degradation resumes at a
        # stale scale while other classes carry its share of the shortfall
        if self._slo_subs():
            with self._admission_lock:
                self._reallocate(at=cur.cursor)
        return Status.OK

    # -- federation support (herd camera migration) --------------------------------
    def export_camera(self, camera_id: str, *, at: float = 0.0
                      ) -> tuple[CamBroker, list, dict]:
        """Detach a camera and everything it owns here, for a herd
        migration.

        Returns ``(cam, replica_tail, cursors)``: the camera-node broker
        object itself (its ``HostLog``, live ``CharacterizationTable`` +
        jitted table twin, and host PI controller all travel with it), the
        edge replica's frames (the target replays them into a fresh
        replica; its monotonic-timestamp rule dedupes any overlap), and the
        per-subscription ``_CamCursor`` records keyed by local sub id (the
        herd re-creates each as a part on the target and imports the cursor
        so polling resumes exactly where it stopped).

        Bookkeeping handled here, per the migration contract:

        * in-flight fetch credits are DRAINED -- returned to each
          subscription's ledger exactly like ``reattach_camera`` does for a
          recovered crash (the fetch RPC can never complete against the old
          route), so ``credit_report()`` stays conserved herd-wide;
        * fleet subscriptions export the camera's lane state back into the
          host controller (``FleetController.export_lane``) so the PI
          integral survives the hand-off; the source fleet's lane goes
          permanently invalid in place (the fused tick holds it, exactly
          like a crashed camera) -- no rebuild, no retrace;
        * the camera's entries in the shared frame cache are invalidated
          (the source must never serve a payload for a camera it no longer
          routes);
        * subscriptions left with zero cameras are closed (their ledgers
          fold into the broker totals) and the wire budget is reallocated.
        """
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        cam = self._cams.get(camera_id)
        if cam is None:
            raise RPCTimeout(f"unknown camera {camera_id}")
        cursors: dict[str, _CamCursor] = {}
        emptied = []
        for sub_id, rec in self._subscriptions.items():
            cur = rec.cameras.get(camera_id)
            if cur is None:
                continue
            if cur.credits_held:
                rec.credits_returned += cur.credits_held
                cur.credits_held = 0
            if rec.fleet is not None and camera_id in rec.fleet.lane_of:
                rec.fleet.export_lane(camera_id)
            del rec.cameras[camera_id]
            rec.invalidate_active()
            cursors[sub_id] = cur
            if not rec.cameras:
                emptied.append(sub_id)
            key = (rec.application_id, camera_id)
            ids = self._sub_index.get(key)
            if ids is not None:
                if sub_id in ids:
                    ids.remove(sub_id)
                if not ids:
                    del self._sub_index[key]
        for sub_id in emptied:
            self.close_subscription(sub_id)
        replica = self.replicas.pop(camera_id, None)
        tail = replica.snapshot() if replica is not None else []
        self.frame_cache.invalidate(camera_id)
        self.unregister(camera_id)
        if not emptied and self._slo_subs():
            # emptied subs already reallocated via close_subscription
            with self._admission_lock:
                self._reallocate(at=at)
        return cam, tail, cursors

    def adopt_camera(self, cam: CamBroker, *, replica_tail=()) -> None:
        """Attach a migrated camera: register it (re-pointing its shared
        cache at THIS edge's) and replay the source replica tail into the
        fresh replica.  The log's ordering rule rejects any frame at or
        before the replica's last timestamp, so the at-most-one frame both
        brokers saw during the route flip lands exactly once."""
        self.register(cam)
        rep = self.replicas[cam.camera_id]
        for ts, frame in replica_tail:
            rep.append(ts, frame)

    def import_camera_cursor(self, subscription_id: str, camera_id: str,
                             state: _CamCursor) -> None:
        """Install an exported cursor on a freshly-created part
        subscription: polling resumes at the migrated cursor position (not
        the spec's t_start -- nothing is re-fetched), the feedback window
        carries over so the fleet lane's p95 seed matches the source, and
        the failed flag survives (a camera that crashed mid-migration still
        needs reattach_camera after recovery)."""
        rec = self._subscriptions.get(subscription_id)
        if rec is None:
            raise RPCTimeout(f"unknown subscription {subscription_id}")
        cur = rec.cameras.get(camera_id)
        if cur is None:
            raise RPCTimeout(f"camera {camera_id} not in {subscription_id}")
        cur.cursor = max(cur.cursor, state.cursor)
        cur.window[:] = list(state.window)
        cur.failed = state.failed
        cur.drained = state.drained
        rec.invalidate_active()

    def close_subscription(self, subscription_id: str) -> Status:
        """Explicit teardown: evicts the record and scrubs the legacy
        (application, camera) index so the registry stays O(live
        subscriptions).  Safe on unknown/already-closed ids (FAIL)."""
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        rec = self._subscriptions.pop(subscription_id, None)
        if rec is None:
            return Status.FAIL
        # fold the subscription's credit ledger into the broker totals;
        # credits still held by (dead) cameras can never return now and are
        # written off as dropped rather than vanishing from the accounting
        held = sum(c.credits_held for c in rec.cameras.values())
        self._credit_totals["granted"] += rec.credits_granted
        self._credit_totals["returned"] += rec.credits_returned
        self._credit_totals["dropped"] += rec.credits_dropped + held
        for cid in rec.cameras:
            key = (rec.application_id, cid)
            ids = self._sub_index.get(key)
            if ids is not None:
                if subscription_id in ids:
                    ids.remove(subscription_id)
                if not ids:
                    del self._sub_index[key]
        if any(r.slo is not None for r in self._subscriptions.values()):
            # a leaving tenant frees wire budget: restore degraded lanes
            with self._admission_lock:
                self._reallocate(at=max((c.cursor
                                         for c in rec.cameras.values()),
                                        default=0.0))
        return Status.OK

    def subscription_fleet(self, subscription_id: str
                           ) -> FleetController | None:
        """The live fleet control plane of a fleet-backed subscription
        (None for host-path subscriptions) -- introspection for parity
        tests and the fleet-scaling benchmark."""
        rec = self._subscriptions.get(subscription_id)
        return rec.fleet if rec is not None else None

    def subscription_drift(self, subscription_id: str) -> DriftMonitor | None:
        """The live staleness monitor of an auto-recharacterizing
        subscription (None otherwise) -- introspection for the drift tests
        and the fig12 benchmark."""
        rec = self._subscriptions.get(subscription_id)
        return rec.drift if rec is not None else None

    def subscription_events(self, subscription_id: str) -> list[SessionEvent]:
        """Drain pending out-of-band events for a subscription.  The buffer
        is bounded; when undrained events were evicted since the last call,
        the first returned event is an ``EVENTS_DROPPED`` marker."""
        rec = self._subscriptions.get(subscription_id)
        if rec is None:
            return []
        return rec.events.drain()

    def session_subscription_ids(self, session_id: str) -> list[str]:
        """Live subscription ids of a session (``Session.update_qos`` fans
        a renegotiation out over these)."""
        sess = self._sessions.get(session_id)
        if sess is None:
            return []
        return [sid for sid in sess.sub_ids if sid in self._subscriptions]

    def session_events(self, session_id: str) -> list[SessionEvent]:
        """Drain pending events across all subscriptions of a session,
        plus session-level events (admission rejections happen before a
        subscription record exists, so they land on the session)."""
        sess = self._sessions.get(session_id)
        if sess is None:
            return []
        out: list[SessionEvent] = sess.events.drain()
        for sub_id in sess.sub_ids:
            out.extend(self.subscription_events(sub_id))
        return out

    def subscription_state(self, subscription_id: str) -> SubscriptionState:
        rec = self._subscriptions.get(subscription_id)
        if rec is None:
            return SubscriptionState.CLOSED
        cams = rec.cameras.values()
        if any(c.active for c in cams):
            return SubscriptionState.ACTIVE
        if any(c.failed for c in cams):
            return SubscriptionState.FAILED
        return SubscriptionState.DRAINED

    # -- v1 compat shim ------------------------------------------------------------
    def subscribe(self, spec: SubscribeSpec, *,
                  controlled: bool = True,
                  feedback_window: int = 8,
                  fetch_window: int = 2) -> Iterator[DeliveredFrame]:
        """Deprecated v1 streaming subscription.  Use the v2 session API
        (``open_session`` / ``create_subscription`` / ``poll_subscription``)
        or, for existing v1 callers, ``repro.compat.subscribe_v1`` which
        wraps this without a per-call warning."""
        warnings.warn(
            "EdgeBroker.subscribe (v1 iterator API) is deprecated; use the "
            "v2 session API or repro.compat.subscribe_v1",
            DeprecationWarning, stacklevel=2)
        return self._subscribe_v1(spec, controlled=controlled,
                                  feedback_window=feedback_window,
                                  fetch_window=fetch_window)

    def _subscribe_v1(self, spec: SubscribeSpec, *,
                      controlled: bool = True,
                      feedback_window: int = 8,
                      fetch_window: int = 2) -> Iterator[DeliveredFrame]:
        """v1 streaming subscription (paper Fig. 7), as a shim over the v2
        session machinery.

        Yields frames as they become available in [t_start, t_stop].  Each
        poll is capped at ``fetch_window`` frames so the control loop samples
        the subscriber-observed p95 latency at its interval rather than
        bulk-draining the camera log -- numerically identical to the original
        single-camera loop.
        """
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")

        def gen() -> Iterator[DeliveredFrame]:
            sid = self.open_session(spec.application_id)
            sub_id = self.create_subscription(
                sid, (spec,),
                options=SubscriptionOptions(controlled=controlled,
                                            feedback_window=feedback_window,
                                            credit_limit=fetch_window),
                retarget=False)
            try:
                while True:
                    batch = self.poll_subscription(sub_id,
                                                   max_frames=fetch_window)
                    if not batch:
                        break
                    yield from batch.frames
            finally:
                if not self.crashed:
                    self.close_session(sid)

        return gen()

    def unsubscribe(self, application_id: str, camera_id: str) -> Status:
        """v1 Unsubscribe: detach the camera from every live subscription of
        this application.  Idempotent and deterministic: a second call, or a
        call naming an unknown camera/application, returns ``Status.FAIL``
        without raising or corrupting registry state."""
        if self.crashed:
            raise RPCTimeout("EdgeBroker down")
        detached = False
        for sub_id in self._sub_index.get((application_id, camera_id), []):
            rec = self._subscriptions.get(sub_id)
            if rec is None:
                continue
            cur = rec.cameras.get(camera_id)
            if cur is not None and not cur.detached:
                cur.detached = True
                if cur.credits_held:     # detached cameras never reattach
                    rec.credits_dropped += cur.credits_held
                    cur.credits_held = 0
                rec.invalidate_active()
                detached = True
        return Status.OK if detached else Status.FAIL

    # -- fault tolerance --------------------------------------------------------------
    def crash(self) -> None:
        self.crashed = True

    def persist(self) -> None:
        if self.store is not None:
            for log in self.replicas.values():
                self.store.persist(log)

    def recover(self) -> None:
        if self.store is not None:
            for cid in list(self.replicas):
                restored = self.store.recover(cid)
                if restored is not None:
                    self.replicas[cid] = restored
        self.crashed = False


class MezSystem:
    """Convenience facade wiring cameras + brokers + controller (the thing
    benchmarks instantiate)."""

    def __init__(self, channel: WirelessChannel, *,
                 store: LogSegmentStore | None = None,
                 wire_budget: float | None = None):
        self.channel = channel
        self.edge = EdgeBroker(store=store, wire_budget=wire_budget)
        self.cams: dict[str, CamBroker] = {}

    def add_camera(self, camera_id: str, *, distance_m: float = 6.0,
                   fps: float = 5.0) -> CamBroker:
        cam = CamBroker(camera_id, self.channel, distance_m=distance_m,
                        fps=fps, store=self.edge.store)
        self.cams[camera_id] = cam
        self.edge.register(cam)
        return cam


class NatsLikeSystem:
    """The NATS baseline (paper Section 5.2): low-latency general pub-sub,
    NO latency control, NO storage layer, 1 MB message size limit."""

    MESSAGE_LIMIT = 1_000_000  # bytes

    def __init__(self, channel: WirelessChannel):
        self.channel = channel
        self._cams: dict[str, dict] = {}
        self.rejected_oversize = 0

    def add_camera(self, camera_id: str, *, distance_m: float = 6.0,
                   fps: float = 5.0) -> None:
        self._cams[camera_id] = {"distance": distance_m, "fps": fps}
        self.channel.activate(camera_id)

    def get_camera_info(self) -> list[str]:
        return sorted(self._cams)

    def deliver(self, camera_id: str, timestamp: float, frame: np.ndarray
                ) -> DeliveredFrame:
        """Publish + fan out one frame, unmodified."""
        info = self._cams[camera_id]
        nbytes = wire_size(frame)
        if self.channel.scaled_bytes(nbytes) > self.MESSAGE_LIMIT:
            # Paper: "Since NATS has a 1MB message size limit, DukeMTMC frames
            # cannot be sent/received using NATS."
            self.rejected_oversize += 1
            raise ValueError(
                f"NATS message size limit exceeded: {nbytes} > 1MB")
        net = self.channel.transfer(nbytes, fps=info["fps"],
                                    distance_m=info["distance"])
        lat = LatencyBreakdown(publish_api=PUBLISH_API_COST * 0.5,
                               network=net,
                               broker_processing=BROKER_PROC_COST * 0.4,
                               subscribe_api=SUBSCRIBE_API_COST * 0.5)
        return DeliveredFrame(camera_id, timestamp, frame, nbytes, lat, -1)
