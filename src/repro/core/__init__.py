"""Mez core: the paper's contribution (brokers, log, latency controller) plus
the TPU-native extension (controller-driven approximate collectives)."""

from repro.core.api import (AdmissionRejected, BoundedEventBuffer, BrokerDown,
                            CameraQosResult, DeliveredFrame, EventKind,
                            FrameBatch, LatencyBreakdown, MessagingSystem,
                            QosBounds, QosUpdate, RPCTimeout, SessionEvent,
                            SessionedMessagingSystem, SloClass, SLO_CLASSES,
                            Status, SubscribeSpec, SubscriptionOptions,
                            SubscriptionState, resolve_slo)
from repro.core.channel import ChannelConfig, WirelessChannel, calibrated_channel
from repro.core.characterization import (CharacterizationTable,
                                         LatencyRegression, characterize,
                                         fit_latency_regression)
from repro.core.controller import (ControllerConfig, ControllerState,
                                   JaxControllerTables, LatencyController,
                                   controller_init, controller_step)
from repro.core.drift import (DriftConfig, DriftMonitor, DriftState,
                              drift_init, drift_update)
from repro.core.grid_engine import (GridCharacterization, WireSizeProxy,
                                    run_grid)
from repro.core.knobs import (KnobSetting, TransformMemo, apply_knobs,
                              enumerate_settings, wire_size)
from repro.core.log import (FrameLog, HostLog, LogSegmentStore, frame_log_append,
                            frame_log_init, frame_log_point_query,
                            frame_log_range_query)
from repro.core.session import MezClient, Session, Subscription

__all__ = [
    "BrokerDown", "DeliveredFrame", "LatencyBreakdown", "MessagingSystem",
    "RPCTimeout", "Status", "SubscribeSpec", "ChannelConfig", "WirelessChannel",
    "calibrated_channel", "CharacterizationTable", "LatencyRegression",
    "characterize", "fit_latency_regression", "ControllerConfig",
    "ControllerState", "JaxControllerTables", "LatencyController",
    "controller_init", "controller_step", "KnobSetting", "apply_knobs",
    "enumerate_settings", "wire_size", "FrameLog", "HostLog", "LogSegmentStore",
    "frame_log_append", "frame_log_init", "frame_log_point_query",
    "frame_log_range_query", "EventKind", "FrameBatch", "QosUpdate",
    "SessionEvent", "SessionedMessagingSystem", "SubscriptionState",
    "MezClient", "Session", "Subscription", "GridCharacterization",
    "WireSizeProxy", "run_grid", "TransformMemo", "DriftConfig",
    "DriftMonitor", "DriftState", "drift_init", "drift_update",
    "AdmissionRejected", "CameraQosResult", "QosBounds", "SloClass",
    "SLO_CLASSES", "SubscriptionOptions", "resolve_slo",
    "BoundedEventBuffer", "MqttBridge", "MqttMessage",
]

from repro.core.mqtt_bridge import MqttBridge, MqttMessage  # noqa: E402
