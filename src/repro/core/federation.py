"""Federated broker tier: a herd of ``EdgeBroker``s behind one routing table.

A single edge broker is Mez's scalability and availability bottleneck: an
edge crash stalls every camera behind it and a hot broker has no way to
shed load.  ``BrokerHerd`` federates N independent ``EdgeBroker``s --
FogMQ's broker-herd/migration design -- while presenting the exact
``SessionedMessagingSystem`` surface one broker does, so ``MezClient`` /
``Session`` / ``run_scenario`` work against a herd unchanged.

Topology
    Every camera routes to exactly one broker (``_cam_route``).  A herd
    session lazily opens one local session per broker it touches; a herd
    subscription decomposes into per-broker *parts* (one local
    subscription per broker holding any of its cameras).  Polls fan out to
    the parts with a per-camera frame budget identical to the
    single-broker share split, and the part batches are merged back in
    ``(timestamp, camera_id)`` order -- a no-migration federated trace is
    frame-identical to the same workload on one broker.

Live migration (``migrate_camera``)
    The ``CamBroker`` object itself moves: its host log, live
    characterization table + jitted twin, and host PI controller travel
    with it.  The source broker drains the camera's in-flight fetch
    credits (returned to the ledger exactly like a crash reattach, so
    ``credit_report()`` stays conserved herd-wide), exports each fleet
    lane's PI state back into the host controller
    (``FleetController.export_lane`` -- no retrace on either side), and
    hands over the edge replica tail.  The target replays the tail into a
    fresh replica (the log's monotonic-timestamp ordering rule dedupes the
    at-most-one overlapping frame), re-creates each affected subscription
    part with ``retarget=False`` (the controller keeps its target and
    carried integral), and imports the cursors so polling resumes exactly
    where it stopped -- no frame loss, no duplicate delivery, and the
    subscriber never sees anything but a ``CAMERA_MIGRATED`` event.

Overload policy (``rebalance``)
    Per-broker watermarks -- offered wire load over ``overload_ratio`` x
    budget, or delivered-latency p95 over ``latency_watermark`` -- mark a
    broker overloaded.  The herd emits ``BROKER_OVERLOAD`` on every
    affected subscription and migrates cameras of the NEWEST
    lowest-priority SLO lanes first (ascending ``(priority, -seq)`` --
    best_effort before silver before gold, newest first within a class,
    mirroring admission control's degradation order) to the least-loaded
    broker until the watermark clears.  Untenanted subscriptions are never
    shed, mirroring admission's protected demand.

Rolling upgrade (``rolling_upgrade``)
    For each broker in turn: migrate its cameras to the least-loaded peer,
    crash + recover the (now empty) broker, and proceed -- a full-herd
    restart with zero frame loss and no subscriber-visible downtime.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import numpy as np

from repro.core.api import (AdmissionRejected, BoundedEventBuffer, EventKind,
                            FrameBatch, QosUpdate, RPCTimeout, SessionEvent,
                            SloClass, Status, SubscribeSpec,
                            SubscriptionOptions, SubscriptionState)
from repro.core.broker import CamBroker, EdgeBroker
from repro.core.channel import WirelessChannel
from repro.core.log import LogSegmentStore

__all__ = ["BrokerHerd", "FederatedMezSystem"]


@dataclasses.dataclass
class _Part:
    """One broker-local slice of a herd subscription."""
    broker: int
    sub_id: str                    # local (broker-side) subscription id
    cameras: list[str]


@dataclasses.dataclass
class _HerdSub:
    sub_id: str                    # herd-level id ("hsub-N")
    session_id: str                # herd-level session id
    specs: dict[str, SubscribeSpec]
    options: SubscriptionOptions | None
    parts: list[_Part]
    seq: int
    # herd-level events (CAMERA_MIGRATED, BROKER_OVERLOAD); the parts'
    # broker-side buffers are drained and re-stamped alongside
    events: BoundedEventBuffer = dataclasses.field(
        default_factory=BoundedEventBuffer)

    def part_of(self, camera_id: str) -> _Part | None:
        for p in self.parts:
            if camera_id in p.cameras:
                return p
        return None


@dataclasses.dataclass
class _HerdSession:
    session_id: str                # herd-level id ("hsess-N")
    application_id: str
    tenant: str | None
    slo: SloClass | str | None
    locals: dict[int, str] = dataclasses.field(default_factory=dict)
    sub_ids: list[str] = dataclasses.field(default_factory=list)


class _HerdCacheView:
    """Read-only aggregate over the brokers' shared frame caches, shaped
    like one ``SharedFrameCache`` for introspection (hits/misses/evictions,
    ``hit_rate()``, ``len``)."""

    def __init__(self, brokers: list[EdgeBroker]):
        self._brokers = brokers

    def _caches(self):
        return [b.frame_cache for b in self._brokers]

    @property
    def hits(self) -> int:
        return sum(c.hits for c in self._caches())

    @property
    def misses(self) -> int:
        return sum(c.misses for c in self._caches())

    @property
    def evictions(self) -> int:
        return sum(c.evictions for c in self._caches())

    @property
    def capacity(self) -> int:
        return sum(c.capacity for c in self._caches())

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self) -> int:
        return sum(len(c) for c in self._caches())


class BrokerHerd:
    """N ``EdgeBroker``s behind one routing table, speaking the single-broker
    session surface (see module docstring).

    ``wire_budget`` is PER BROKER (None -> each broker falls back to the
    shared channel's base rate): a herd of two brokers at budget B serves
    each camera under exactly the admission pressure a lone broker at B
    would, which keeps federated and single-broker traces comparable.
    """

    def __init__(self, n_brokers: int = 2, *, log_capacity: int = 4096,
                 store: LogSegmentStore | None = None,
                 wire_budget: float | None = None,
                 overload_ratio: float = 0.95,
                 latency_watermark: float | None = None):
        if n_brokers < 1:
            raise ValueError(f"need at least one broker, got {n_brokers}")
        self.brokers = [EdgeBroker(log_capacity=log_capacity, store=store,
                                   wire_budget=wire_budget)
                        for _ in range(n_brokers)]
        self.store = store
        self.overload_ratio = float(overload_ratio)
        self.latency_watermark = latency_watermark
        self._cam_route: dict[str, int] = {}
        self._ids = itertools.count()
        self._sessions: dict[str, _HerdSession] = {}
        self._subs: dict[str, _HerdSub] = {}
        # (broker_idx, local_sub_id) -> herd sub id, for event re-stamping
        self._part_owner: dict[tuple[int, str], str] = {}
        # recent delivered-latency samples per broker (poll watermark)
        self._lat_window: list[list[float]] = [[] for _ in range(n_brokers)]
        self.migrations = 0
        self.frame_cache = _HerdCacheView(self.brokers)

    # -- camera routing ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.brokers)

    def register(self, cam: CamBroker, *, broker: int | None = None) -> int:
        """Route a camera to ``broker`` (default: the broker with the
        fewest cameras; stable tie-break on index) and register it there."""
        if broker is None:
            counts = [0] * len(self.brokers)
            for b in self._cam_route.values():
                counts[b] += 1
            broker = int(np.argmin(counts))
        self._check_broker(broker)
        self.brokers[broker].register(cam)
        self._cam_route[cam.camera_id] = broker
        return broker

    def route_of(self, camera_id: str) -> int:
        if camera_id not in self._cam_route:
            raise RPCTimeout(f"unknown camera {camera_id}")
        return self._cam_route[camera_id]

    def _check_broker(self, idx: int) -> None:
        if not 0 <= idx < len(self.brokers):
            raise ValueError(f"broker index {idx} out of range "
                             f"[0, {len(self.brokers)})")

    def _broker_of(self, camera_id: str) -> EdgeBroker:
        return self.brokers[self.route_of(camera_id)]

    # -- SessionedMessagingSystem surface --------------------------------------------
    @property
    def crashed(self) -> bool:
        """True while ANY broker is down: scenario reattach deferral is
        conservative -- partial availability still serves polls, but
        recovery actions wait until the whole herd is back."""
        return any(b.crashed for b in self.brokers)

    def crash(self, broker: int | None = None) -> None:
        if broker is None:
            for b in self.brokers:
                b.crash()
        else:
            self._check_broker(broker)
            self.brokers[broker].crash()

    def recover(self, broker: int | None = None) -> None:
        if broker is None:
            for b in self.brokers:
                b.recover()
        else:
            self._check_broker(broker)
            self.brokers[broker].recover()

    def persist(self) -> None:
        for b in self.brokers:
            b.persist()

    def connect(self, url: str) -> str:
        return f"herd-client-{next(self._ids)}"

    def get_camera_info(self) -> list[str]:
        return sorted(self._cam_route)

    def open_session(self, application_id: str, *,
                     tenant: str | None = None,
                     slo: SloClass | str | None = None) -> str:
        sid = f"hsess-{next(self._ids)}"
        self._sessions[sid] = _HerdSession(sid, application_id, tenant, slo)
        return sid

    def _local_session(self, sess: _HerdSession, broker: int) -> str:
        if broker not in sess.locals:
            sess.locals[broker] = self.brokers[broker].open_session(
                sess.application_id, tenant=sess.tenant, slo=sess.slo)
        return sess.locals[broker]

    def close_session(self, session_id: str) -> Status:
        sess = self._sessions.pop(session_id, None)
        if sess is None:
            return Status.FAIL
        for sub_id in list(sess.sub_ids):
            self.close_subscription(sub_id)
        for broker, lsid in sess.locals.items():
            self.brokers[broker].close_session(lsid)
        return Status.OK

    def create_subscription(self, session_id: str,
                            specs: Sequence[SubscribeSpec], *,
                            options: SubscriptionOptions | None = None,
                            retarget: bool = True) -> str:
        """One herd subscription = one local part per broker that routes
        any of its cameras.  Admission runs per broker against that
        broker's wire budget; a rejection on ANY part rolls back the parts
        already created and re-raises."""
        sess = self._sessions.get(session_id)
        if sess is None:
            raise RPCTimeout(f"unknown session {session_id}")
        if not specs:
            raise ValueError("subscription needs at least one camera spec")
        by_broker: dict[int, list[SubscribeSpec]] = {}
        for spec in specs:
            by_broker.setdefault(self.route_of(spec.camera_id),
                                 []).append(spec)
        num = next(self._ids)
        hsub_id = f"hsub-{num}"
        parts: list[_Part] = []
        try:
            for broker in sorted(by_broker):
                lsid = self._local_session(sess, broker)
                local = self.brokers[broker].create_subscription(
                    lsid, by_broker[broker], options=options,
                    retarget=retarget)
                parts.append(_Part(broker, local,
                                   [s.camera_id for s in by_broker[broker]]))
        except (AdmissionRejected, RPCTimeout):
            for p in parts:
                self.brokers[p.broker].close_subscription(p.sub_id)
            raise
        rec = _HerdSub(hsub_id, session_id,
                       {s.camera_id: s for s in specs}, options, parts,
                       seq=num)
        rec.events.owner = hsub_id
        self._subs[hsub_id] = rec
        sess.sub_ids.append(hsub_id)
        for p in parts:
            self._part_owner[(p.broker, p.sub_id)] = hsub_id
        return hsub_id

    def poll_subscription(self, subscription_id: str, *,
                          max_frames: int = 16,
                          deadline: float | None = None) -> FrameBatch:
        """Fan the poll out over the parts and merge.

        Each part gets ``share x |part cameras|`` frames where ``share =
        max(1, max_frames // total cameras)`` -- the same per-camera budget
        the single-broker poll computes, so a no-migration federated run
        delivers frame-identical batches.  A part whose broker is down (or
        whose cameras all failed) raises locally; the herd re-raises only
        when EVERY part is unreachable -- otherwise the surviving brokers'
        frames are delivered and the dead part's events surface on the
        stream.  Delivered frames are never trimmed (they are fetched,
        at-most-once) -- with ``max_frames < total cameras`` the merged
        batch may slightly exceed ``max_frames``, exactly as a lone broker
        may overshoot its integer share split."""
        rec = self._subs.get(subscription_id)
        if rec is None:
            return FrameBatch((), subscription_id)
        total_cams = sum(len(p.cameras) for p in rec.parts)
        if total_cams == 0:
            return FrameBatch((), subscription_id)
        share = max(1, max_frames // total_cams)
        out = []
        errors = 0
        for part in rec.parts:
            if not part.cameras:
                continue
            try:
                batch = self.brokers[part.broker].poll_subscription(
                    part.sub_id, max_frames=share * len(part.cameras),
                    deadline=deadline)
            except RPCTimeout:
                errors += 1
                continue
            out.extend(batch.frames)
            window = self._lat_window[part.broker]
            window.extend(f.latency.total for f in batch.frames
                          if f.latency is not None)
            del window[:-256]
        if errors and errors == sum(1 for p in rec.parts if p.cameras):
            raise RPCTimeout(
                f"all parts of {subscription_id} unreachable")
        out.sort(key=lambda d: (d.timestamp, d.camera_id))
        return FrameBatch(tuple(out), subscription_id)

    def update_subscription_qos(self, subscription_id: str, *,
                                latency: float | None = None,
                                accuracy: float | None = None,
                                recharacterize: bool = False) -> QosUpdate:
        rec = self._require(subscription_id)
        updates = [self.brokers[p.broker].update_subscription_qos(
                       p.sub_id, latency=latency, accuracy=accuracy,
                       recharacterize=recharacterize)
                   for p in rec.parts]
        first = updates[0]
        return dataclasses.replace(
            first,
            subscription_id=subscription_id,
            status=(Status.OK if all(u.status is Status.OK for u in updates)
                    else Status.FAIL),
            applied_cameras=tuple(c for u in updates
                                  for c in u.applied_cameras),
            recharacterized=tuple(c for u in updates
                                  for c in u.recharacterized),
            per_camera=tuple(r for u in updates for r in u.per_camera))

    def close_subscription(self, subscription_id: str) -> Status:
        rec = self._subs.pop(subscription_id, None)
        if rec is None:
            return Status.FAIL
        status = Status.OK
        for p in rec.parts:
            self._part_owner.pop((p.broker, p.sub_id), None)
            if self.brokers[p.broker].close_subscription(p.sub_id) \
                    is not Status.OK:
                status = Status.FAIL
        sess = self._sessions.get(rec.session_id)
        if sess is not None and subscription_id in sess.sub_ids:
            sess.sub_ids.remove(subscription_id)
        return status

    def reattach_camera(self, subscription_id: str,
                        camera_id: str) -> Status:
        rec = self._subs.get(subscription_id)
        if rec is None:
            return Status.FAIL
        part = rec.part_of(camera_id)
        if part is None:
            return Status.FAIL
        return self.brokers[part.broker].reattach_camera(part.sub_id,
                                                         camera_id)

    def _require(self, subscription_id: str) -> _HerdSub:
        rec = self._subs.get(subscription_id)
        if rec is None:
            raise RPCTimeout(f"unknown subscription {subscription_id}")
        return rec

    def _restamp(self, events: list[SessionEvent],
                 hsub_id: str) -> list[SessionEvent]:
        return [dataclasses.replace(e, subscription_id=hsub_id)
                if e.subscription_id else e for e in events]

    def subscription_events(self, subscription_id: str) -> list[SessionEvent]:
        rec = self._subs.get(subscription_id)
        if rec is None:
            return []
        out = rec.events.drain()
        for p in rec.parts:
            out.extend(self._restamp(
                self.brokers[p.broker].subscription_events(p.sub_id),
                subscription_id))
        return out

    def session_events(self, session_id: str) -> list[SessionEvent]:
        sess = self._sessions.get(session_id)
        if sess is None:
            return []
        out: list[SessionEvent] = []
        for sub_id in sess.sub_ids:
            rec = self._subs.get(sub_id)
            if rec is not None:
                out.extend(rec.events.drain())
        # local drains cover session-level events (admission rejections)
        # AND the parts' per-subscription buffers; re-stamp local sub ids
        # back to herd ids where a part mapping is known
        for broker, lsid in sess.locals.items():
            for e in self.brokers[broker].session_events(lsid):
                hid = self._part_owner.get((broker, e.subscription_id))
                out.append(dataclasses.replace(e, subscription_id=hid)
                           if hid else e)
        return out

    def session_subscription_ids(self, session_id: str) -> list[str]:
        sess = self._sessions.get(session_id)
        if sess is None:
            return []
        return [sid for sid in sess.sub_ids if sid in self._subs]

    def subscription_state(self, subscription_id: str) -> SubscriptionState:
        rec = self._subs.get(subscription_id)
        if rec is None:
            return SubscriptionState.CLOSED
        states = [self.brokers[p.broker].subscription_state(p.sub_id)
                  for p in rec.parts]
        if SubscriptionState.ACTIVE in states:
            return SubscriptionState.ACTIVE
        if SubscriptionState.FAILED in states:
            return SubscriptionState.FAILED
        if SubscriptionState.DRAINED in states:
            return SubscriptionState.DRAINED
        return SubscriptionState.CLOSED

    def subscription_fleet(self, subscription_id: str):
        """The fleet control plane of the FIRST part (introspection; a
        migrated herd subscription has one fleet per part)."""
        rec = self._subs.get(subscription_id)
        if rec is None or not rec.parts:
            return None
        return self.brokers[rec.parts[0].broker].subscription_fleet(
            rec.parts[0].sub_id)

    def subscription_drift(self, subscription_id: str):
        rec = self._subs.get(subscription_id)
        if rec is None or not rec.parts:
            return None
        return self.brokers[rec.parts[0].broker].subscription_drift(
            rec.parts[0].sub_id)

    # -- herd-wide introspection -----------------------------------------------------
    def credit_report(self) -> dict:
        """The fetch-credit ledger summed over the herd.  ``leaked`` is
        recomputed from the herd totals and must be 0 through any sequence
        of crashes, migrations, and teardowns -- migration drains in-flight
        credits on the source before the route flips, so no credit is ever
        stranded on a broker that no longer routes the camera."""
        totals = {"granted": 0, "returned": 0, "in_flight": 0, "dropped": 0}
        for b in self.brokers:
            rep = b.credit_report()
            for k in totals:
                totals[k] += rep[k]
        totals["leaked"] = (totals["granted"] - totals["returned"]
                            - totals["in_flight"] - totals["dropped"])
        return totals

    def wire_report(self) -> dict:
        """Per-broker allocation reports plus one herd-level view keyed by
        HERD subscription id (a spanning subscription reports the MINIMUM
        scale across its parts -- the degradation a subscriber actually
        observes)."""
        reports = [b.wire_report() for b in self.brokers]
        subs: dict[str, dict] = {}
        for rec in self._subs.values():
            entries = []
            for p in rec.parts:
                e = reports[p.broker]["subscriptions"].get(p.sub_id)
                if e is not None:
                    entries.append(e)
            if not entries:
                continue
            subs[rec.sub_id] = {
                "tenant": entries[0]["tenant"],
                "slo": entries[0]["slo"],
                "priority": entries[0]["priority"],
                "demand_bps": sum(e["demand_bps"] for e in entries),
                "floor_bps": sum(e["floor_bps"] for e in entries),
                "scale": min(e["scale"] for e in entries),
                "allocated_bps": sum(e["allocated_bps"] for e in entries),
            }
        return {
            "budget_bps": sum(r["budget_bps"] for r in reports),
            "offered_bps": sum(r["offered_bps"] for r in reports),
            "subscriptions": subs,
            "brokers": reports,
        }

    # -- live camera migration ---------------------------------------------------------
    def migrate_camera(self, camera_id: str, to_broker: int, *,
                       at: float = 0.0) -> bool:
        """Move a camera -- and every subscription lane riding it -- to
        another broker, live.  See the module docstring for the contract.
        Returns False (no-op) when the camera already routes there."""
        src_idx = self.route_of(camera_id)
        self._check_broker(to_broker)
        if src_idx == to_broker:
            return False
        src, dst = self.brokers[src_idx], self.brokers[to_broker]
        if src.crashed or dst.crashed:
            raise RPCTimeout(
                f"migration endpoint down (brokers {src_idx}, {to_broker})")
        cam, tail, cursors = src.export_camera(camera_id, at=at)
        dst.adopt_camera(cam, replica_tail=tail)
        self._cam_route[camera_id] = to_broker
        # rebuild each affected herd subscription's part set: drop the
        # camera from its source part (closing parts left empty), create a
        # fresh part on the target with retarget=False (the controller
        # keeps its target and the carried PI integral), and import the
        # cursor so polling resumes in place
        for rec in self._subs.values():
            part = rec.part_of(camera_id)
            if part is None or part.broker != src_idx:
                continue
            part.cameras.remove(camera_id)
            if not part.cameras:
                # the broker already closed the emptied local record
                self._part_owner.pop((part.broker, part.sub_id), None)
                rec.parts.remove(part)
            sess = self._sessions[rec.session_id]
            opts = rec.options
            if opts is not None and opts.admission != "degrade":
                # a migrated lane is already admitted: it may be degraded
                # on the target but never re-rejected
                opts = dataclasses.replace(opts, admission="degrade")
            lsid = self._local_session(sess, to_broker)
            local = dst.create_subscription(lsid, [rec.specs[camera_id]],
                                            options=opts, retarget=False)
            dst.import_camera_cursor(local, camera_id,
                                     cursors[part.sub_id])
            new_part = _Part(to_broker, local, [camera_id])
            rec.parts.append(new_part)
            self._part_owner[(to_broker, local)] = rec.sub_id
            rec.events.append(SessionEvent(
                EventKind.CAMERA_MIGRATED, camera_id, rec.sub_id, at,
                f"broker {src_idx} -> {to_broker}"))
        self.migrations += 1
        return True

    # -- overload policy ---------------------------------------------------------------
    def broker_load(self, idx: int) -> dict:
        """One broker's watermark inputs: offered/budget wire ratio and the
        p95 of its recent delivered latencies (NaN with no samples)."""
        self._check_broker(idx)
        rep = self.brokers[idx].wire_report()
        budget = rep["budget_bps"]
        ratio = (rep["offered_bps"] / budget
                 if np.isfinite(budget) and budget > 0 else 0.0)
        window = self._lat_window[idx]
        p95 = float(np.percentile(window, 95)) if window else float("nan")
        return {"wire_ratio": ratio, "latency_p95": p95,
                "offered_bps": rep["offered_bps"], "budget_bps": budget}

    def overloaded(self, idx: int) -> bool:
        load = self.broker_load(idx)
        if load["wire_ratio"] > self.overload_ratio:
            return True
        return (self.latency_watermark is not None
                and not np.isnan(load["latency_p95"])
                and load["latency_p95"] > self.latency_watermark)

    def set_wire_budget(self, idx: int, budget: float | None) -> None:
        """Operator/scenario override of one broker's wire budget (e.g. a
        degraded backhaul); admission reallocates on the next join/leave,
        the herd's overload policy on the next ``rebalance``."""
        self._check_broker(idx)
        self.brokers[idx]._wire_budget = budget

    def rebalance(self, *, at: float = 0.0,
                  max_moves: int | None = None) -> list[tuple[str, int, int]]:
        """Shed load off every overloaded broker: migrate cameras of the
        newest lowest-priority SLO lanes first (admission's degradation
        order) to the least-loaded peer until the watermark clears or no
        sheddable lane remains.  Emits ``BROKER_OVERLOAD`` on each affected
        subscription.  Returns the ``(camera_id, from, to)`` moves made."""
        moves: list[tuple[str, int, int]] = []
        receivers: set[int] = set()
        for idx in range(len(self.brokers)):
            if self.brokers[idx].crashed or not self.overloaded(idx):
                continue
            if idx in receivers:
                # this broker absorbed shed lanes earlier in the pass:
                # shedding them straight back would ping-pong cameras
                # between mutually-overloaded brokers; the next rebalance
                # re-evaluates with settled loads
                continue
            load = self.broker_load(idx)
            wire_over = load["wire_ratio"] > self.overload_ratio
            trigger = (f"wire {load['wire_ratio']:.2f} > "
                       f"{self.overload_ratio:.2f}" if wire_over
                       else f"latency p95 {load['latency_p95'] * 1e3:.1f} ms")
            overloaded_subs = set()
            for hsub, camera_id in self._shed_candidates(idx):
                if max_moves is not None and len(moves) >= max_moves:
                    break
                # a wire-triggered shed only needs the move to reduce
                # imbalance (when the whole herd is past the watermark --
                # a degraded backhaul under saturation -- there IS no
                # non-overloaded peer, yet moving lanes toward the less
                # loaded broker still restores proportional service);
                # a latency-triggered shed keeps the stricter rule
                below = (self.broker_load(idx)["wire_ratio"] if wire_over
                         else None)
                target = self._least_loaded(exclude=idx, below=below)
                if target is None:
                    break
                if hsub.sub_id not in overloaded_subs:
                    overloaded_subs.add(hsub.sub_id)
                    hsub.events.append(SessionEvent(
                        EventKind.BROKER_OVERLOAD, camera_id, hsub.sub_id,
                        at, f"broker {idx}: {trigger}"))
                self.migrate_camera(camera_id, target, at=at)
                moves.append((camera_id, idx, target))
                receivers.add(target)
                self._lat_window[idx].clear()
                if not self.overloaded(idx):
                    break
        return moves

    def _shed_candidates(self, idx: int):
        """(herd sub, camera) pairs on broker ``idx`` in shed order:
        ascending (SLO priority, -seq) -- newest best_effort first -- then
        camera id for determinism.  Untenanted lanes are never shed."""
        ranked = []
        for rec in self._subs.values():
            for p in rec.parts:
                if p.broker != idx or not p.cameras:
                    continue
                entry = self.brokers[idx].wire_report()[
                    "subscriptions"].get(p.sub_id)
                if entry is None or entry["slo"] is None:
                    continue
                for cid in sorted(p.cameras):
                    ranked.append((entry["priority"], -rec.seq, cid, rec))
        ranked.sort(key=lambda t: t[:3])
        return [(rec, cid) for _, _, cid, rec in ranked]

    def _least_loaded(self, *, exclude: int,
                      below: float | None = None) -> int | None:
        """Least-loaded live peer.  With ``below`` set, any peer whose wire
        ratio sits strictly under it qualifies (relative balance); without
        it only a non-overloaded peer does (absolute watermark)."""
        best, best_ratio = None, None
        for i, b in enumerate(self.brokers):
            if i == exclude or b.crashed:
                continue
            ratio = self.broker_load(i)["wire_ratio"]
            if below is None:
                if self.overloaded(i):
                    continue
            elif ratio >= below:
                continue
            if best_ratio is None or ratio < best_ratio:
                best, best_ratio = i, ratio
        return best

    # -- rolling upgrade ---------------------------------------------------------------
    def rolling_upgrade(self, *, at: float = 0.0) -> int:
        """Restart every broker in turn with zero downtime: migrate its
        cameras to the least-loaded peer, crash + recover the emptied
        broker, then move on.  Cameras are NOT moved back -- the overload
        policy (or explicit migrations) rebalances afterwards.  Returns the
        number of migrations performed."""
        if len(self.brokers) < 2:
            raise ValueError("rolling upgrade needs at least two brokers")
        moved = 0
        for idx in range(len(self.brokers)):
            for camera_id in [cid for cid, b in self._cam_route.items()
                              if b == idx]:
                peers = [(self.broker_load(i)["wire_ratio"], i)
                         for i in range(len(self.brokers))
                         if i != idx and not self.brokers[i].crashed]
                if not peers:
                    raise RPCTimeout("no live peer to migrate onto")
                target = min(peers)[1]
                if self.migrate_camera(camera_id, target, at=at):
                    moved += 1
            self.brokers[idx].persist()
            self.brokers[idx].crash()
            self.brokers[idx].recover()
        return moved


class FederatedMezSystem:
    """Herd-backed drop-in for ``MezSystem``: same facade fields
    (``channel`` / ``edge`` / ``cams``), with ``edge`` a ``BrokerHerd`` --
    ``MezClient(system)`` and ``run_scenario`` work unchanged."""

    def __init__(self, channel: WirelessChannel, *, n_brokers: int = 2,
                 store: LogSegmentStore | None = None,
                 wire_budget: float | None = None,
                 overload_ratio: float = 0.95,
                 latency_watermark: float | None = None):
        self.channel = channel
        self.herd = BrokerHerd(n_brokers, store=store,
                               wire_budget=wire_budget,
                               overload_ratio=overload_ratio,
                               latency_watermark=latency_watermark)
        self.edge = self.herd
        self.cams: dict[str, CamBroker] = {}

    def add_camera(self, camera_id: str, *, distance_m: float = 6.0,
                   fps: float = 5.0, broker: int | None = None) -> CamBroker:
        cam = CamBroker(camera_id, self.channel, distance_m=distance_m,
                        fps=fps, store=self.herd.store)
        self.cams[camera_id] = cam
        self.herd.register(cam, broker=broker)
        return cam
