"""v2 client surface: ``MezClient`` -> ``Session`` -> ``Subscription``.

The paper's five-call API (Section 3.1) is single-camera and blocking; the
headline workload (Section 5.1) is five cameras feeding one detector.  This
module is the session-oriented client shape that matches that workload:

    client = MezClient(system)
    with client.open_session("app0", tenant="acme", slo="gold") as session:
        sub = session.subscribe(["cam0", "cam1"], 0.0, 8.0,
                                qos=QosBounds(latency=0.100, accuracy=0.95),
                                options=SubscriptionOptions(fleet=True))
        while (batch := sub.poll(max_frames=10)):
            payload, valid = batch.stack()        # jit-ready [B,H,W,C]
            ...
        sub.update_qos(latency=0.060)             # live renegotiation
        for ev in sub.events():                   # INFEASIBLE / RPC_TIMEOUT
            ...

Handles are thin: all state lives broker-side (``EdgeBroker`` session
registry), so a handle can be dropped and the registry stays authoritative
-- the same reasoning the paper uses to keep subscriber recovery trivial.

Configuration is a frozen ``SubscriptionOptions`` (``core.api``); the old
per-kwarg spelling (``controlled=``, ``fleet=``, ...) still works for one
release with a ``DeprecationWarning``.  Sessions opened under a tenant/SLO
class enter fleet-wide admission control (see ``EdgeBroker.wire_budget``).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

from repro.core.api import (FrameBatch, QosBounds, QosUpdate, SessionEvent,
                            SloClass, Status, SubscribeSpec,
                            SubscriptionOptions, SubscriptionState,
                            resolve_slo)

__all__ = ["MezClient", "Session", "Subscription"]

_UNSET = object()


class MezClient:
    """Entry point for the v2 API.  Wraps anything that implements the
    ``SessionedMessagingSystem`` protocol -- an ``EdgeBroker`` directly or a
    ``MezSystem`` facade (``system.edge`` is unwrapped automatically)."""

    def __init__(self, system):
        self._edge = getattr(system, "edge", system)

    def connect(self, url: str = "mez://edge") -> str:
        return self._edge.connect(url)

    def get_camera_info(self) -> list[str]:
        return self._edge.get_camera_info()

    def open_session(self, application_id: str, *,
                     tenant: str | None = None,
                     slo: SloClass | str | None = None) -> "Session":
        """Open a session, optionally under a tenant identity + SLO class
        (``"gold"`` / ``"silver"`` / ``"best_effort"`` or a custom
        ``SloClass``).  The pair becomes the default for every subscription
        the session creates and opts them into fleet-wide admission
        control."""
        return Session(self._edge,
                       self._edge.open_session(application_id, tenant=tenant,
                                               slo=slo),
                       application_id, tenant=tenant, slo=resolve_slo(slo))


class Session:
    """One application's conversation with the edge broker.  Context-manager;
    closing the session closes every subscription it created."""

    def __init__(self, edge, session_id: str, application_id: str, *,
                 tenant: str | None = None, slo: SloClass | None = None):
        self._edge = edge
        self.session_id = session_id
        self.application_id = application_id
        self.tenant = tenant
        self.slo = slo
        self._closed = False

    def subscribe(self, camera_ids: str | Sequence[str], t_start: float,
                  t_stop: float, *,
                  qos: QosBounds | None = None,
                  options: SubscriptionOptions | None = None,
                  latency: float | None = None,
                  accuracy: float | None = None,
                  controlled=_UNSET, feedback_window=_UNSET,
                  credit_limit=_UNSET, fleet=_UNSET, mesh=_UNSET,
                  auto_recharacterize=_UNSET,
                  drift_config=_UNSET) -> "Subscription":
        """Subscribe one or many cameras under shared QoS bounds; frames from
        all of them arrive timestamp-merged through one ``poll()``.

        Bounds come from ``qos`` (a ``QosBounds``); with a session-level SLO
        class and no explicit ``qos``, the class's (latency, accuracy) pair
        is used.  ``latency=``/``accuracy=`` floats are the deprecated
        spelling of ``qos`` and fold into it with a ``DeprecationWarning``
        when ``qos`` is not given.

        Everything else lives in ``options`` (a frozen
        ``SubscriptionOptions``); the individual kwargs (``controlled``,
        ``fleet``, ...) are deprecated and fold into ``options`` likewise.

        ``options.fleet`` runs the subscription's per-camera PI controllers
        as ONE compiled vmapped step per poll (the fleet control plane):
        per-poll control cost is ~flat in camera count, and per-camera QoS
        retargets / table refreshes hot-swap into the compiled step without
        recompiling.  ``options.mesh`` additionally partitions the fused
        tick over the camera axis (``shard_map``).

        ``options.auto_recharacterize`` arms the drift-aware refresh loop
        (see ``EdgeBroker.create_subscription``); refreshes surface as
        ``TABLE_REFRESH`` events on ``events()``.
        """
        if isinstance(camera_ids, str):
            camera_ids = [camera_ids]
        opts = options if options is not None else SubscriptionOptions()
        legacy = {k: v for k, v in [("controlled", controlled),
                                    ("feedback_window", feedback_window),
                                    ("credit_limit", credit_limit),
                                    ("fleet", fleet),
                                    ("mesh", mesh),
                                    ("auto_recharacterize", auto_recharacterize),
                                    ("drift_config", drift_config)]
                  if v is not _UNSET}
        if legacy:
            warnings.warn(
                "passing {} to Session.subscribe is deprecated; use "
                "options=SubscriptionOptions(...)".format(
                    ", ".join(sorted(legacy))),
                DeprecationWarning, stacklevel=2)
            opts = dataclasses.replace(opts, **legacy)
        if qos is None and (latency is not None or accuracy is not None):
            if latency is not None and accuracy is not None:
                warnings.warn(
                    "passing latency=/accuracy= to Session.subscribe is "
                    "deprecated; use qos=QosBounds(latency, accuracy)",
                    DeprecationWarning, stacklevel=2)
                qos = QosBounds(latency, accuracy)
            else:
                raise ValueError("latency and accuracy must be given together"
                                 " (or use qos=QosBounds(...))")
        if qos is None:
            slo = (resolve_slo(opts.slo) if opts.slo is not None
                   else self.slo)
            if slo is None:
                raise ValueError(
                    "subscribe needs qos=QosBounds(...) (or a session/"
                    "options SLO class to default the bounds from)")
            qos = QosBounds(slo.max_latency, slo.min_accuracy)
        specs = tuple(SubscribeSpec(self.application_id, cid, t_start, t_stop,
                                    qos.latency, qos.accuracy)
                      for cid in camera_ids)
        sub_id = self._edge.create_subscription(self.session_id, specs,
                                                options=opts)
        return Subscription(self._edge, sub_id, tuple(camera_ids))

    def events(self) -> list[SessionEvent]:
        """Drain pending events across all of this session's subscriptions
        (plus session-level ones, e.g. ``ADMISSION_REJECTED``)."""
        return self._edge.session_events(self.session_id)

    def update_qos(self, *, latency: float | None = None,
                   accuracy: float | None = None,
                   recharacterize: bool = False) -> QosUpdate:
        """Renegotiate bounds across EVERY subscription of this session.

        With ``recharacterize=True`` each camera first re-sweeps its knob
        tables over its own recent frames (the batched grid engine runs in
        seconds, cheap enough to fold into a renegotiation) and hot-swaps
        them into its live controller before the new bounds are applied --
        online re-characterization, per the CANS self-configuration model.

        Returns ONE merged ``QosUpdate`` covering every subscription
        (``per_camera`` / ``subscription_ids`` carry the fan-out detail; it
        used to return a list).
        """
        updates = [self._edge.update_subscription_qos(
                       sid, latency=latency, accuracy=accuracy,
                       recharacterize=recharacterize)
                   for sid in self._edge.session_subscription_ids(
                       self.session_id)]
        merged = QosUpdate.merge(updates)
        if self.tenant or self.slo is not None:
            merged = dataclasses.replace(
                merged, tenant=self.tenant or "",
                slo_class=self.slo.name if self.slo else merged.slo_class)
        return merged

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> Status:
        if self._closed:
            return Status.OK
        self._closed = True
        return self._edge.close_session(self.session_id)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class Subscription:
    """Handle for one (possibly multi-camera) subscription."""

    def __init__(self, edge, subscription_id: str, cameras: tuple[str, ...]):
        self._edge = edge
        self.subscription_id = subscription_id
        self.cameras = cameras
        self._closed = False

    def poll(self, max_frames: int = 16,
             deadline: float | None = None) -> FrameBatch:
        """Next ``FrameBatch``: at most ``max_frames`` timestamp-merged,
        at-most-once frames across all subscribed cameras.  Empty batch =>
        drained.  ``deadline`` (seconds) bounds the call's wall-clock time."""
        return self._edge.poll_subscription(self.subscription_id,
                                            max_frames=max_frames,
                                            deadline=deadline)

    def update_qos(self, *, latency: float | None = None,
                   accuracy: float | None = None,
                   recharacterize: bool = False) -> QosUpdate:
        """Renegotiate bounds live: per-camera controllers retarget in place,
        cursors/windows survive, no teardown or resubscribe.

        ``recharacterize=True`` additionally re-runs the batched knob-grid
        sweep on each camera's recent frames and hot-swaps the fresh tables
        into the live controller (and its jitted twin) before retargeting,
        so the new bounds are enforced against current conditions
        (``QosUpdate.recharacterized`` lists the cameras that re-swept).
        Same ``QosUpdate`` shape as ``Session.update_qos`` -- ``per_camera``
        carries the per-camera statuses.
        """
        return self._edge.update_subscription_qos(
            self.subscription_id, latency=latency, accuracy=accuracy,
            recharacterize=recharacterize)

    def events(self) -> list[SessionEvent]:
        """Drain this subscription's INFEASIBLE / RPC_TIMEOUT /
        TENANT_DEGRADED notifications."""
        return self._edge.subscription_events(self.subscription_id)

    @property
    def state(self) -> SubscriptionState:
        return self._edge.subscription_state(self.subscription_id)

    def close(self) -> Status:
        """Idempotent explicit teardown (broker record is evicted once;
        repeat closes are local no-ops)."""
        if self._closed:
            return Status.OK
        self._closed = True
        return self._edge.close_subscription(self.subscription_id)

    def frames(self, *, max_frames: int = 16):
        """Migration helper: drain as a flat v1-style frame iterator."""
        while True:
            batch = self.poll(max_frames=max_frames)
            if not batch:
                return
            yield from batch.frames

    def __enter__(self) -> "Subscription":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
