"""v2 client surface: ``MezClient`` -> ``Session`` -> ``Subscription``.

The paper's five-call API (Section 3.1) is single-camera and blocking; the
headline workload (Section 5.1) is five cameras feeding one detector.  This
module is the session-oriented client shape that matches that workload:

    client = MezClient(system)
    with client.open_session("app0") as session:
        sub = session.subscribe(["cam0", "cam1"], 0.0, 8.0,
                                latency=0.100, accuracy=0.95)
        while (batch := sub.poll(max_frames=10)):
            payload, valid = batch.stack()        # jit-ready [B,H,W,C]
            ...
        sub.update_qos(latency=0.060)             # live renegotiation
        for ev in sub.events():                   # INFEASIBLE / RPC_TIMEOUT
            ...

Handles are thin: all state lives broker-side (``EdgeBroker`` session
registry), so a handle can be dropped and the registry stays authoritative
-- the same reasoning the paper uses to keep subscriber recovery trivial.
"""

from __future__ import annotations

from typing import Sequence

from repro.core.api import (FrameBatch, QosUpdate, SessionEvent, Status,
                            SubscribeSpec, SubscriptionState)

__all__ = ["MezClient", "Session", "Subscription"]


class MezClient:
    """Entry point for the v2 API.  Wraps anything that implements the
    ``SessionedMessagingSystem`` protocol -- an ``EdgeBroker`` directly or a
    ``MezSystem`` facade (``system.edge`` is unwrapped automatically)."""

    def __init__(self, system):
        self._edge = getattr(system, "edge", system)

    def connect(self, url: str = "mez://edge") -> str:
        return self._edge.connect(url)

    def get_camera_info(self) -> list[str]:
        return self._edge.get_camera_info()

    def open_session(self, application_id: str) -> "Session":
        return Session(self._edge,
                       self._edge.open_session(application_id),
                       application_id)


class Session:
    """One application's conversation with the edge broker.  Context-manager;
    closing the session closes every subscription it created."""

    def __init__(self, edge, session_id: str, application_id: str):
        self._edge = edge
        self.session_id = session_id
        self.application_id = application_id
        self._closed = False

    def subscribe(self, camera_ids: str | Sequence[str], t_start: float,
                  t_stop: float, *, latency: float, accuracy: float,
                  controlled: bool = True, feedback_window: int = 8,
                  credit_limit: int = 2, fleet: bool = False,
                  mesh=None, auto_recharacterize: bool = False,
                  drift_config=None) -> "Subscription":
        """Subscribe one or many cameras under shared QoS bounds; frames from
        all of them arrive timestamp-merged through one ``poll()``.

        ``fleet=True`` runs the subscription's per-camera PI controllers as
        ONE compiled vmapped step per poll (the fleet control plane):
        per-poll control cost is ~flat in camera count, and per-camera QoS
        retargets / table refreshes hot-swap into the compiled step without
        recompiling.  ``mesh`` additionally partitions the fused tick over
        the camera axis (``shard_map``): pass a device count, a
        ``jax.sharding.Mesh`` with a ``cams`` axis, or None to stay
        single-device -- sharding never changes the decisions.

        ``auto_recharacterize=True`` arms the drift-aware refresh loop: a
        vectorized staleness monitor watches each camera's observed wire
        sizes against its live table's predictions and re-characterizes a
        camera automatically when its windowed drift score crosses the
        hysteresis threshold -- no ``update_qos(recharacterize=True)``
        needed when the scene regime shifts mid-stream.  Refreshes surface
        as ``TABLE_REFRESH`` events on ``events()``.  ``drift_config`` is an
        optional ``repro.core.drift.DriftConfig`` tuning window/thresholds.
        """
        if isinstance(camera_ids, str):
            camera_ids = [camera_ids]
        specs = tuple(SubscribeSpec(self.application_id, cid, t_start, t_stop,
                                    latency, accuracy) for cid in camera_ids)
        sub_id = self._edge.create_subscription(
            self.session_id, specs, controlled=controlled,
            feedback_window=feedback_window, credit_limit=credit_limit,
            fleet=fleet, mesh=mesh,
            auto_recharacterize=auto_recharacterize,
            drift_config=drift_config)
        return Subscription(self._edge, sub_id, tuple(camera_ids))

    def events(self) -> list[SessionEvent]:
        """Drain pending events across all of this session's subscriptions."""
        return self._edge.session_events(self.session_id)

    def update_qos(self, *, latency: float | None = None,
                   accuracy: float | None = None,
                   recharacterize: bool = False) -> list[QosUpdate]:
        """Renegotiate bounds across EVERY subscription of this session.

        With ``recharacterize=True`` each camera first re-sweeps its knob
        tables over its own recent frames (the batched grid engine runs in
        seconds, cheap enough to fold into a renegotiation) and hot-swaps
        them into its live controller before the new bounds are applied --
        online re-characterization, per the CANS self-configuration model.
        Returns one ``QosUpdate`` per subscription.
        """
        return [self._edge.update_subscription_qos(
                    sid, latency=latency, accuracy=accuracy,
                    recharacterize=recharacterize)
                for sid in self._edge.session_subscription_ids(
                    self.session_id)]

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> Status:
        if self._closed:
            return Status.OK
        self._closed = True
        return self._edge.close_session(self.session_id)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class Subscription:
    """Handle for one (possibly multi-camera) subscription."""

    def __init__(self, edge, subscription_id: str, cameras: tuple[str, ...]):
        self._edge = edge
        self.subscription_id = subscription_id
        self.cameras = cameras
        self._closed = False

    def poll(self, max_frames: int = 16,
             deadline: float | None = None) -> FrameBatch:
        """Next ``FrameBatch``: at most ``max_frames`` timestamp-merged,
        at-most-once frames across all subscribed cameras.  Empty batch =>
        drained.  ``deadline`` (seconds) bounds the call's wall-clock time."""
        return self._edge.poll_subscription(self.subscription_id,
                                            max_frames=max_frames,
                                            deadline=deadline)

    def update_qos(self, *, latency: float | None = None,
                   accuracy: float | None = None,
                   recharacterize: bool = False) -> QosUpdate:
        """Renegotiate bounds live: per-camera controllers retarget in place,
        cursors/windows survive, no teardown or resubscribe.

        ``recharacterize=True`` additionally re-runs the batched knob-grid
        sweep on each camera's recent frames and hot-swaps the fresh tables
        into the live controller (and its jitted twin) before retargeting,
        so the new bounds are enforced against current conditions
        (``QosUpdate.recharacterized`` lists the cameras that re-swept).
        """
        return self._edge.update_subscription_qos(
            self.subscription_id, latency=latency, accuracy=accuracy,
            recharacterize=recharacterize)

    def events(self) -> list[SessionEvent]:
        """Drain this subscription's INFEASIBLE / RPC_TIMEOUT notifications."""
        return self._edge.subscription_events(self.subscription_id)

    @property
    def state(self) -> SubscriptionState:
        return self._edge.subscription_state(self.subscription_id)

    def close(self) -> Status:
        """Idempotent explicit teardown (broker record is evicted once;
        repeat closes are local no-ops)."""
        if self._closed:
            return Status.OK
        self._closed = True
        return self._edge.close_subscription(self.subscription_id)

    def frames(self, *, max_frames: int = 16):
        """Migration helper: drain as a flat v1-style frame iterator."""
        while True:
            batch = self.poll(max_frames=max_frames)
            if not batch:
                return
            yield from batch.frames

    def __enter__(self) -> "Subscription":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
